//! Table/figure regeneration benches — one end-to-end entry per paper
//! artifact (Sec. 4), timed with the in-repo harness.  Each entry runs a
//! reduced-budget version of the corresponding `e2train exp <id>`
//! pipeline so `cargo bench` both times the harness and re-prints the
//! paper's rows.  `E2T_BENCH_ITERS` scales the per-run budget.

use std::path::PathBuf;
use std::time::Instant;

fn main() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("index.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    }
    let iters: u64 = std::env::var("E2T_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let out = PathBuf::from("results");

    // every table and figure of the paper's evaluation section
    for id in [
        "tab2", "tab3", "fig4", "fig3a", "fig3b", "tab1", "fig5", "tab4", "finetune",
    ] {
        println!("\n######## bench: {id} (per-run budget {iters} iters) ########");
        let t0 = Instant::now();
        if let Err(e) = e2train::experiments::run_experiment(id, iters, &artifacts, &out)
        {
            eprintln!("{id} failed: {e:#}");
        }
        println!(
            "== {id} regenerated in {:.1}s ==",
            t0.elapsed().as_secs_f64()
        );
    }
}
