//! Runtime micro-benchmarks (in-repo harness; criterion is unavailable
//! offline): per-method train-step latency, eval latency, data pipeline,
//! and the host-side energy-model cost.  These are the L3 perf numbers
//! recorded in EXPERIMENTS.md §Perf.

use std::path::PathBuf;

use e2train::data::{synthetic, AugmentCfg, Sampler};
use e2train::energy::EnergyModel;
use e2train::runtime::{Engine, ModelState, StepHyper, TrainProgram};
use e2train::util::bench::bench;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    if !artifacts().join("index.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    }
    let engine = Engine::cpu().expect("PJRT CPU client");
    println!("== train-step latency per method (resnet8-c10-tiny, batch 32) ==");
    for method in ["sgd32", "fixed8", "signsgd", "psg", "slu", "sd", "e2train"] {
        let prog = TrainProgram::load(
            &engine,
            &artifacts().join(format!("resnet8-c10-tiny/{method}.json")),
        )
        .unwrap();
        let mut state = ModelState::init(&prog.manifest, 0);
        let data = synthetic::generate(10, 256, prog.manifest.arch.image_size, 0);
        let mut sampler =
            Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 0);
        let (x, y) = sampler.next_batch(&data);
        let mask: Option<Vec<f32>> = (prog.manifest.method.gating == "mask")
            .then(|| vec![1.0; prog.manifest.num_gated()]);
        bench(&format!("train_step/{method}"), 3, 20, || {
            prog.step(&mut state, &x, &y, StepHyper::lr(0.05), mask.as_deref())
                .unwrap();
        });
    }

    println!("\n== eval-batch latency ==");
    for family in ["resnet8-c10-tiny", "resnet20-c10"] {
        let prog = TrainProgram::load(
            &engine,
            &artifacts().join(format!("{family}/sgd32.json")),
        )
        .unwrap();
        let state = ModelState::init(&prog.manifest, 0);
        let hw = prog.manifest.arch.image_size;
        let eb = prog.eval_batch();
        let data = synthetic::generate(10, eb, hw, 0);
        let x = e2train::runtime::HostTensor::f32(
            vec![eb, hw, hw, 3],
            data.images.clone(),
        );
        let y = e2train::runtime::HostTensor::i32(vec![eb], data.labels.clone());
        bench(&format!("eval_batch/{family} (b={eb})"), 2, 10, || {
            prog.eval_batch_run(&state, &x, &y).unwrap();
        });
    }

    println!("\n== host-side pipeline (no device) ==");
    let data = synthetic::generate(10, 2048, 16, 0);
    let mut sampler = Sampler::new(data.n, 32, AugmentCfg::default(), 0);
    bench("sampler/next_batch (b=32, 16px, augmented)", 10, 200, || {
        let _ = sampler.next_batch(&data);
    });
    bench("synthetic/generate (256 samples, 16px)", 1, 10, || {
        let _ = synthetic::generate(10, 256, 16, 1);
    });

    let prog = TrainProgram::load(&engine, &artifacts().join("resnet20-c10/e2train.json"))
        .unwrap();
    let em = EnergyModel::from_manifest(&prog.manifest);
    let fracs = vec![0.7; prog.manifest.num_gated()];
    bench("energy_model/train_step charge", 100, 5000, || {
        let _ = em.train_step(&prog.manifest.method, &fracs, Some(0.6));
    });

    println!("\n== artifact compile time (cold cache) ==");
    let t0 = std::time::Instant::now();
    let fresh = Engine::cpu().unwrap();
    let _ = fresh
        .load(&artifacts().join("resnet20-c10/e2train.train.hlo.txt"))
        .unwrap();
    println!(
        "compile resnet20-c10/e2train.train: {:.2}s",
        t0.elapsed().as_secs_f64()
    );
}
