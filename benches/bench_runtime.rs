//! Runtime micro-benchmarks (in-repo harness; criterion is unavailable
//! offline): host-path vs resident-path train-step latency, trainer
//! throughput with/without prefetch, per-method step latency over real
//! AOT artifacts when present, data pipeline and energy-model cost.
//!
//! The host-vs-resident comparison runs on the generated reference
//! family, so it works on every machine; its results land in
//! `BENCH_runtime.json` at the repo root (schema bench_runtime/v1),
//! which tracks the perf trajectory across PRs — see PERF.md.

use std::path::PathBuf;

use e2train::data::{synthetic, AugmentCfg, Sampler};
use e2train::energy::EnergyModel;
use e2train::runtime::{
    write_reference_family, Engine, ModelState, RefFamilySpec, StepHyper, TrainProgram,
};
use e2train::util::bench::bench;
use e2train::util::perf;
use e2train::util::tmp::TempDir;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
}

/// Always-on section: the resident-state + prefetch story, measured on
/// the reference backend at bench scale.
fn bench_reference_paths() {
    let tmp = TempDir::new().expect("temp dir");
    let spec = RefFamilySpec::bench();
    write_reference_family(tmp.path(), &spec).expect("reference family");
    let engine = Engine::cpu().expect("engine");

    println!("== host path vs resident path ({}, reference backend) ==", spec.family);
    let mut steps = Vec::new();
    for method in ["sgd32", "e2train"] {
        let cmp = perf::compare_step_paths(&engine, tmp.path(), &spec.family, method, 5, 40)
            .expect("step comparison");
        println!(
            "  {method:<8} resident is {:.2}x the host path per step",
            cmp.speedup()
        );
        steps.push(cmp);
    }

    println!("\n== trainer throughput, prefetch on vs off (resident path) ==");
    let prefetch = perf::compare_prefetch(&engine, tmp.path(), &spec.family, "sgd32", 120)
        .expect("prefetch comparison");
    println!(
        "  steps/s: {:.1} with prefetch, {:.1} without",
        prefetch.steps_per_sec_on, prefetch.steps_per_sec_off
    );

    let report = perf::bench_report(
        "bench_runtime (release profile)",
        &spec.family,
        &steps,
        &prefetch,
    );
    perf::write_bench_report(&repo_root().join("BENCH_runtime.json"), &report)
        .expect("writing BENCH_runtime.json");
}

fn main() {
    bench_reference_paths();

    if !artifacts().join("index.json").exists() {
        eprintln!("\nAOT artifacts not built (`make artifacts`) — skipping PJRT sections");
        return;
    }
    let engine = Engine::cpu().expect("PJRT CPU client");
    println!("\n== train-step latency per method (resnet8-c10-tiny, batch 32) ==");
    for method in ["sgd32", "fixed8", "signsgd", "psg", "slu", "sd", "e2train"] {
        let prog = TrainProgram::load(
            &engine,
            &artifacts().join(format!("resnet8-c10-tiny/{method}.json")),
        )
        .unwrap();
        let mut state = ModelState::init(&prog.manifest, 0);
        let data = synthetic::generate(10, 256, prog.manifest.arch.image_size, 0);
        let mut sampler =
            Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 0);
        let (x, y) = sampler.next_batch(&data);
        let mask: Option<Vec<f32>> = (prog.manifest.method.gating == "mask")
            .then(|| vec![1.0; prog.manifest.num_gated()]);
        bench(&format!("train_step/{method}"), 3, 20, || {
            prog.step(&mut state, &x, &y, StepHyper::lr(0.05), mask.as_deref())
                .unwrap();
        });
    }

    println!("\n== eval-batch latency ==");
    for family in ["resnet8-c10-tiny", "resnet20-c10"] {
        let prog = TrainProgram::load(
            &engine,
            &artifacts().join(format!("{family}/sgd32.json")),
        )
        .unwrap();
        let state = ModelState::init(&prog.manifest, 0);
        let hw = prog.manifest.arch.image_size;
        let eb = prog.eval_batch();
        let data = synthetic::generate(10, eb, hw, 0);
        let x = e2train::runtime::HostTensor::f32(
            vec![eb, hw, hw, 3],
            data.images.clone(),
        );
        let y = e2train::runtime::HostTensor::i32(vec![eb], data.labels.clone());
        bench(&format!("eval_batch/{family} (b={eb})"), 2, 10, || {
            prog.eval_batch_run(&state, &x, &y).unwrap();
        });
    }

    println!("\n== host-side pipeline (no device) ==");
    let data = synthetic::generate(10, 2048, 16, 0);
    let mut sampler = Sampler::new(data.n, 32, AugmentCfg::default(), 0);
    bench("sampler/next_batch (b=32, 16px, augmented)", 10, 200, || {
        let _ = sampler.next_batch(&data);
    });
    bench("synthetic/generate (256 samples, 16px)", 1, 10, || {
        let _ = synthetic::generate(10, 256, 16, 1);
    });

    let prog = TrainProgram::load(&engine, &artifacts().join("resnet20-c10/e2train.json"))
        .unwrap();
    let em = EnergyModel::from_manifest(&prog.manifest);
    let fracs = vec![0.7; prog.manifest.num_gated()];
    bench("energy_model/train_step charge", 100, 5000, || {
        let _ = em.train_step(&prog.manifest.method, &fracs, Some(0.6));
    });

    println!("\n== artifact compile time (cold cache) ==");
    let t0 = std::time::Instant::now();
    let fresh = Engine::cpu().unwrap();
    let _ = fresh
        .load(&artifacts().join("resnet20-c10/e2train.train.hlo.txt"))
        .unwrap();
    println!(
        "compile resnet20-c10/e2train.train: {:.2}s",
        t0.elapsed().as_secs_f64()
    );
}
