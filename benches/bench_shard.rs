//! Sharded-training scaling bench (in-repo harness; criterion is
//! unavailable offline): steps/sec through the data-parallel sharded
//! path at shard counts {1, 2, 4} × reducer overlap {off, on} on the
//! bench-scale reference family, plus the single-device resident
//! baseline.  Writes `BENCH_shard.json` at the repo root (schema
//! `bench_shard/v1`, see PERF.md) — the canonical release-profile
//! record; the tier-1 smoke test writes debug numbers and never
//! overwrites a release-sourced file.

use std::path::PathBuf;

use e2train::experiments::{run_shard_bench, ShardBenchCfg};
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::perf::write_bench_report;
use e2train::util::tmp::TempDir;

fn main() {
    let tmp = TempDir::new().expect("temp dir");
    let spec = RefFamilySpec::bench();
    let fam = write_reference_family(tmp.path(), &spec).expect("reference family");
    let engine = Engine::cpu().expect("engine");

    let cfg = ShardBenchCfg {
        shard_counts: vec![1, 2, 4],
        warmup_steps: 5,
        steps: 60,
        accum: 2,
        seed: 0,
        source: "bench_shard (release profile)".into(),
    };
    println!("== sharded training scaling ({}, reference backend) ==", spec.family);
    let report =
        run_shard_bench(&engine, &fam.join("sgd32.json"), &cfg).expect("shard bench");

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_shard.json");
    write_bench_report(&path, &report).expect("writing BENCH_shard.json");
}
