//! Analytic energy-model report: per-method J/step and savings against
//! the paper's anchor numbers, without training anything.
//!
//!     cargo run --release --example energy_report [family]

use anyhow::Result;

fn main() -> Result<()> {
    let family = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "resnet20-c10".to_string());
    e2train::experiments::energy_report(&family, std::path::Path::new("artifacts"))
}
