//! Quickstart: load an AOT artifact, train E²-Train for 100 iterations
//! on the synthetic CIFAR-like task, and print accuracy + energy.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::runtime::Engine;

fn main() -> Result<()> {
    // 1. One PJRT CPU client for the process.
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());

    // 2. Configure a run: the e2train method = SLU gates + PSG updates in
    //    the AOT artifact, + SMD at the coordinator level.
    let mut cfg = RunCfg::quick("resnet8-c10-tiny", "e2train", 100);
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 1024, n_test: 256, seed: 0 };

    // 3. Train.  The trainer owns data, SMD schedule, SWA and the energy
    //    ledger; compute runs through the compiled HLO train step.
    let mut trainer = Trainer::new(&engine, cfg)?;
    let outcome = trainer.run(None)?;

    let m = &outcome.metrics;
    println!("\n== E2-Train quickstart ==");
    println!("test accuracy     : {:.2}%", m.final_test_acc * 100.0);
    println!("training energy   : {:.3} J (simulated 45nm, DESIGN.md)", m.total_joules);
    println!(
        "steps executed    : {} (+{} dropped by SMD)",
        m.steps_run, m.steps_skipped
    );
    if let Some(p) = m.mean_psg_frac {
        println!("PSG predictor use : {:.0}% of weight-gradient entries", p * 100.0);
    }
    if !m.mean_gate_fracs.is_empty() {
        let g: f64 = m.mean_gate_fracs.iter().sum::<f64>() / m.mean_gate_fracs.len() as f64;
        println!("SLU gate activity : {:.0}% of gateable blocks", g * 100.0);
    }
    Ok(())
}
