//! The Sec. 4.5 adaptation experiment as a standalone example: pre-train
//! on half the data, then compare two energy-constrained fine-tuning
//! strategies on the held-out half:
//!
//!   (1) fine-tune only the FC head with standard training (`headft`)
//!   (2) fine-tune everything with E²-Train (`e2train` + SMD)
//!
//! Paper result: option (2) gains more accuracy (+1.37% vs +0.30%) AND
//! uses 61.6% less energy.
//!
//!     cargo run --release --example finetune [iters]

use anyhow::Result;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::data::synthetic;
use e2train::runtime::Engine;

fn main() -> Result<()> {
    let iters: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let family = "resnet8-c10-tiny";
    let engine = Engine::cpu()?;

    // Shared task: one prototype seed; halves split i.i.d. (Sec. 4.5).
    let (full, test) = synthetic::generate_split(10, 2048, 512, 16, 0);
    let (half_a, half_b) = full.split(0.5);
    let dummy = DataCfg::Synthetic { classes: 10, n_train: 1, n_test: 1, seed: 0 };

    // --- pre-train on half A (standard fp32) ---------------------------
    let mut pre_cfg = RunCfg::quick(family, "sgd32", iters);
    pre_cfg.data = dummy.clone();
    let mut pre = Trainer::new(&engine, pre_cfg)?;
    pre.set_data(half_a, test.clone());
    let pre_out = pre.run(None)?;
    println!(
        "pre-trained on half A: {:.2}% test acc ({:.3} J)",
        pre_out.metrics.final_test_acc * 100.0,
        pre_out.metrics.total_joules
    );

    // --- option 1: head-only fine-tuning --------------------------------
    let mut h_cfg = RunCfg::quick(family, "headft", iters / 2);
    h_cfg.data = dummy.clone();
    let mut head = Trainer::new(&engine, h_cfg)?;
    head.set_data(half_b.clone(), test.clone());
    let h_out = head.run(Some(pre_out.state.clone()))?;

    // --- option 2: E2-Train on all layers --------------------------------
    let mut e_cfg = RunCfg::quick(family, "e2train", iters / 2);
    e_cfg.smd.enabled = true;
    e_cfg.data = dummy;
    let mut e2 = Trainer::new(&engine, e_cfg)?;
    e2.set_data(half_b, test);
    let e_out = e2.run(Some(pre_out.state))?;

    let base = pre_out.metrics.final_test_acc;
    println!("\n=== fine-tuning on held-out half B ===");
    println!(
        "head-only FT : {:+.2}% acc   {:.3} J",
        (h_out.metrics.final_test_acc - base) * 100.0,
        h_out.metrics.total_joules
    );
    println!(
        "E2-Train FT  : {:+.2}% acc   {:.3} J",
        (e_out.metrics.final_test_acc - base) * 100.0,
        e_out.metrics.total_joules
    );
    println!(
        "E2-Train saves {:.1}% energy vs head-only (paper: 61.6%)",
        (1.0 - e_out.metrics.total_joules / h_out.metrics.total_joules) * 100.0
    );
    Ok(())
}
