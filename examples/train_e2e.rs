//! End-to-end driver (the DESIGN.md validation workload): train the
//! resnet20-class model for several hundred steps on the synthetic
//! CIFAR-like corpus with the full E²-Train stack AND the fp32 baseline,
//! logging both loss curves and the accuracy-per-joule comparison.
//!
//!     cargo run --release --example train_e2e [iters] [family]
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::runtime::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let iters: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let family = args
        .get(2)
        .cloned()
        .unwrap_or_else(|| "resnet20-c10".to_string());

    let engine = Engine::cpu()?;
    let data = DataCfg::Synthetic { classes: 10, n_train: 2048, n_test: 512, seed: 0 };

    let mut results = Vec::new();
    for method in ["sgd32", "e2train"] {
        let mut cfg = RunCfg::quick(&family, method, iters);
        cfg.data = data.clone();
        cfg.eval_every = (iters / 8).max(1);
        let mut trainer = Trainer::new(&engine, cfg)?;
        println!(
            "\n=== {family}/{method}: {iters} iters, {} params ===",
            trainer.program.manifest.param_count
        );
        let out = trainer.run(None)?;
        println!("{:>6} {:>9} {:>9} {:>10} {:>9}", "iter", "loss", "train", "joules", "test");
        for p in &out.metrics.trace {
            if p.iter % (iters / 10).max(1) == 0 || p.test_acc.is_some() {
                println!(
                    "{:>6} {:>9.4} {:>8.1}% {:>10.3} {:>9}",
                    p.iter,
                    p.loss,
                    p.train_acc * 100.0,
                    p.joules,
                    p.test_acc
                        .map(|a| format!("{:.1}%", a * 100.0))
                        .unwrap_or_else(|| "-".into())
                );
            }
        }
        println!(
            "final: acc {:.2}% | {:.3} J | {} steps ({} SMD-dropped) | {:.1}s wall",
            out.metrics.final_test_acc * 100.0,
            out.metrics.total_joules,
            out.metrics.steps_run,
            out.metrics.steps_skipped,
            out.metrics.wall_seconds,
        );
        results.push((method, out.metrics));
    }

    let (bm, base) = &results[0];
    let (em, e2) = &results[1];
    println!("\n=== energy comparison ===");
    println!(
        "{bm}: {:.2}% @ {:.3} J   {em}: {:.2}% @ {:.3} J",
        base.final_test_acc * 100.0,
        base.total_joules,
        e2.final_test_acc * 100.0,
        e2.total_joules
    );
    println!(
        "E2-Train energy saving: {:.1}%  (paper claims >80% at small accuracy cost)",
        (1.0 - e2.total_joules / base.total_joules) * 100.0
    );
    Ok(())
}
