//! Golden-shape test for the `obs_trace/v1` JSONL contract.
//!
//! `e2train trace-report`, external tooling, and future schema bumps
//! all hang off these exact row shapes, so this test pins them field by
//! field: row order (meta, spans, recoveries, counters, summaries), the
//! exact key set of every kind, and every value that is deterministic
//! (durations fed through `Obs::record` are explicit, so only wall-clock
//! offsets float).  Growing the schema additively is fine — rename or
//! drop a field and this test is the tripwire that says "bump the
//! schema string".

use std::time::Duration;

use e2train::obs::{self, Obs, TraceKey, TRACE_SCHEMA};
use e2train::util::json::{parse, Json};

/// The golden key set per row kind.  BTreeMap-backed objects iterate
/// sorted, so the comparison is order-insensitive but exhaustive:
/// missing AND extra fields both fail.
fn assert_fields(row: &Json, kind: &str, want: &[&str]) {
    let obj = row.as_obj().unwrap_or_else(|| panic!("{kind} row not an object"));
    let mut got: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
    got.sort_unstable();
    let mut want: Vec<&str> = want.to_vec();
    want.sort_unstable();
    assert_eq!(got, want, "{kind} row field set drifted");
}

/// Build the reference trace: one of everything, with explicit
/// durations so every dur/count/value below is exact.
fn sample_trace() -> obs::RunTrace {
    let obs = Obs::new(true);
    obs.set_key(TraceKey {
        family: "refmlp-tiny".into(),
        method: "e2train".into(),
        backend: "sharded".into(),
        shards: 2,
        batch: 8,
    });
    obs.record(obs::PHASE_AUGMENT, Duration::from_micros(150));
    obs.record(obs::PHASE_STEP_EXEC, Duration::from_micros(400));
    obs.record_on("shard-0", obs::PHASE_SHARD_EXEC, Duration::from_micros(180));
    obs.record_on("shard-1", obs::PHASE_SHARD_EXEC, Duration::from_micros(220));
    obs.count(obs::CTR_CKPT_SUBMITS, 1);
    obs.count(obs::CTR_SHARD_IMBALANCE_NS, 40_000);
    obs.recovery("engine.train_step", 1, 10);
    obs.snapshot().expect("live hub snapshots")
}

#[test]
fn jsonl_rows_match_the_golden_shape() {
    let trace = sample_trace();
    let text = trace.to_jsonl();
    let rows: Vec<Json> = text.lines().map(|l| parse(l).unwrap()).collect();

    // Row order is part of the contract: meta first, then the span
    // event log in record order, recoveries, counters, summaries.
    let kinds: Vec<&str> =
        rows.iter().map(|r| r.at(&["kind"]).as_str().unwrap()).collect();
    assert_eq!(
        kinds,
        vec![
            "meta", "span", "span", "span", "span", "recovery", "counter",
            "counter", "summary", "summary", "summary",
        ],
        "row order drifted"
    );

    // meta: the run key + trace-wide facts.
    let meta = &rows[0];
    assert_fields(
        meta,
        "meta",
        &[
            "kind", "schema", "family", "method", "backend", "shards", "batch",
            "wall_ms", "dropped_events",
        ],
    );
    assert_eq!(meta.at(&["schema"]).as_str(), Some(TRACE_SCHEMA));
    assert_eq!(meta.at(&["schema"]).as_str(), Some("obs_trace/v1"));
    assert_eq!(meta.at(&["family"]).as_str(), Some("refmlp-tiny"));
    assert_eq!(meta.at(&["method"]).as_str(), Some("e2train"));
    assert_eq!(meta.at(&["backend"]).as_str(), Some("sharded"));
    assert_eq!(meta.at(&["shards"]).as_f64(), Some(2.0));
    assert_eq!(meta.at(&["batch"]).as_f64(), Some(8.0));
    assert_eq!(meta.at(&["dropped_events"]).as_f64(), Some(0.0));
    assert!(meta.at(&["wall_ms"]).as_f64().unwrap() >= 0.0);

    // span events: record order, sequenced, thread-labeled.
    for (i, row) in rows[1..5].iter().enumerate() {
        assert_fields(row, "span", &["kind", "phase", "thread", "seq", "t_ms", "dur_ms"]);
        assert_eq!(row.at(&["seq"]).as_f64(), Some(i as f64), "span seq");
    }
    assert_eq!(rows[1].at(&["phase"]).as_str(), Some(obs::PHASE_AUGMENT));
    assert_eq!(rows[1].at(&["dur_ms"]).as_f64(), Some(0.15));
    assert_eq!(rows[3].at(&["phase"]).as_str(), Some(obs::PHASE_SHARD_EXEC));
    assert_eq!(rows[3].at(&["thread"]).as_str(), Some("shard-0"));
    assert_eq!(rows[4].at(&["thread"]).as_str(), Some("shard-1"));
    assert_eq!(rows[4].at(&["dur_ms"]).as_f64(), Some(0.22));

    // recovery: structured supervision events, not log lines.
    let rec = &rows[5];
    assert_fields(rec, "recovery", &["kind", "site", "attempt", "backoff_ms", "t_ms"]);
    assert_eq!(rec.at(&["site"]).as_str(), Some("engine.train_step"));
    assert_eq!(rec.at(&["attempt"]).as_f64(), Some(1.0));
    assert_eq!(rec.at(&["backoff_ms"]).as_f64(), Some(10.0));

    // counters: final values, sorted by name (BTreeMap order).
    for row in &rows[6..8] {
        assert_fields(row, "counter", &["kind", "name", "value"]);
    }
    assert_eq!(rows[6].at(&["name"]).as_str(), Some(obs::CTR_CKPT_SUBMITS));
    assert_eq!(rows[6].at(&["value"]).as_f64(), Some(1.0));
    assert_eq!(rows[7].at(&["name"]).as_str(), Some(obs::CTR_SHARD_IMBALANCE_NS));
    assert_eq!(rows[7].at(&["value"]).as_f64(), Some(40_000.0));

    // summaries: one per phase, sorted by phase name, with the full
    // latency digest.  shard-exec merged both thread labels.
    for row in &rows[8..] {
        assert_fields(
            row,
            "summary",
            &["kind", "phase", "count", "total_ms", "mean_ms", "p50_ms", "p99_ms", "max_ms"],
        );
    }
    let phases: Vec<&str> =
        rows[8..].iter().map(|r| r.at(&["phase"]).as_str().unwrap()).collect();
    assert_eq!(
        phases,
        vec![obs::PHASE_AUGMENT, obs::PHASE_SHARD_EXEC, obs::PHASE_STEP_EXEC],
        "summary rows must arrive sorted by phase"
    );
    let shard = &rows[9];
    assert_eq!(shard.at(&["count"]).as_f64(), Some(2.0));
    let total = shard.at(&["total_ms"]).as_f64().unwrap();
    assert!((total - 0.4).abs() < 1e-9, "shard-exec total {total}");
    // Histogram percentiles are bucket upper bounds clamped to the
    // observed max — never below the true p50, never above the max.
    let p50 = shard.at(&["p50_ms"]).as_f64().unwrap();
    let max = shard.at(&["max_ms"]).as_f64().unwrap();
    assert!((max - 0.22).abs() < 1e-9, "shard-exec max {max}");
    assert!(p50 >= 0.18 - 1e-9 && p50 <= max + 1e-9, "shard-exec p50 {p50}");
}

/// The trace file a real traced run writes is exactly `to_jsonl()` —
/// pinned so `trace-report` can always re-read what `--trace-out` wrote.
#[test]
fn write_emits_the_same_bytes_as_to_jsonl() {
    let trace = sample_trace();
    let tmp = e2train::util::tmp::TempDir::new().unwrap();
    let path = tmp.path().join("trace.jsonl");
    trace.write(&path).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), trace.to_jsonl());
}

/// When the planner recorded a plan, it becomes the second row — right
/// after `meta`, before the span log — with the exact `PlanRecord`
/// field set.  Planless traces (everything above) carry no such row, so
/// the schema grows additively.
#[test]
fn plan_row_follows_meta_when_a_plan_was_recorded() {
    use e2train::obs::catalog::PlanRecord;

    let obs = Obs::new(true);
    obs.set_key(TraceKey {
        family: "refmlp-tiny".into(),
        method: "sgd32".into(),
        backend: "resident".into(),
        shards: 0,
        batch: 8,
    });
    obs.record(obs::PHASE_STEP_EXEC, Duration::from_micros(100));
    obs.set_plan(PlanRecord {
        backend: "resident".into(),
        prefetch: true,
        prefetch_depth: Some(2),
        predicted_sps: 1000.0,
        ..Default::default()
    });
    let text = obs.snapshot().unwrap().to_jsonl();
    let rows: Vec<Json> = text.lines().map(|l| parse(l).unwrap()).collect();

    let kinds: Vec<&str> =
        rows.iter().map(|r| r.at(&["kind"]).as_str().unwrap()).collect();
    assert_eq!(kinds, vec!["meta", "plan", "span", "summary"], "plan row position");
    assert_fields(
        &rows[1],
        "plan",
        &[
            "kind", "backend", "shards", "prefetch", "prefetch_depth", "probed",
            "predicted_sps", "predicted_j_per_step", "actual_sps",
            "actual_j_per_step", "sps_rel_err", "j_rel_err",
        ],
    );
    assert_eq!(rows[1].at(&["backend"]).as_str(), Some("resident"));
    assert_eq!(rows[1].at(&["prefetch_depth"]).as_f64(), Some(2.0));
    assert_eq!(rows[1].at(&["predicted_sps"]).as_f64(), Some(1000.0));
}

/// An aggregate-only hub (no `--trace-out`) produces no span rows at
/// all: the event log costs nothing unless a trace was requested.
#[test]
fn aggregate_only_traces_carry_no_span_rows() {
    let obs = Obs::new(false);
    obs.record(obs::PHASE_STEP_EXEC, Duration::from_micros(100));
    let text = obs.snapshot().unwrap().to_jsonl();
    assert!(
        !text.lines().any(|l| parse(l).unwrap().at(&["kind"]).as_str() == Some("span")),
        "aggregate-only hub leaked span events"
    );
}
