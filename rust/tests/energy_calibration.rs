//! Energy-model calibration pinned to the paper's anchor numbers
//! (Sec. 4.4 / Table 3) — these are the claims the reproduction rests on,
//! so they are tested, not just reported.

use std::path::PathBuf;

use e2train::energy::EnergyModel;
use e2train::runtime::Manifest;

fn manifest(method: &str) -> Option<Manifest> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join(format!("artifacts/resnet20-c10/{method}.json"));
    p.exists().then(|| Manifest::load(&p).unwrap())
}

fn saving(method: &str, fracs: &[f64], psg: Option<f64>) -> Option<f64> {
    let base_m = manifest("sgd32")?;
    let m = manifest(method)?;
    let e0 = EnergyModel::from_manifest(&base_m)
        .train_step(&base_m.method, &[], None)
        .total();
    let e = EnergyModel::from_manifest(&m)
        .train_step(&m.method, fracs, psg)
        .total();
    Some(1.0 - e / e0)
}

#[test]
fn fixed8_saving_matches_paper_anchor() {
    // Paper: 38.62% (8-bit fwd, 32-bit gradients).
    if let Some(s) = saving("fixed8", &[], None) {
        assert!((0.33..=0.45).contains(&s), "fixed8 saving {s}");
    }
}

#[test]
fn psg_saving_matches_paper_anchor() {
    // Paper: 63.28% at >=60% predictor usage.
    if let Some(s) = saving("psg", &[], Some(0.6)) {
        assert!((0.55..=0.72).contains(&s), "psg saving {s}");
    }
}

#[test]
fn e2train_sweep_matches_table3() {
    // Paper Table 3 (+SMD): skip 20/40/60% -> 84.6/88.7/92.8% savings.
    let Some(m) = manifest("e2train") else { return };
    let ng = m.num_gated();
    let expected = [(0.2, 0.846), (0.4, 0.887), (0.6, 0.928)];
    for (skip, paper) in expected {
        let s = saving("e2train", &vec![1.0 - skip; ng], Some(0.6)).unwrap();
        // +SMD halves the charged steps.
        let with_smd = 1.0 - 0.5 * (1.0 - s);
        assert!(
            (with_smd - paper).abs() < 0.05,
            "skip {skip}: measured {with_smd:.3} vs paper {paper}"
        );
    }
}

#[test]
fn savings_monotone_in_skip_ratio() {
    let Some(m) = manifest("e2train") else { return };
    let ng = m.num_gated();
    let mut prev = -1.0;
    for skip in [0.0, 0.2, 0.4, 0.6, 0.8] {
        let s = saving("e2train", &vec![1.0 - skip; ng], Some(0.6)).unwrap();
        assert!(s > prev, "saving not monotone at skip {skip}");
        prev = s;
    }
}

#[test]
fn signsgd_saves_little() {
    // Paper leaves SignSGD's saving blank: it computes full gradients.
    if let Some(s) = saving("signsgd", &[], None) {
        assert!(s < 0.05, "signsgd saving {s} should be negligible");
    }
}

#[test]
fn gate_overhead_below_paper_bound() {
    // Appendix C: RNNGates cost ~0.04% of the trunk FLOPs.
    let Some(m) = manifest("e2train") else { return };
    let frac = m.gate_flops as f64 / m.total_flops as f64;
    assert!(frac < 0.005, "gate overhead {frac}");
}
