//! End-to-end integration tests over the real AOT artifacts: PJRT load,
//! train-step execution, state round-trips, the full Trainer loop, and
//! the fine-tuning protocol.  All tests skip gracefully when artifacts
//! haven't been built (`make artifacts`).

use std::path::PathBuf;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::data::synthetic;
use e2train::energy::EnergyModel;
use e2train::runtime::{Engine, Manifest, ModelState, StepHyper, TrainProgram};

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts().join("index.json").exists()
}

/// One engine per test: the PJRT client holds raw pointers (not Sync),
/// so it cannot live in a shared static.  With the single-core test
/// harness tests run serially and the per-test compile cost is bounded.
fn engine() -> Engine {
    Engine::cpu().expect("PJRT CPU client")
}

fn quick_cfg(method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick("resnet8-c10-tiny", method, iters);
    cfg.artifacts_dir = artifacts();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 256, n_test: 128, seed: 0 };
    cfg
}

#[test]
fn train_step_roundtrip_all_methods() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for method in ["sgd32", "fixed8", "signsgd", "psg", "slu", "sd", "e2train", "headft"]
    {
        let eng = engine();
        let path = artifacts().join("resnet8-c10-tiny").join(format!("{method}.json"));
        let prog = TrainProgram::load(&eng, &path).unwrap();
        let mut state = ModelState::init(&prog.manifest, 7);
        let n0 = state.total_elems();
        let data = synthetic::generate(10, 64, prog.manifest.arch.image_size, 0);
        let mut sampler = e2train::data::Sampler::new(
            data.n,
            prog.batch(),
            e2train::data::AugmentCfg::default(),
            1,
        );
        let (x, y) = sampler.next_batch(&data);
        let mask: Option<Vec<f32>> = (prog.manifest.method.gating == "mask")
            .then(|| vec![1.0; prog.manifest.num_gated()]);
        let sm = prog
            .step(&mut state, &x, &y, StepHyper::lr(0.05), mask.as_deref())
            .unwrap();
        assert!(sm.loss.is_finite() && sm.loss > 0.0, "{method}: loss {}", sm.loss);
        assert!(sm.correct >= 0.0 && sm.correct <= prog.batch() as f64);
        assert_eq!(state.total_elems(), n0, "{method}: state shape drift");
        if prog.manifest.method.gating != "none" {
            assert_eq!(sm.gate_fracs.len(), prog.manifest.num_gated(), "{method}");
        }
        if prog.manifest.method.update == "psg" {
            let f = sm.psg_frac.unwrap();
            assert!((0.0..=1.0).contains(&f), "{method}: psg_frac {f}");
        }
        // eval path works on the same state (eval batch differs from
        // the train batch — build one of the right size).
        let eb = prog.eval_batch();
        let hw = prog.manifest.arch.image_size;
        let ed = synthetic::generate(10, eb, hw, 3);
        let ex = e2train::runtime::HostTensor::f32(
            vec![eb, hw, hw, 3],
            ed.images.clone(),
        );
        let ey = e2train::runtime::HostTensor::i32(vec![eb], ed.labels.clone());
        let em = prog.eval_batch_run(&state, &ex, &ey).unwrap();
        assert!(em.loss.is_finite());
        assert!(em.correct <= em.correct5 + 1e-9);
    }
}

#[test]
fn loss_decreases_on_fixed_batch() {
    if !have_artifacts() {
        return;
    }
    let eng = engine();
    let path = artifacts().join("resnet8-c10-tiny/sgd32.json");
    let prog = TrainProgram::load(&eng, &path).unwrap();
    let mut state = ModelState::init(&prog.manifest, 3);
    let data = synthetic::generate(10, 32, prog.manifest.arch.image_size, 5);
    let mut sampler = e2train::data::Sampler::new(
        data.n,
        prog.batch(),
        e2train::data::AugmentCfg { enabled: false, ..Default::default() },
        2,
    );
    let (x, y) = sampler.next_batch(&data);
    let mut losses = Vec::new();
    for _ in 0..10 {
        let sm = prog.step(&mut state, &x, &y, StepHyper::lr(0.05), None).unwrap();
        losses.push(sm.loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn trainer_end_to_end_with_smd() {
    if !have_artifacts() {
        return;
    }
    let eng = engine();
    let mut cfg = quick_cfg("sgd32", 30);
    cfg.smd.enabled = true;
    cfg.smd.p = 0.5;
    let mut trainer = Trainer::new(&eng, cfg).unwrap();
    let out = trainer.run(None).unwrap();
    let m = &out.metrics;
    assert_eq!(m.steps_run + m.steps_skipped, 30);
    assert!(m.steps_skipped > 5, "SMD skipped only {}", m.steps_skipped);
    assert!(m.final_test_acc >= 0.0 && m.final_test_acc <= 1.0);
    assert!(m.total_joules > 0.0);
    // energy trace is monotone
    let js: Vec<f64> = m.trace.iter().map(|p| p.joules).collect();
    assert!(js.windows(2).all(|w| w[0] <= w[1]));
}

#[test]
fn smd_halves_energy_vs_smb() {
    if !have_artifacts() {
        return;
    }
    let eng = engine();
    let base = Trainer::new(&eng, quick_cfg("sgd32", 24))
        .unwrap()
        .run(None)
        .unwrap();
    let mut cfg = quick_cfg("sgd32", 24);
    cfg.smd.enabled = true;
    let smd = Trainer::new(&eng, cfg).unwrap().run(None).unwrap();
    let ratio = smd.metrics.total_joules / base.metrics.total_joules;
    assert!(ratio < 0.85, "SMD energy ratio {ratio} not < 0.85");
}

#[test]
fn e2train_saves_energy_vs_sgd32() {
    if !have_artifacts() {
        return;
    }
    let eng = engine();
    let base = Trainer::new(&eng, quick_cfg("sgd32", 20))
        .unwrap()
        .run(None)
        .unwrap();
    let e2 = Trainer::new(&eng, quick_cfg("e2train", 20))
        .unwrap()
        .run(None)
        .unwrap();
    let saving = 1.0 - e2.metrics.total_joules / base.metrics.total_joules;
    // SMD (x0.5) + PSG precision + SLU skipping: must save well over half.
    assert!(saving > 0.5, "e2train saving only {saving}");
    assert!(e2.metrics.mean_psg_frac.is_some());
}

#[test]
fn sd_method_runs_with_masks() {
    if !have_artifacts() {
        return;
    }
    let eng = engine();
    let mut cfg = quick_cfg("sd", 10);
    cfg.sd.p_l = 0.3;
    let out = Trainer::new(&eng, cfg).unwrap().run(None).unwrap();
    // mean gate activity should reflect the aggressive drop schedule
    let mean: f64 = out.metrics.mean_gate_fracs.iter().sum::<f64>()
        / out.metrics.mean_gate_fracs.len().max(1) as f64;
    assert!(mean < 0.95, "sd mean gate {mean}");
}

#[test]
fn seeds_reproduce_exactly() {
    if !have_artifacts() {
        return;
    }
    let eng = engine();
    let a = Trainer::new(&eng, quick_cfg("sgd32", 8))
        .unwrap()
        .run(None)
        .unwrap();
    let b = Trainer::new(&eng, quick_cfg("sgd32", 8))
        .unwrap()
        .run(None)
        .unwrap();
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc);
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules);
    let la: Vec<f64> = a.metrics.trace.iter().map(|p| p.loss).collect();
    let lb: Vec<f64> = b.metrics.trace.iter().map(|p| p.loss).collect();
    assert_eq!(la, lb);
}

#[test]
fn finetune_state_carries_over() {
    if !have_artifacts() {
        return;
    }
    // Pre-train, then verify resuming from the returned state beats a
    // fresh init on the same eval set (the Sec. 4.5 mechanism).
    let eng = engine();
    let mut pre = Trainer::new(&eng, quick_cfg("sgd32", 40)).unwrap();
    let out = pre.run(None).unwrap();
    let (acc_resume, _, _) = pre.evaluate_full(&out.state).unwrap();
    let fresh = ModelState::init(&pre.program.manifest, 99);
    let (acc_fresh, _, _) = pre.evaluate_full(&fresh).unwrap();
    assert!(
        acc_resume > acc_fresh,
        "trained {acc_resume} <= fresh {acc_fresh}"
    );
}

#[test]
fn energy_model_matches_manifest_blocks() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load(&artifacts().join("resnet8-c10-tiny/e2train.json")).unwrap();
    let em = EnergyModel::from_manifest(&m);
    assert_eq!(em.blocks.len(), m.blocks.len());
    // full-active step charges more than half-active
    let full = em.train_step(&m.method, &vec![1.0; m.num_gated()], Some(0.6));
    let half = em.train_step(&m.method, &vec![0.5; m.num_gated()], Some(0.6));
    assert!(half.total() < full.total());
}
