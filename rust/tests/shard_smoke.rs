//! Tier-1 shard smoke: runs the sharded-training scaling sweep at
//! reduced scale and records `BENCH_shard.json` at the repo root, so
//! every verified checkout carries a sharding-perf snapshot even when
//! `cargo bench --bench bench_shard` never runs.  Debug timings are
//! only a smoke signal; the release bench (or
//! `scripts/shard_bench.sh`) writes the canonical numbers, and this
//! test never overwrites a release-sourced file — the same convention
//! as `BENCH_runtime.json` / `BENCH_serve.json`.

use std::path::PathBuf;

use e2train::experiments::{run_shard_bench, ShardBenchCfg};
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::json::parse;
use e2train::util::tmp::TempDir;

#[test]
fn shard_smoke_records_bench_shard_json() {
    let tmp = TempDir::new().unwrap();
    let spec = RefFamilySpec::tiny();
    let fam = write_reference_family(tmp.path(), &spec).unwrap();
    let engine = Engine::cpu().unwrap();

    let cfg = ShardBenchCfg {
        shard_counts: vec![1, 2],
        warmup_steps: 1,
        steps: 8,
        accum: 2,
        seed: 0,
        source: "cargo-test smoke (debug profile)".into(),
    };
    let report = run_shard_bench(&engine, &fam.join("sgd32.json"), &cfg).unwrap();

    // Schema + per-row sanity: shards {1, 2} × reducer overlap
    // {off, on}, each with scaling efficiency and the measured
    // per-step host-reduce wall.  Debug timings are too noisy to
    // assert overlap-on beats overlap-off here — the release bench is
    // where that comparison is read.
    assert_eq!(report.at(&["schema"]).as_str(), Some("bench_shard/v1"));
    assert!(report.at(&["single_device_sps"]).as_f64().unwrap() > 0.0);
    let rows = report.at(&["rows"]).as_arr().expect("rows array");
    assert_eq!(rows.len(), 4, "shards {{1,2}} x overlap {{off,on}}");
    for (i, (want_shards, want_overlap)) in
        [(1.0, false), (2.0, false), (1.0, true), (2.0, true)].iter().enumerate()
    {
        assert_eq!(rows[i].at(&["shards"]).as_f64(), Some(*want_shards));
        assert_eq!(rows[i].at(&["overlap"]).as_bool(), Some(*want_overlap));
    }
    for row in rows {
        assert!(row.at(&["steps_per_sec"]).as_f64().unwrap() > 0.0);
        assert_eq!(row.at(&["accum"]).as_f64(), Some(2.0));
        let reduce_ms = row.at(&["reduce_ms"]).as_f64().expect("reduce_ms field");
        assert!(reduce_ms.is_finite() && reduce_ms >= 0.0);
        let eff = row.at(&["efficiency"]).as_f64().unwrap();
        assert!(eff.is_finite() && eff > 0.0);
    }

    // Record at the repo root unless a release run already did.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_shard.json");
    let has_release_numbers = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| parse(&t).ok())
        .and_then(|v| v.at(&["source"]).as_str().map(|s| s.contains("release")))
        .unwrap_or(false);
    if has_release_numbers {
        eprintln!("[smoke] BENCH_shard.json holds release numbers; leaving it alone");
    } else {
        std::fs::write(&path, report.to_string()).unwrap();
        assert!(path.exists());
    }
}
