//! Determinism contract of the data-parallel sharded training path:
//! for fixed seeds, S ∈ {1, 2, 3} shards produce **bitwise-identical**
//! metrics and final model state to the single-device resident path —
//! the same contract `resident_equivalence.rs` pins for
//! resident-vs-host, extended to the sharded fixed-order host-side
//! all-reduce (`runtime::shard`).
//!
//! Coverage baked into the workload:
//! * batch 8 across 3 shards — a non-divisible (3/3/2) split;
//! * the `e2train` method runs with SMD enabled (its `RunCfg::quick`
//!   default), so dropped iterations consume whole batches on the
//!   sharded loop too (asserted below);
//! * prefetch stays on (the default), so the sharded probe step the
//!   depth auto-tuner takes must be invisible;
//! * `e2train` also exercises learned gates, PSG telemetry, SWA
//!   snapshots and the running-mean state through the sharded apply.

use std::path::Path;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

fn ref_cfg(artifacts: &Path, method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, method, iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg
}

#[test]
fn sharded_runs_match_single_device_resident_path() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    for method in ["sgd32", "e2train"] {
        let engine = Engine::cpu().unwrap();
        let mut base_cfg = ref_cfg(tmp.path(), method, 24);
        base_cfg.eval_every = 8;
        assert_eq!(base_cfg.shards, 0, "default must stay single-executor");
        let base = Trainer::new(&engine, base_cfg).unwrap().run(None).unwrap();
        if method == "e2train" {
            // SMD is on by default for e2train; without at least one
            // dropped iteration the test loses its SMD coverage.
            assert!(
                base.metrics.steps_skipped > 0,
                "SMD never dropped a batch in 24 iters"
            );
        }

        for shards in [1usize, 2, 3] {
            let mut cfg = ref_cfg(tmp.path(), method, 24);
            cfg.eval_every = 8;
            cfg.shards = shards;
            let out = Trainer::new(&engine, cfg).unwrap().run(None).unwrap();
            let tag = format!("{method} S={shards}");
            assert_eq!(
                out.metrics.final_test_acc, base.metrics.final_test_acc,
                "{tag}: final acc"
            );
            assert_eq!(
                out.metrics.final_test_acc_top5,
                base.metrics.final_test_acc_top5,
                "{tag}: final top5"
            );
            assert_eq!(out.metrics.final_loss, base.metrics.final_loss, "{tag}: loss");
            assert_eq!(
                out.metrics.total_joules, base.metrics.total_joules,
                "{tag}: energy"
            );
            assert_eq!(out.metrics.steps_run, base.metrics.steps_run, "{tag}");
            assert_eq!(out.metrics.steps_skipped, base.metrics.steps_skipped, "{tag}");
            assert_eq!(
                out.metrics.mean_gate_fracs, base.metrics.mean_gate_fracs,
                "{tag}: gate telemetry"
            );
            assert_eq!(
                out.metrics.mean_psg_frac, base.metrics.mean_psg_frac,
                "{tag}: psg telemetry"
            );
            let la: Vec<f64> = base.metrics.trace.iter().map(|p| p.loss).collect();
            let lb: Vec<f64> = out.metrics.trace.iter().map(|p| p.loss).collect();
            assert_eq!(la, lb, "{tag}: per-step losses diverged");
            let ea: Vec<Option<f64>> =
                base.metrics.trace.iter().map(|p| p.test_acc).collect();
            let eb: Vec<Option<f64>> =
                out.metrics.trace.iter().map(|p| p.test_acc).collect();
            assert_eq!(ea, eb, "{tag}: periodic evals diverged");
            out.state.assert_bitwise_eq(&base.state);
        }
    }
}

/// The sharded loop composes with the legacy synchronous sampling path
/// too: prefetch off must not change a single bit either.
#[test]
fn sharded_run_is_prefetch_invariant() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    let mut on_cfg = ref_cfg(tmp.path(), "sgd32", 16);
    on_cfg.shards = 2;
    let on = Trainer::new(&engine, on_cfg).unwrap().run(None).unwrap();

    let mut off_cfg = ref_cfg(tmp.path(), "sgd32", 16);
    off_cfg.shards = 2;
    off_cfg.prefetch = false;
    let off = Trainer::new(&engine, off_cfg).unwrap().run(None).unwrap();

    assert_eq!(on.metrics.final_test_acc, off.metrics.final_test_acc);
    assert_eq!(on.metrics.final_loss, off.metrics.final_loss);
    let la: Vec<f64> = on.metrics.trace.iter().map(|p| p.loss).collect();
    let lb: Vec<f64> = off.metrics.trace.iter().map(|p| p.loss).collect();
    assert_eq!(la, lb, "prefetch on/off diverged on the sharded loop");
    on.state.assert_bitwise_eq(&off.state);
}

/// Fine-tune handoff works through the sharded loop: a sgd32-pretrained
/// state migrates by name into a sharded e2train run.
#[test]
fn sharded_finetune_handoff_matches_single_device() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let pre = Trainer::new(&engine, ref_cfg(tmp.path(), "sgd32", 12))
        .unwrap()
        .run(None)
        .unwrap();

    let single = Trainer::new(&engine, ref_cfg(tmp.path(), "e2train", 8))
        .unwrap()
        .run(Some(pre.state.clone()))
        .unwrap();

    let mut sharded_cfg = ref_cfg(tmp.path(), "e2train", 8);
    sharded_cfg.shards = 2;
    let sharded = Trainer::new(&engine, sharded_cfg)
        .unwrap()
        .run(Some(pre.state))
        .unwrap();

    assert_eq!(single.metrics.final_test_acc, sharded.metrics.final_test_acc);
    single.state.assert_bitwise_eq(&sharded.state);
}
