//! The fault-injection matrix (reference backend, runs everywhere):
//! supervised recovery must be **invisible** in everything the
//! determinism contract covers.
//!
//! 1. **Bitwise recovery** — a supervised run with deterministic faults
//!    injected at every armed site (`engine.train_step`,
//!    `data.prefetch`, `checkpoint.sink`, `registry.read`,
//!    `shard.engine` + `pool.fork`) ends with exactly the metrics
//!    trace, energy ledger and final model state of the fault-free run
//!    of the same config — across the host, resident(+prefetch) and
//!    sharded (S ∈ {2, 3}) execution layouts.  Only
//!    `RunMetrics::recoveries` (outside the contract) may differ.
//! 2. **Fatal means fatal** — contradictions no retry can fix (a
//!    checkpoint fingerprint from another run, an exhausted retry
//!    budget) fail fast with the original error, never loop.
//! 3. **Serve resilience** — a worker death fails only the batch it
//!    held (explicit error, no hung ticket), the monitor respawns
//!    within budget, and past the budget every request still fails
//!    explicitly.  The registry watcher absorbs torn manifest reads and
//!    counts the retries.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use e2train::checkpoint::{
    CheckpointRegistry, FsRemoteStore, RemoteRegistry, RetentionCfg,
};
use e2train::config::{CkptCfg, DataCfg, RunCfg};
use e2train::coordinator::{RunOutcome, Trainer};
use e2train::data::synthetic;
use e2train::runtime::{
    write_reference_family, Engine, ModelState, RefFamilySpec, SnapshotCell,
    StateSnapshot, TrainProgram,
};
use e2train::serve::{ServeCfg, ServeService};
use e2train::util::fault::{self, FaultPlan, FaultSiteCfg, FaultsCfg};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

fn ref_cfg(artifacts: &Path, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, "e2train", iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg.eval_every = 8;
    cfg
}

fn with_ckpt(mut cfg: RunCfg, dir: &Path, every: u64) -> RunCfg {
    cfg.checkpoint = CkptCfg {
        every,
        dir: Some(dir.to_path_buf()),
        keep_last: 16,
        keep_every: 0,
        ..CkptCfg::default()
    };
    cfg
}

fn site(name: &str, at: u64, times: u64) -> FaultSiteCfg {
    FaultSiteCfg { site: name.into(), at, times, after_bytes: None }
}

/// Full bitwise comparison of two run outcomes (everything except wall
/// time, the machine-dependent prefetch depth, and the recovery count,
/// which is exactly what supervision is allowed to change).
fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{ctx}: acc");
    assert_eq!(
        a.metrics.final_test_acc_top5, b.metrics.final_test_acc_top5,
        "{ctx}: top5"
    );
    assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{ctx}: loss");
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{ctx}: joules");
    assert_eq!(a.metrics.executed_macs, b.metrics.executed_macs, "{ctx}: macs");
    assert_eq!(a.metrics.steps_run, b.metrics.steps_run, "{ctx}: steps");
    assert_eq!(
        a.metrics.steps_skipped, b.metrics.steps_skipped,
        "{ctx}: skipped"
    );
    assert_eq!(
        a.metrics.mean_gate_fracs, b.metrics.mean_gate_fracs,
        "{ctx}: gate means"
    );
    assert_eq!(
        a.metrics.mean_psg_frac, b.metrics.mean_psg_frac,
        "{ctx}: psg mean"
    );
    assert_eq!(a.metrics.trace.len(), b.metrics.trace.len(), "{ctx}: trace len");
    for (x, y) in a.metrics.trace.iter().zip(b.metrics.trace.iter()) {
        assert_eq!(x.iter, y.iter, "{ctx}: trace iter");
        assert_eq!(x.loss, y.loss, "{ctx}: trace loss @{}", x.iter);
        assert_eq!(x.train_acc, y.train_acc, "{ctx}: trace acc @{}", x.iter);
        assert_eq!(x.joules, y.joules, "{ctx}: trace joules @{}", x.iter);
        assert_eq!(x.test_acc, y.test_acc, "{ctx}: trace eval @{}", x.iter);
    }
    assert_eq!(
        a.ledger.steps_charged, b.ledger.steps_charged,
        "{ctx}: ledger steps"
    );
    assert_eq!(a.ledger.macs, b.ledger.macs, "{ctx}: ledger macs");
    assert_eq!(a.ledger.trace, b.ledger.trace, "{ctx}: ledger trace");
    a.state.assert_bitwise_eq(&b.state);
}

/// One execution layout of the step loop (all bitwise interchangeable).
struct Layout {
    name: &'static str,
    resident: bool,
    prefetch: bool,
    shards: usize,
    accum: usize,
}

/// `sharded2-accum2` pins shard death *mid-pipeline*: with two
/// micro-batches per step the failing fan-out sits between reducer
/// jobs, so recovery must retry only the failed micro-batch and never
/// hand the reducer a stale buffer.
const LAYOUTS: &[Layout] = &[
    Layout { name: "host", resident: false, prefetch: false, shards: 0, accum: 1 },
    Layout { name: "resident", resident: true, prefetch: true, shards: 0, accum: 1 },
    Layout { name: "sharded2", resident: true, prefetch: true, shards: 2, accum: 1 },
    Layout { name: "sharded3", resident: true, prefetch: true, shards: 3, accum: 1 },
    Layout { name: "sharded2-accum2", resident: true, prefetch: true, shards: 2, accum: 2 },
];

fn shaped(mut cfg: RunCfg, l: &Layout) -> RunCfg {
    cfg.resident = l.resident;
    cfg.prefetch = l.prefetch;
    cfg.shards = l.shards;
    cfg.accum = l.accum;
    cfg
}

/// Run `cfg` under supervision with `sites` armed; hand back the
/// outcome plus the plan so callers can assert firings.
fn supervised_with_faults(
    engine: &Engine,
    mut cfg: RunCfg,
    sites: Vec<FaultSiteCfg>,
) -> (RunOutcome, Arc<FaultPlan>) {
    cfg.faults = FaultsCfg { sites, backoff_ms: 1, ..Default::default() };
    let plan = FaultPlan::from_cfg(&cfg.faults, cfg.seed).unwrap();
    let mut trainer = Trainer::new(engine, cfg).unwrap();
    trainer.set_faults(plan.clone());
    let out = trainer.run_supervised().unwrap();
    (out, plan)
}

/// The tentpole pin: every injectable site, on every execution layout,
/// recovered to a bitwise fault-free outcome.
#[test]
fn injected_faults_recover_bitwise_on_every_layout() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    for layout in LAYOUTS {
        let base_reg = TempDir::new().unwrap();
        let base_cfg =
            shaped(with_ckpt(ref_cfg(tmp.path(), 18), base_reg.path(), 6), layout);
        let baseline = Trainer::new(&engine, base_cfg).unwrap().run(None).unwrap();

        let mut site_sets: Vec<(&str, Vec<FaultSiteCfg>)> = vec![
            // fires after the iter-6 checkpoint: exercises the
            // restore-and-replay path
            ("train-step", vec![site(fault::SITE_TRAIN_STEP, 8, 1)]),
            // fires before any checkpoint exists: restart from scratch
            ("train-step-early", vec![site(fault::SITE_TRAIN_STEP, 2, 1)]),
            // the first checkpoint write dies after 200 bytes; the
            // parked error surfaces and the run restarts
            (
                "ckpt-sink",
                vec![FaultSiteCfg {
                    site: fault::SITE_CKPT_SINK.into(),
                    at: 1,
                    times: 1,
                    after_bytes: Some(200),
                }],
            ),
            // the supervisor's own restore-point read comes back torn
            ("registry-read", vec![site(fault::SITE_REGISTRY_READ, 1, 1)]),
        ];
        if layout.prefetch {
            // the prefetch worker panics assembling its 5th batch
            site_sets.push(("prefetch", vec![site(fault::SITE_PREFETCH, 5, 1)]));
        }
        if layout.shards > 0 {
            // one shard dies mid-step AND its first replacement fork
            // fails: recovered in place, no supervisor restart at all
            site_sets.push((
                "shard-engine+fork",
                vec![
                    site(fault::SITE_SHARD_ENGINE, 2, 1),
                    site(fault::SITE_POOL_FORK, 1, 1),
                ],
            ));
        }

        for (name, sites) in site_sets {
            let reg = TempDir::new().unwrap();
            let cfg =
                shaped(with_ckpt(ref_cfg(tmp.path(), 18), reg.path(), 6), layout);
            let in_place = name.starts_with("shard-engine");
            let (out, plan) = supervised_with_faults(&engine, cfg, sites);
            assert!(
                plan.fired_total() >= 1,
                "{}/{name}: the armed fault never fired",
                layout.name
            );
            if in_place {
                // shard recovery never reaches the supervisor
                assert_eq!(
                    out.metrics.recoveries, 0,
                    "{}/{name}: in-place recovery restarted the run",
                    layout.name
                );
            } else {
                assert!(
                    out.metrics.recoveries >= 1,
                    "{}/{name}: the supervisor never recovered",
                    layout.name
                );
            }
            assert_outcomes_identical(
                &baseline,
                &out,
                &format!("{}/{name}", layout.name),
            );
        }
    }
}

/// A checkpoint from a *different* run (other seed, other fingerprint)
/// in the restore registry is a contradiction no retry fixes: the
/// supervisor must fail fast with the fingerprint error, not burn its
/// budget replaying the same rejection.
#[test]
fn foreign_checkpoint_fingerprint_is_fatal_not_retried() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    let reg = TempDir::new().unwrap();
    let cfg = with_ckpt(ref_cfg(tmp.path(), 12), reg.path(), 6);
    Trainer::new(&engine, cfg).unwrap().run(None).unwrap();

    let mut wrong = with_ckpt(ref_cfg(tmp.path(), 12), reg.path(), 6);
    wrong.seed = 1; // different training stream, same registry
    let err = Trainer::new(&engine, wrong)
        .unwrap()
        .run_supervised()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fatal"), "not classified fatal: {msg}");
    assert!(msg.contains("fingerprint"), "original cause lost: {msg}");
}

/// A fault that fires on every single attempt exhausts the retry budget
/// and surfaces the (typed) original error — bounded, never an infinite
/// recovery loop.
#[test]
fn exhausted_retry_budget_surfaces_the_injected_error() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    let reg = TempDir::new().unwrap();
    let mut cfg = with_ckpt(ref_cfg(tmp.path(), 12), reg.path(), 6);
    cfg.faults = FaultsCfg {
        sites: vec![site(fault::SITE_TRAIN_STEP, 1, 1_000_000)],
        max_retries: 2,
        backoff_ms: 1,
        seed: 0,
    };
    let t0 = Instant::now();
    let err = Trainer::new(&engine, cfg)
        .unwrap()
        .run_supervised()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("retry budget exhausted"), "{msg}");
    assert!(fault::is_injected(&err), "typed marker lost: {msg}");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "budget exhaustion took implausibly long (runaway retries?)"
    );
}

// ---------------------------------------------------------------------
// Replication fault sites
// ---------------------------------------------------------------------

/// The replica root must list exactly the local registry's entries and
/// serve back its newest checkpoint (fetches are hash+trailer verified,
/// so a successful load *is* a bitwise guarantee).
fn assert_replica_complete(replica: &Path, local: &Path) {
    let local_entries = CheckpointRegistry::new(local, RetentionCfg::default())
        .entries()
        .unwrap();
    let remote = RemoteRegistry::new(Box::new(FsRemoteStore::new(replica)));
    assert_eq!(remote.entries().unwrap(), local_entries, "replica out of sync");
    let latest = remote.load_latest().unwrap().expect("replica has checkpoints");
    assert_eq!(latest.iter, local_entries.last().unwrap().iter);
}

/// The three replication fault sites recover under supervision to the
/// bitwise fault-free (and replication-free) outcome:
///
/// * `replicate.upload` — the first staged append is truncated; the
///   parked error fails the run at drain time and the next attempt's
///   replicator **resumes from the verified staged prefix**.
/// * `replicate.manifest` — the remote manifest write tears at the
///   final path; the next attempt's replicator rebuilds it.
/// * `remote.read` — disaster resume: a box with an **empty local
///   registry** restores from the replica, riding out a transient
///   remote read on the first attempt.
#[test]
fn replication_faults_recover_bitwise() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    let base_reg = TempDir::new().unwrap();
    let baseline =
        Trainer::new(&engine, with_ckpt(ref_cfg(tmp.path(), 18), base_reg.path(), 6))
            .unwrap()
            .run(None)
            .unwrap();

    // (a) truncated upload -> resumed from the staged prefix
    {
        let reg = TempDir::new().unwrap();
        let replica = TempDir::new().unwrap();
        let mut cfg = with_ckpt(ref_cfg(tmp.path(), 18), reg.path(), 6);
        cfg.checkpoint.replicate = Some(replica.path().to_path_buf());
        let (out, plan) = supervised_with_faults(
            &engine,
            cfg,
            vec![FaultSiteCfg {
                site: fault::SITE_REPLICATE_UPLOAD.into(),
                at: 1,
                times: 1,
                after_bytes: Some(100),
            }],
        );
        assert_eq!(plan.fired(fault::SITE_REPLICATE_UPLOAD), 1);
        assert!(out.metrics.recoveries >= 1, "upload: supervisor never recovered");
        assert!(
            out.metrics.replica_retries >= 1,
            "the resumed staged upload was not counted"
        );
        assert_eq!(out.metrics.replica_lag_iters, 0, "replica left behind");
        assert_outcomes_identical(&baseline, &out, "replicate.upload");
        assert_replica_complete(replica.path(), reg.path());
    }

    // (b) torn remote manifest -> rebuilt on the next attempt
    {
        let reg = TempDir::new().unwrap();
        let replica = TempDir::new().unwrap();
        let mut cfg = with_ckpt(ref_cfg(tmp.path(), 18), reg.path(), 6);
        cfg.checkpoint.replicate = Some(replica.path().to_path_buf());
        let (out, plan) = supervised_with_faults(
            &engine,
            cfg,
            vec![site(fault::SITE_REPLICATE_MANIFEST, 1, 1)],
        );
        assert_eq!(plan.fired(fault::SITE_REPLICATE_MANIFEST), 1);
        assert!(out.metrics.recoveries >= 1, "manifest: supervisor never recovered");
        assert_eq!(out.metrics.replica_lag_iters, 0, "replica left behind");
        assert_outcomes_identical(&baseline, &out, "replicate.manifest");
        assert_replica_complete(replica.path(), reg.path());
    }

    // (c) disaster resume from the replica with no local checkpoints
    {
        // a fault-free replicated run populates the replica — and must
        // itself be invisible next to the replication-free baseline
        let reg1 = TempDir::new().unwrap();
        let replica = TempDir::new().unwrap();
        let mut seed_cfg = with_ckpt(ref_cfg(tmp.path(), 18), reg1.path(), 6);
        seed_cfg.checkpoint.replicate = Some(replica.path().to_path_buf());
        let seeded = Trainer::new(&engine, seed_cfg).unwrap().run(None).unwrap();
        assert_outcomes_identical(&baseline, &seeded, "replication invisibility");

        // the replacement box: fresh (empty) local registry, replica
        // configured; its very first replica read fails transiently
        let reg2 = TempDir::new().unwrap();
        let mut cfg = with_ckpt(ref_cfg(tmp.path(), 18), reg2.path(), 6);
        cfg.checkpoint.replica = Some(replica.path().to_path_buf());
        let (out, plan) = supervised_with_faults(
            &engine,
            cfg,
            vec![site(fault::SITE_REMOTE_READ, 1, 1)],
        );
        assert_eq!(plan.fired(fault::SITE_REMOTE_READ), 1);
        assert_eq!(out.metrics.recoveries, 1, "exactly one transient replica read");
        assert_outcomes_identical(&baseline, &out, "remote.read disaster resume");
    }
}

// ---------------------------------------------------------------------
// Serve-side resilience
// ---------------------------------------------------------------------

/// A booted service over the sgd32 fixture with one published snapshot.
fn serve_fixture(
    tmp: &TempDir,
    engine: &Engine,
    cfg: ServeCfg,
) -> (ServeService, usize) {
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let manifest = fam.join("sgd32.json");
    let prog = TrainProgram::load_eval_only(engine, &manifest).unwrap();
    let hw = prog.manifest.arch.image_size;
    let state = ModelState::init(&prog.manifest, 5);
    let cell = Arc::new(SnapshotCell::new());
    cell.publish(StateSnapshot::from_model_state(prog.backend(), &state).unwrap());
    let service = ServeService::start(engine, &manifest, cell, cfg).unwrap();
    (service, hw)
}

/// An injected worker death fails exactly the batch the worker held —
/// with an explicit error, never a hung `Ticket::wait` — and the
/// monitor's respawned worker serves the very next request.
#[test]
fn serve_worker_death_respawns_and_fails_only_the_held_batch() {
    let tmp = TempDir::new().unwrap();
    let engine = Engine::cpu().unwrap();
    let plan = FaultPlan::from_cfg(
        &FaultsCfg {
            sites: vec![site(fault::SITE_SERVE_WORKER, 2, 1)],
            ..Default::default()
        },
        0,
    )
    .unwrap();
    let (service, hw) = serve_fixture(
        &tmp,
        &engine,
        ServeCfg { workers: 1, faults: Some(plan.clone()), ..Default::default() },
    );
    let stride = hw * hw * 3;
    let data = synthetic::generate(10, 4, hw, 11);
    let client = service.client();
    let submit = |i: usize| {
        client
            .submit(&data.images[i * stride..(i + 1) * stride], &[data.labels[i]])
            .unwrap()
            .wait()
    };

    // batch 1: hit 1, below the firing hit — served normally
    let r1 = submit(0).expect("healthy worker answers");
    assert_eq!(r1.len(), 1);
    // batch 2: the worker dies holding it; the dropped routes resolve
    // the ticket with an explicit error
    let err = submit(1).expect_err("the held batch must fail, not hang");
    assert!(
        format!("{err:#}").contains("dropped the batch mid-flight"),
        "unexpected failure shape: {err:#}"
    );
    // batch 3: the respawned worker (same plan, fault spent) answers
    let r3 = submit(2).expect("respawned worker serves again");
    assert_eq!(r3.len(), 1);
    assert_eq!(plan.fired(fault::SITE_SERVE_WORKER), 1);

    let stats = service.shutdown();
    assert_eq!(stats.worker_respawns, 1, "exactly one respawn recorded");
}

/// With the respawn budget exhausted (zero here), pending and future
/// requests fail explicitly through the monitor's terminal drain —
/// clients never hang on a dead pool.
#[test]
fn exhausted_respawn_budget_fails_requests_explicitly() {
    let tmp = TempDir::new().unwrap();
    let engine = Engine::cpu().unwrap();
    let plan = FaultPlan::from_cfg(
        &FaultsCfg {
            sites: vec![site(fault::SITE_SERVE_WORKER, 1, 1)],
            ..Default::default()
        },
        0,
    )
    .unwrap();
    let (service, hw) = serve_fixture(
        &tmp,
        &engine,
        ServeCfg {
            workers: 1,
            max_respawns: 0,
            faults: Some(plan),
            ..Default::default()
        },
    );
    let stride = hw * hw * 3;
    let data = synthetic::generate(10, 4, hw, 11);
    let client = service.client();

    // the only worker dies on its first batch
    let err = client
        .submit(&data.images[..stride], &[data.labels[0]])
        .unwrap()
        .wait()
        .expect_err("the held batch fails explicitly");
    assert!(
        format!("{err:#}").contains("dropped the batch mid-flight"),
        "{err:#}"
    );
    // later requests drain through the monitor's consumer of last
    // resort with its explicit error — and must not hang either
    let err2 = client
        .submit(&data.images[stride..2 * stride], &[data.labels[1]])
        .unwrap()
        .wait()
        .expect_err("requests after pool death fail explicitly");
    assert!(
        format!("{err2:#}").contains("all serve workers stopped"),
        "{err2:#}"
    );
    let stats = service.shutdown();
    assert_eq!(stats.worker_respawns, 0);
}

/// The registry watcher rides out torn manifest reads: the armed
/// `registry.read` site fails its first two polls, the retries are
/// counted in the serve stats, and the checkpoint still hot-loads.
#[test]
fn registry_watcher_retries_torn_reads_and_counts_them() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    // a trainer (conceptually another process) leaves checkpoints
    let reg = TempDir::new().unwrap();
    let cfg = with_ckpt(ref_cfg(tmp.path(), 12), reg.path(), 6);
    Trainer::new(&engine, cfg).unwrap().run(None).unwrap();

    let plan = FaultPlan::from_cfg(
        &FaultsCfg {
            sites: vec![site(fault::SITE_REGISTRY_READ, 1, 2)],
            ..Default::default()
        },
        0,
    )
    .unwrap();
    let cell = Arc::new(SnapshotCell::new());
    let service = ServeService::start(
        &engine,
        &fam.join("e2train.json"),
        cell.clone(),
        ServeCfg { faults: Some(plan.clone()), ..Default::default() },
    )
    .unwrap();
    let _watcher = service.watch_registry(reg.path(), Duration::from_millis(5));

    let t0 = Instant::now();
    while cell.version() == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "watcher never recovered from the torn reads"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(plan.fired(fault::SITE_REGISTRY_READ), 2, "both tears injected");
    let stats = service.stats();
    assert!(
        stats.registry_retries >= 2,
        "torn reads not counted: {}",
        stats.registry_retries
    );
    service.shutdown();
}
