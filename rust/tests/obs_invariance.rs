//! The observability invariance matrix: tracing must be **provably
//! inert**.
//!
//! For every execution layout (host, resident(+prefetch), sharded
//! S ∈ {1, 2, 3}) the same config is run twice — once untraced
//! (`trace_out: None`, the `Obs::off()` hub everywhere) and once traced
//! to an `obs_trace/v1` JSONL file.  The traced run must end with
//! exactly the metrics trace, energy ledger and final model state of
//! the untraced run: telemetry lives on the observability plane and is
//! never allowed to touch the data plane.
//!
//! On top of bitwise identity, the traced run must actually *observe*:
//! the JSONL parses through `obs::report::aggregate`, and every phase
//! the layout exercises shows a nonzero total — a phase that silently
//! stopped recording is a regression even though the run still trains.

use std::path::Path;

use e2train::config::{CkptCfg, DataCfg, RunCfg};
use e2train::coordinator::{RunOutcome, Trainer};
use e2train::obs;
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

fn ref_cfg(artifacts: &Path, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, "e2train", iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg.eval_every = 8;
    cfg
}

fn with_ckpt(mut cfg: RunCfg, dir: &Path, every: u64) -> RunCfg {
    cfg.checkpoint = CkptCfg {
        every,
        dir: Some(dir.to_path_buf()),
        keep_last: 16,
        keep_every: 0,
        ..CkptCfg::default()
    };
    cfg
}

/// Full bitwise comparison of two run outcomes.  Deliberately does NOT
/// compare `metrics.obs` — the traced run carries timings the untraced
/// run doesn't have; everything the determinism contract covers must
/// still match exactly.
fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{ctx}: acc");
    assert_eq!(
        a.metrics.final_test_acc_top5, b.metrics.final_test_acc_top5,
        "{ctx}: top5"
    );
    assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{ctx}: loss");
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{ctx}: joules");
    assert_eq!(a.metrics.executed_macs, b.metrics.executed_macs, "{ctx}: macs");
    assert_eq!(a.metrics.steps_run, b.metrics.steps_run, "{ctx}: steps");
    assert_eq!(
        a.metrics.steps_skipped, b.metrics.steps_skipped,
        "{ctx}: skipped"
    );
    assert_eq!(
        a.metrics.mean_gate_fracs, b.metrics.mean_gate_fracs,
        "{ctx}: gate means"
    );
    assert_eq!(
        a.metrics.mean_psg_frac, b.metrics.mean_psg_frac,
        "{ctx}: psg mean"
    );
    assert_eq!(a.metrics.trace.len(), b.metrics.trace.len(), "{ctx}: trace len");
    for (x, y) in a.metrics.trace.iter().zip(b.metrics.trace.iter()) {
        assert_eq!(x.iter, y.iter, "{ctx}: trace iter");
        assert_eq!(x.loss, y.loss, "{ctx}: trace loss @{}", x.iter);
        assert_eq!(x.train_acc, y.train_acc, "{ctx}: trace acc @{}", x.iter);
        assert_eq!(x.joules, y.joules, "{ctx}: trace joules @{}", x.iter);
        assert_eq!(x.test_acc, y.test_acc, "{ctx}: trace eval @{}", x.iter);
    }
    assert_eq!(
        a.ledger.steps_charged, b.ledger.steps_charged,
        "{ctx}: ledger steps"
    );
    assert_eq!(a.ledger.macs, b.ledger.macs, "{ctx}: ledger macs");
    assert_eq!(a.ledger.trace, b.ledger.trace, "{ctx}: ledger trace");
    a.state.assert_bitwise_eq(&b.state);
}

/// One execution layout of the step loop (all bitwise interchangeable).
struct Layout {
    name: &'static str,
    resident: bool,
    prefetch: bool,
    shards: usize,
}

/// `sharded1` is deliberately in the matrix: a single-shard run still
/// goes through the fan-out/reduce machinery, so its shard phases must
/// record like the multi-shard legs.
const LAYOUTS: &[Layout] = &[
    Layout { name: "host", resident: false, prefetch: false, shards: 0 },
    Layout { name: "resident", resident: true, prefetch: true, shards: 0 },
    Layout { name: "sharded1", resident: true, prefetch: true, shards: 1 },
    Layout { name: "sharded2", resident: true, prefetch: true, shards: 2 },
    Layout { name: "sharded3", resident: true, prefetch: true, shards: 3 },
];

fn shaped(mut cfg: RunCfg, l: &Layout) -> RunCfg {
    cfg.resident = l.resident;
    cfg.prefetch = l.prefetch;
    cfg.shards = l.shards;
    cfg
}

/// The tentpole pin: on every layout, the traced run is bitwise
/// identical to the untraced run, AND the trace it wrote is live —
/// parseable, keyed, with every layout-relevant phase showing time.
#[test]
fn tracing_is_bitwise_inert_on_every_layout() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    for layout in LAYOUTS {
        let base_reg = TempDir::new().unwrap();
        let base_cfg =
            shaped(with_ckpt(ref_cfg(tmp.path(), 18), base_reg.path(), 6), layout);
        let baseline = Trainer::new(&engine, base_cfg).unwrap().run(None).unwrap();

        let traced_reg = TempDir::new().unwrap();
        let trace_path = traced_reg.path().join("trace.jsonl");
        let mut traced_cfg =
            shaped(with_ckpt(ref_cfg(tmp.path(), 18), traced_reg.path(), 6), layout);
        traced_cfg.trace_out = Some(trace_path.clone());
        let traced = Trainer::new(&engine, traced_cfg).unwrap().run(None).unwrap();

        assert_outcomes_identical(&baseline, &traced, layout.name);

        // The trace file round-trips through the report aggregator.
        let text = std::fs::read_to_string(&trace_path)
            .unwrap_or_else(|e| panic!("{}: reading trace: {e}", layout.name));
        let rep = e2train::obs::report::aggregate(&text)
            .unwrap_or_else(|e| panic!("{}: parsing trace: {e:#}", layout.name));
        assert!(rep.wall_ms > 0.0, "{}: wall_ms", layout.name);
        assert!(
            rep.key_line.contains(FAM),
            "{}: key line {:?} lost the family",
            layout.name,
            rep.key_line
        );
        assert!(
            rep.key_line.contains(&format!("shards={}", layout.shards)),
            "{}: key line {:?} lost the shard count",
            layout.name,
            rep.key_line
        );

        // Every phase this layout exercises recorded real time.  The
        // summary folded into RunMetrics is the same data the JSONL
        // carries, just pre-aggregated.
        let summary = traced.metrics.obs.as_ref().expect("traced run has obs summary");
        let mut want: Vec<&str> = vec![
            obs::PHASE_AUGMENT,
            obs::PHASE_STEP_EXEC,
            obs::PHASE_CKPT_ENCODE,
            obs::PHASE_REGISTRY_PUBLISH,
        ];
        if layout.prefetch {
            want.push(obs::PHASE_PREFETCH_STALL);
        }
        if layout.shards > 0 {
            // optim-apply is recorded by the sharded backend's host-side
            // gradient application; host/resident fold it into step-exec.
            // reduce-tree and pipeline-stall come from the pipelined
            // reducer, which is the sharded default (overlap on) — the
            // stall span records with a 1 ns floor so it is live even
            // when the reducer never blocks the step loop.
            want.extend([
                obs::PHASE_SHARD_EXEC,
                obs::PHASE_SHARD_REDUCE,
                obs::PHASE_REDUCE_TREE,
                obs::PHASE_OPTIM_APPLY,
                obs::PHASE_PIPELINE_STALL,
            ]);
        }
        for phase in want {
            assert!(
                summary.phase_total_ms(phase) > 0.0,
                "{}: phase {phase:?} never recorded",
                layout.name
            );
        }

        // Counter liveness, per layer the layout runs through.
        assert!(
            summary.counter(obs::CTR_CKPT_SUBMITS) >= 1,
            "{}: no checkpoint submits counted",
            layout.name
        );
        assert!(
            summary.counter(obs::CTR_CKPT_BACKPRESSURE_WAIT_NS) >= 1,
            "{}: backpressure wait never counted",
            layout.name
        );
        if layout.prefetch {
            assert!(
                summary.counter(obs::CTR_PREFETCH_PRODUCED) >= 1,
                "{}: prefetch produced nothing",
                layout.name
            );
            assert!(
                summary.counter(obs::CTR_PREFETCH_OCC_SAMPLES) >= 1,
                "{}: occupancy never sampled",
                layout.name
            );
        }
        if layout.shards > 1 {
            // With 2+ shards the slow/fast spread is nonzero every step.
            assert!(
                summary.counter(obs::CTR_SHARD_IMBALANCE_NS) >= 1,
                "{}: shard imbalance never counted",
                layout.name
            );
        }
    }
}

/// An untraced run still aggregates nothing: `metrics.obs` summarizes a
/// hub only when the trainer created one, and `Obs::off()` snapshots to
/// `None`.  (The trainer always creates a hub, so the summary is
/// present — but the *event log* only exists when a trace was asked
/// for.  This pins the cheap path: no trace file, no event buffering.)
#[test]
fn untraced_run_writes_no_trace_file() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let reg = TempDir::new().unwrap();
    let cfg = with_ckpt(ref_cfg(tmp.path(), 6), reg.path(), 3);
    let out = Trainer::new(&engine, cfg).unwrap().run(None).unwrap();
    // Summary present (the trainer aggregates for BENCH fields)…
    assert!(out.metrics.obs.is_some());
    // …but nothing landed on disk anywhere near the registry.
    let stray: Vec<_> = std::fs::read_dir(reg.path())
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().map(|x| x == "jsonl").unwrap_or(false))
        .collect();
    assert!(stray.is_empty(), "untraced run wrote {stray:?}");
}
