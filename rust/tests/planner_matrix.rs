//! The planning-layer contract, pinned end to end:
//!
//! * the `obs_catalog/v1` JSON schema, field by field (like
//!   tests/trace_schema.rs pins `obs_trace/v1`) — external tooling and
//!   `e2train catalog --merge/--ingest` hang off these exact shapes;
//! * `backend = "auto"` on an **empty** catalog runs calibration probes,
//!   bootstraps the catalog file, and still completes the run;
//! * planning is deterministic: the same catalog + config picks the
//!   same plan, twice;
//! * a planned run is **bitwise identical** to the same layout requested
//!   explicitly — for every layout the planner can choose (host,
//!   resident, sharded S ∈ {1, 2, 3}), forced by seeding the catalog;
//! * predicted-vs-actual accounting lands in `RunMetrics::plan` and the
//!   run trace's `plan` row, and the catalog is recalibrated with the
//!   run's own measurements at end of run;
//! * a corrupt catalog file fails the run cleanly instead of silently
//!   erasing every calibration.

use std::path::Path;

use e2train::config::{BackendChoice, DataCfg, RunCfg};
use e2train::coordinator::{RunOutcome, Trainer};
use e2train::obs::catalog::{Catalog, CatalogKey, Observation, CATALOG_SCHEMA};
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::json::{parse, Json};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";
/// `RefFamilySpec::tiny()` trains at batch 8 — catalog keys must match.
const BATCH: usize = 8;

/// Every layout the planner enumerates for a mask-free method, in its
/// own enumeration order.
const LAYOUTS: &[(&str, usize)] = &[
    ("host", 0),
    ("resident", 0),
    ("sharded", 1),
    ("sharded", 2),
    ("sharded", 3),
];

fn key(method: &str, backend: &str, shards: usize) -> CatalogKey {
    CatalogKey {
        family: FAM.into(),
        method: method.into(),
        backend: backend.into(),
        shards,
        batch: BATCH,
    }
}

/// A measurement batch of four identical step/augment observations —
/// histogram means stay exact, so predicted orderings are exact too.
fn measured(step_us: u64, aug_us: u64, joules: f64, steps: u64) -> Observation {
    let mut o = Observation { joules, joule_steps: steps, ..Default::default() };
    for _ in 0..4 {
        o.step_ns.observe(step_us * 1000);
        o.augment_ns.observe(aug_us * 1000);
    }
    o
}

/// A full catalog where `favorite` is strictly the fastest layout and
/// everything else is measurably slower — forcing the planner's pick.
fn catalog_favoring(method: &str, favorite: (&str, usize)) -> Catalog {
    let mut cat = Catalog::new();
    for (i, &(backend, shards)) in LAYOUTS.iter().enumerate() {
        let step_us = if (backend, shards) == favorite { 100 } else { 400 + 100 * i as u64 };
        cat.observe(key(method, backend, shards), &measured(step_us, 20, 0.8, 4));
    }
    cat
}

fn ref_cfg(artifacts: &Path, method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, method, iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg.eval_every = 8;
    cfg
}

/// The planned configuration: `backend = "auto"` with the catalog pinned
/// to a temp path so tests never touch the working directory.
fn auto_cfg(mut cfg: RunCfg, catalog: &Path) -> RunCfg {
    cfg.backend = Some(BackendChoice::Auto);
    cfg.shards = 0;
    cfg.catalog = Some(catalog.to_path_buf());
    cfg
}

/// Full bitwise comparison of two run outcomes (everything except wall
/// time, prefetch depth, and the backend attribution itself) — the same
/// contract tests/backend_matrix.rs pins across explicit layouts.
fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{ctx}: acc");
    assert_eq!(
        a.metrics.final_test_acc_top5, b.metrics.final_test_acc_top5,
        "{ctx}: top5"
    );
    assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{ctx}: loss");
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{ctx}: joules");
    assert_eq!(a.metrics.executed_macs, b.metrics.executed_macs, "{ctx}: macs");
    assert_eq!(a.metrics.steps_run, b.metrics.steps_run, "{ctx}: steps");
    assert_eq!(a.metrics.steps_skipped, b.metrics.steps_skipped, "{ctx}: skipped");
    assert_eq!(
        a.metrics.mean_gate_fracs, b.metrics.mean_gate_fracs,
        "{ctx}: gate means"
    );
    assert_eq!(a.metrics.mean_psg_frac, b.metrics.mean_psg_frac, "{ctx}: psg");
    assert_eq!(a.metrics.trace.len(), b.metrics.trace.len(), "{ctx}: trace len");
    for (x, y) in a.metrics.trace.iter().zip(b.metrics.trace.iter()) {
        assert_eq!(x.iter, y.iter, "{ctx}: trace iter");
        assert_eq!(x.loss, y.loss, "{ctx}: trace loss @{}", x.iter);
        assert_eq!(x.train_acc, y.train_acc, "{ctx}: trace acc @{}", x.iter);
        assert_eq!(x.joules, y.joules, "{ctx}: trace joules @{}", x.iter);
        assert_eq!(x.test_acc, y.test_acc, "{ctx}: trace eval @{}", x.iter);
    }
    assert_eq!(a.ledger.steps_charged, b.ledger.steps_charged, "{ctx}: ledger steps");
    assert_eq!(a.ledger.macs, b.ledger.macs, "{ctx}: ledger macs");
    assert_eq!(a.ledger.trace, b.ledger.trace, "{ctx}: ledger rows");
    a.state.assert_bitwise_eq(&b.state);
}

/// Exhaustive field-set check: missing AND extra fields both fail.
fn assert_fields(v: &Json, what: &str, want: &[&str]) {
    let obj = v.as_obj().unwrap_or_else(|| panic!("{what} not an object"));
    let mut got: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
    got.sort_unstable();
    let mut want: Vec<&str> = want.to_vec();
    want.sort_unstable();
    assert_eq!(got, want, "{what} field set drifted");
}

/// `obs_catalog/v1`, pinned field by field.  Rename or drop a field and
/// this is the tripwire that says "bump the schema string".
#[test]
fn catalog_json_matches_the_golden_shape() {
    let mut cat = Catalog::new();
    cat.observe(key("sgd32", "host", 0), &measured(200, 40, 0.8, 4));
    let mut probe = Observation { probe: true, ..Default::default() };
    probe.step_ns.observe(150_000);
    cat.observe(key("sgd32", "sharded", 2), &probe);

    let j = cat.to_json();
    assert_fields(&j, "catalog", &["schema", "entries"]);
    assert_eq!(j.at(&["schema"]).as_str(), Some(CATALOG_SCHEMA));
    assert_eq!(j.at(&["schema"]).as_str(), Some("obs_catalog/v1"));

    // Entry ids are "{family}/{method}/{backend}/s{shards}/b{batch}",
    // in BTreeMap order (deterministic file layout).
    let entries = j.at(&["entries"]).as_obj().unwrap();
    let ids: Vec<&str> = entries.keys().map(|k| k.as_str()).collect();
    assert_eq!(
        ids,
        vec!["refmlp-tiny/sgd32/host/s0/b8", "refmlp-tiny/sgd32/sharded/s2/b8"]
    );
    for (id, e) in entries {
        assert_fields(
            e,
            id,
            &[
                "family", "method", "backend", "shards", "batch", "runs", "probes",
                "step_ns", "augment_ns", "reduce_ns", "joules", "joule_steps",
            ],
        );
        assert_fields(e.at(&["step_ns"]), "step_ns", &["buckets", "total", "max"]);
        assert_fields(e.at(&["augment_ns"]), "augment_ns", &["buckets", "total", "max"]);
        assert_fields(e.at(&["reduce_ns"]), "reduce_ns", &["buckets", "total", "max"]);
    }

    let host = &entries["refmlp-tiny/sgd32/host/s0/b8"];
    assert_eq!(host.at(&["family"]).as_str(), Some(FAM));
    assert_eq!(host.at(&["backend"]).as_str(), Some("host"));
    assert_eq!(host.at(&["shards"]).as_f64(), Some(0.0));
    assert_eq!(host.at(&["batch"]).as_f64(), Some(8.0));
    assert_eq!(host.at(&["runs"]).as_f64(), Some(1.0));
    assert_eq!(host.at(&["probes"]).as_f64(), Some(0.0));
    assert_eq!(host.at(&["joules"]).as_f64(), Some(0.8));
    assert_eq!(host.at(&["joule_steps"]).as_f64(), Some(4.0));
    // Histogram totals are exact sums; 4 × 200 µs land in one bucket.
    assert_eq!(host.at(&["step_ns", "total"]).as_f64(), Some(800_000.0));
    assert_eq!(host.at(&["step_ns", "max"]).as_f64(), Some(200_000.0));
    let buckets = host.at(&["step_ns", "buckets"]).as_arr().unwrap();
    assert_eq!(buckets.len(), 1, "identical observations share a bucket");
    let pair = buckets[0].as_arr().unwrap();
    assert_eq!(pair.len(), 2, "bucket is an [index, count] pair");
    assert_eq!(pair[1].as_f64(), Some(4.0));
    // Probe provenance is kept separate from run provenance.
    let probed = &entries["refmlp-tiny/sgd32/sharded/s2/b8"];
    assert_eq!(probed.at(&["runs"]).as_f64(), Some(0.0));
    assert_eq!(probed.at(&["probes"]).as_f64(), Some(1.0));

    // The serialized text round-trips bitwise through our own parser.
    let text = j.to_string();
    let back = Catalog::from_json(&parse(&text).unwrap()).unwrap();
    assert_eq!(back.to_json().to_string(), text);
}

/// Catalogs written before the `reduce_ns` stream existed (still
/// `obs_catalog/v1`) parse leniently: the missing histogram comes back
/// empty instead of failing the load, and re-serializing emits it.
#[test]
fn pre_reduce_catalog_parses_with_an_empty_reduce_histogram() {
    let mut cat = Catalog::new();
    cat.observe(key("sgd32", "host", 0), &measured(200, 40, 0.8, 4));
    let mut j = cat.to_json();
    let Json::Obj(top) = &mut j else { panic!("catalog json not an object") };
    let Some(Json::Obj(entries)) = top.get_mut("entries") else {
        panic!("entries not an object")
    };
    for e in entries.values_mut() {
        let Json::Obj(m) = e else { panic!("entry not an object") };
        m.remove("reduce_ns");
    }
    let back = Catalog::from_json(&j).unwrap();
    let e = back.get(&key("sgd32", "host", 0)).unwrap();
    assert_eq!(e.reduce_ns.count(), 0, "missing stream reads as empty");
    assert!(e.reduce_mean_ns().is_none());
    let rej = back.to_json();
    assert!(
        rej.at(&["entries", "refmlp-tiny/sgd32/host/s0/b8", "reduce_ns"]).as_obj().is_some(),
        "re-serialization emits the field"
    );
}

/// First `auto` run ever: nothing measured, so the planner probes every
/// candidate, bootstraps the catalog file, and the run completes with
/// full predicted-vs-actual accounting.
#[test]
fn auto_on_empty_catalog_probes_and_bootstraps_the_file() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let cat_path = tmp.path().join("OBS_CATALOG.json");

    let cfg = auto_cfg(ref_cfg(tmp.path(), "sgd32", 24), &cat_path);
    let out = Trainer::new(&engine, cfg).unwrap().run(None).unwrap();

    let plan = out.metrics.plan.as_ref().expect("auto run records a plan");
    assert!(plan.probed, "empty catalog must force calibration probes");
    assert!(plan.predicted_sps > 0.0, "probe-seeded prediction");
    assert!(plan.actual_sps > 0.0, "measured throughput");
    assert!(plan.actual_j_per_step > 0.0, "measured energy");
    assert_eq!(out.metrics.backend, plan.backend, "attribution matches plan");
    assert_eq!(out.metrics.shards, plan.shards);

    // The file exists and holds a probe entry per candidate layout plus
    // the completed run folded under the chosen key.
    let cat = Catalog::load(&cat_path).expect("catalog bootstrapped");
    for &(backend, shards) in LAYOUTS {
        let e = cat.get(&key("sgd32", backend, shards)).unwrap_or_else(|| {
            panic!("no catalog entry for probed candidate {backend}/s{shards}")
        });
        assert!(e.probes >= 1, "{backend}/s{shards} probe recorded");
        assert!(e.step_mean_ns().is_some());
    }
    let chosen = cat.get(&key("sgd32", &plan.backend, plan.shards)).unwrap();
    assert_eq!(chosen.runs, 1, "completed run recalibrated the chosen entry");
    assert!(chosen.joule_steps > 0, "run folded its energy in");
}

/// Same catalog + same config ⇒ same plan, and the run itself is
/// bitwise reproducible.  Also pins end-of-run recalibration: the
/// chosen entry's run count grows by exactly one.
#[test]
fn planning_is_deterministic_for_a_given_catalog() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let seeded = catalog_favoring("sgd32", ("resident", 0));

    let mut outs = Vec::new();
    for i in 0..2 {
        // A fresh copy of the identical seeded file each time — the
        // previous run recalibrated its own copy with live timings.
        let cat_path = tmp.path().join(format!("cat-{i}.json"));
        seeded.save(&cat_path).unwrap();
        let cfg = auto_cfg(ref_cfg(tmp.path(), "sgd32", 24), &cat_path);
        outs.push(Trainer::new(&engine, cfg).unwrap().run(None).unwrap());

        let after = Catalog::load(&cat_path).unwrap();
        let chosen = after.get(&key("sgd32", "resident", 0)).unwrap();
        assert_eq!(chosen.runs, 2, "seeded run + this run");
        assert_eq!(chosen.probes, 0, "fully-seeded catalog never probes");
    }
    let (a, b) = (&outs[0], &outs[1]);
    let (pa, pb) = (a.metrics.plan.as_ref().unwrap(), b.metrics.plan.as_ref().unwrap());
    assert_eq!(pa.backend, pb.backend, "same catalog, same pick");
    assert_eq!(pa.shards, pb.shards);
    assert_eq!(pa.prefetch, pb.prefetch);
    assert_eq!(pa.prefetch_depth, pb.prefetch_depth);
    assert!(!pa.probed && !pb.probed);
    assert_eq!(pa.predicted_sps, pb.predicted_sps, "predictions are pure lookups");
    assert_eq!(pa.predicted_j_per_step, pb.predicted_j_per_step);
    assert_outcomes_identical(a, b, "planned run repeated");
}

/// The core determinism claim: for **every** layout the planner can
/// choose, `backend = "auto"` (forced onto that layout by a seeded
/// catalog) is bitwise identical to the same layout requested
/// explicitly.  Plan application is a pure layout choice.
#[test]
fn auto_is_bitwise_identical_to_the_explicit_layout() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    for &(label, shards) in LAYOUTS {
        let cat_path = tmp.path().join(format!("force-{label}-{shards}.json"));
        catalog_favoring("sgd32", (label, shards)).save(&cat_path).unwrap();

        let auto = Trainer::new(&engine, auto_cfg(ref_cfg(tmp.path(), "sgd32", 24), &cat_path))
            .unwrap()
            .run(None)
            .unwrap();
        // The seeding worked: the planner picked the layout we forced.
        assert_eq!(auto.metrics.backend, label, "forced pick");
        assert_eq!(auto.metrics.shards, shards, "forced shard count");
        let plan = auto.metrics.plan.as_ref().unwrap();
        assert!(!plan.probed, "fully-seeded catalog plans without probing");
        assert!(plan.prefetch, "measured augment cost keeps the pipeline on");
        assert!(plan.prefetch_depth.is_some(), "planned depth is pinned");
        assert!(plan.predicted_j_per_step > 0.0, "seeded energy predicts J/step");

        let mut explicit_cfg = ref_cfg(tmp.path(), "sgd32", 24);
        explicit_cfg.backend = Some(match label {
            "host" => BackendChoice::Host,
            "resident" => BackendChoice::Resident,
            _ => BackendChoice::Sharded,
        });
        explicit_cfg.shards = shards;
        let explicit = Trainer::new(&engine, explicit_cfg).unwrap().run(None).unwrap();
        assert!(explicit.metrics.plan.is_none(), "explicit runs carry no plan");
        assert_outcomes_identical(&auto, &explicit, &format!("auto vs {label}/S{shards}"));
    }
}

/// A traced planned run carries the `plan` row — right after `meta`,
/// with the exact `PlanRecord` field set, agreeing with
/// `RunMetrics::plan`.
#[test]
fn run_trace_carries_the_plan_row() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let cat_path = tmp.path().join("cat.json");
    catalog_favoring("sgd32", ("resident", 0)).save(&cat_path).unwrap();

    let trace_path = tmp.path().join("trace.jsonl");
    let mut cfg = auto_cfg(ref_cfg(tmp.path(), "sgd32", 24), &cat_path);
    cfg.trace_out = Some(trace_path.clone());
    let out = Trainer::new(&engine, cfg).unwrap().run(None).unwrap();

    let text = std::fs::read_to_string(&trace_path).unwrap();
    let rows: Vec<Json> = text.lines().map(|l| parse(l).unwrap()).collect();
    assert_eq!(rows[0].at(&["kind"]).as_str(), Some("meta"));
    let row = &rows[1];
    assert_eq!(row.at(&["kind"]).as_str(), Some("plan"), "plan row follows meta");
    assert_fields(
        row,
        "plan",
        &[
            "kind", "backend", "shards", "prefetch", "prefetch_depth", "probed",
            "predicted_sps", "predicted_j_per_step", "actual_sps", "actual_j_per_step",
            "sps_rel_err", "j_rel_err",
        ],
    );
    assert_eq!(
        rows.iter().filter(|r| r.at(&["kind"]).as_str() == Some("plan")).count(),
        1,
        "exactly one plan row"
    );

    // The trace row and the metrics record are the same accounting.
    let plan = out.metrics.plan.as_ref().unwrap();
    assert_eq!(row.at(&["backend"]).as_str(), Some(plan.backend.as_str()));
    assert_eq!(row.at(&["shards"]).as_f64(), Some(plan.shards as f64));
    assert_eq!(row.at(&["predicted_sps"]).as_f64(), Some(plan.predicted_sps));
    assert_eq!(row.at(&["actual_sps"]).as_f64(), Some(plan.actual_sps));
    assert_eq!(row.at(&["sps_rel_err"]).as_f64(), Some(plan.sps_rel_err));
    assert_eq!(row.at(&["j_rel_err"]).as_f64(), Some(plan.j_rel_err));
    // Actuals were really measured, and the relative errors tie the
    // prediction to them: err = (pred - act) / act.
    assert!(plan.actual_sps > 0.0);
    let want = (plan.predicted_sps - plan.actual_sps) / plan.actual_sps;
    assert!((plan.sps_rel_err - want).abs() < 1e-12);
}

/// A corrupt catalog is a hard, clean error — never a silent reset that
/// would erase every calibration.
#[test]
fn corrupt_catalog_fails_the_run_cleanly() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let cat_path = tmp.path().join("cat.json");
    std::fs::write(&cat_path, "{definitely not a catalog").unwrap();

    let cfg = auto_cfg(ref_cfg(tmp.path(), "sgd32", 8), &cat_path);
    let err = match Trainer::new(&engine, cfg).unwrap().run(None) {
        Ok(_) => panic!("corrupt catalog must fail the run"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("catalog"), "error names the catalog: {msg}");
    // The corrupt file is left untouched for diagnosis.
    assert_eq!(
        std::fs::read_to_string(&cat_path).unwrap(),
        "{definitely not a catalog"
    );
}
