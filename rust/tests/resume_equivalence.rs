//! The checkpoint subsystem's contract (reference backend, runs
//! everywhere):
//!
//! 1. **Bitwise resume** — a run interrupted at any checkpoint boundary
//!    and resumed produces exactly the metrics trace, energy ledger and
//!    final model state of the run that never stopped, across the
//!    resident(+prefetch), host+sync, SMD-dropping, streaming-CIFAR
//!    deferred-decode, and sharded (S ∈ {1,2,3}) execution paths —
//!    including resuming under a *different* layout than the one that
//!    checkpointed (the layouts are bitwise interchangeable).
//! 2. **Cross-process serving** — a `ServeService` with no in-process
//!    trainer answers from a registry-loaded checkpoint via the watcher,
//!    reporting the hot-loaded `snapshot_version`.
//! 3. **Corruption safety** — truncated or bit-flipped checkpoint files
//!    and mismatched configs are rejected with clean errors, never a
//!    panic.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use e2train::checkpoint::{read_checkpoint, CheckpointRegistry, RetentionCfg};
use e2train::config::{CkptCfg, DataCfg, RunCfg};
use e2train::coordinator::{RunOutcome, Trainer};
use e2train::runtime::{
    write_reference_family, Engine, RefFamilySpec, SnapshotCell, StateSnapshot,
    TrainProgram,
};
use e2train::serve::{ServeCfg, ServeService};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

fn ref_cfg(artifacts: &Path, method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, method, iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg.eval_every = 8;
    cfg
}

fn with_ckpt(mut cfg: RunCfg, dir: &Path, every: u64) -> RunCfg {
    cfg.checkpoint = CkptCfg {
        every,
        dir: Some(dir.to_path_buf()),
        keep_last: 16, // keep everything: the test resumes old boundaries
        keep_every: 0,
        ..CkptCfg::default()
    };
    cfg
}

/// Full bitwise comparison of two run outcomes (everything except wall
/// time and the machine-dependent prefetch depth).
fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{ctx}: acc");
    assert_eq!(
        a.metrics.final_test_acc_top5, b.metrics.final_test_acc_top5,
        "{ctx}: top5"
    );
    assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{ctx}: loss");
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{ctx}: joules");
    assert_eq!(a.metrics.executed_macs, b.metrics.executed_macs, "{ctx}: macs");
    assert_eq!(a.metrics.steps_run, b.metrics.steps_run, "{ctx}: steps");
    assert_eq!(
        a.metrics.steps_skipped, b.metrics.steps_skipped,
        "{ctx}: skipped"
    );
    assert_eq!(
        a.metrics.mean_gate_fracs, b.metrics.mean_gate_fracs,
        "{ctx}: gate means"
    );
    assert_eq!(
        a.metrics.mean_psg_frac, b.metrics.mean_psg_frac,
        "{ctx}: psg mean"
    );
    assert_eq!(a.metrics.trace.len(), b.metrics.trace.len(), "{ctx}: trace len");
    for (x, y) in a.metrics.trace.iter().zip(b.metrics.trace.iter()) {
        assert_eq!(x.iter, y.iter, "{ctx}: trace iter");
        assert_eq!(x.loss, y.loss, "{ctx}: trace loss @{}", x.iter);
        assert_eq!(x.train_acc, y.train_acc, "{ctx}: trace acc @{}", x.iter);
        assert_eq!(x.joules, y.joules, "{ctx}: trace joules @{}", x.iter);
        assert_eq!(x.test_acc, y.test_acc, "{ctx}: trace eval @{}", x.iter);
    }
    assert_eq!(
        a.ledger.steps_charged, b.ledger.steps_charged,
        "{ctx}: ledger steps"
    );
    assert_eq!(a.ledger.macs, b.ledger.macs, "{ctx}: ledger macs");
    assert_eq!(a.ledger.trace, b.ledger.trace, "{ctx}: ledger trace");
    a.state.assert_bitwise_eq(&b.state);
}

/// Interrupt-at-k + resume == never stopped, for every boundary the
/// registry holds.  `make_resume_cfg` lets callers resume under a
/// different execution layout.
fn check_resume_boundaries(
    engine: &Engine,
    full: &RunOutcome,
    registry_dir: &Path,
    make_resume_cfg: impl Fn() -> RunCfg,
    ctx: &str,
) {
    let registry = CheckpointRegistry::new(registry_dir, RetentionCfg::default());
    let entries = registry.entries().unwrap();
    assert!(
        entries.len() >= 3,
        "{ctx}: expected several checkpoint boundaries, found {}",
        entries.len()
    );
    for entry in &entries {
        let ckpt = registry.load(entry).unwrap();
        let mut resumed = Trainer::new(engine, make_resume_cfg()).unwrap();
        let out = resumed.resume(ckpt).unwrap();
        assert_outcomes_identical(full, &out, &format!("{ctx} @iter {}", entry.iter));
    }
}

/// Resident(+prefetch, the default) and host+sync paths, sgd32 and
/// e2train (the latter exercises SMD drops, SWA snapshots and PSG
/// telemetry through the checkpoint).
#[test]
fn resume_is_bitwise_identical_on_single_device_paths() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    for method in ["sgd32", "e2train"] {
        for (resident, prefetch) in [(true, true), (false, false)] {
            let reg = TempDir::new().unwrap();
            let shape = |mut c: RunCfg| {
                c.resident = resident;
                c.prefetch = prefetch;
                c
            };
            let full_cfg =
                shape(with_ckpt(ref_cfg(tmp.path(), method, 24), reg.path(), 6));
            let full = Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();
            // boundaries 6, 12, 18 + the final 24
            check_resume_boundaries(
                &engine,
                &full,
                reg.path(),
                || shape(ref_cfg(tmp.path(), method, 24)),
                &format!("{method} resident={resident}"),
            );
        }
    }
}

/// Sharded path: checkpoints come off the host-side master (replicas
/// never drain); resume rebuilds + rebroadcasts replicas from the
/// restored master for S ∈ {1, 2, 3}.  Also pins the cross-layout
/// contract both ways: a resident checkpoint resumes sharded, a sharded
/// checkpoint resumes resident — both bitwise equal to the
/// uninterrupted run.
#[test]
fn resume_is_bitwise_identical_on_sharded_paths() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    for shards in [1usize, 2, 3] {
        let reg = TempDir::new().unwrap();
        let mut full_cfg = with_ckpt(ref_cfg(tmp.path(), "e2train", 18), reg.path(), 6);
        full_cfg.shards = shards;
        let full = Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();
        check_resume_boundaries(
            &engine,
            &full,
            reg.path(),
            || {
                let mut c = ref_cfg(tmp.path(), "e2train", 18);
                c.shards = shards;
                c
            },
            &format!("sharded S={shards}"),
        );
    }

    // Cross-layout: one resident run's registry, resumed sharded (and a
    // sharded registry resumed resident) — the execution layout is not
    // part of the determinism contract.
    let reg = TempDir::new().unwrap();
    let full_cfg = with_ckpt(ref_cfg(tmp.path(), "e2train", 18), reg.path(), 6);
    let full = Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();
    check_resume_boundaries(
        &engine,
        &full,
        reg.path(),
        || {
            let mut c = ref_cfg(tmp.path(), "e2train", 18);
            c.shards = 2;
            c
        },
        "resident ckpt -> sharded resume",
    );
    let reg2 = TempDir::new().unwrap();
    let mut sharded_cfg = with_ckpt(ref_cfg(tmp.path(), "e2train", 18), reg2.path(), 6);
    sharded_cfg.shards = 3;
    let sharded_full =
        Trainer::new(&engine, sharded_cfg).unwrap().run(None).unwrap();
    check_resume_boundaries(
        &engine,
        &sharded_full,
        reg2.path(),
        || ref_cfg(tmp.path(), "e2train", 18),
        "sharded ckpt -> resident resume",
    );
    // and the two uninterrupted runs agree with each other
    assert_outcomes_identical(&full, &sharded_full, "resident vs sharded full runs");
}

// ---------------------------------------------------------------------
// Streaming CIFAR-bin ingestion (deferred decode on the prefetch worker)
// ---------------------------------------------------------------------

const REC: usize = 1 + 3072;

/// Deterministic pseudo-CIFAR binaries (same generator as
/// tests/cifar_stream.rs): 5 train files + 1 test file.
fn write_cifar_dir(dir: &Path, per_file: usize, test_records: usize) {
    let mut state = 0x1234_5678u32;
    let mut next = move || -> u8 {
        state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
        (state >> 24) as u8
    };
    let mut file = |n: usize| -> Vec<u8> {
        let mut bytes = Vec::with_capacity(n * REC);
        for _ in 0..n {
            bytes.push(next() % 10);
            for _ in 0..3072 {
                bytes.push(next());
            }
        }
        bytes
    };
    for i in 1..=5 {
        std::fs::write(dir.join(format!("data_batch_{i}.bin")), file(per_file)).unwrap();
    }
    std::fs::write(dir.join("test_batch.bin"), file(test_records)).unwrap();
}

/// A 32px/10-class reference family so CIFAR binaries are loadable.
fn cifar_family() -> RefFamilySpec {
    RefFamilySpec {
        family: "refmlp-c32".into(),
        hw: 32,
        hidden: 8,
        classes: 10,
        batch: 8,
        eval_batch: 16,
        gated_blocks: 4,
    }
}

#[test]
fn resume_is_bitwise_identical_on_deferred_cifar_path() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &cifar_family()).unwrap();
    let data_dir = TempDir::new().unwrap();
    write_cifar_dir(data_dir.path(), 16, 16); // 80 train / 16 test records
    let engine = Engine::cpu().unwrap();

    let cfg = |ckpt: Option<&Path>| {
        let mut c = RunCfg::quick("refmlp-c32", "e2train", 12);
        c.artifacts_dir = tmp.path().to_path_buf();
        c.data = DataCfg::CifarBin { dir: data_dir.path().to_path_buf() };
        c.eval_every = 4;
        assert!(c.prefetch, "deferred decode needs the prefetch default");
        if let Some(d) = ckpt {
            c = with_ckpt(c, d, 4);
        }
        c
    };
    let reg = TempDir::new().unwrap();
    let full = Trainer::new(&engine, cfg(Some(reg.path())))
        .unwrap()
        .run(None)
        .unwrap();
    check_resume_boundaries(
        &engine,
        &full,
        reg.path(),
        || cfg(None),
        "deferred CIFAR",
    );
}

// ---------------------------------------------------------------------
// Cross-process serving from a registry
// ---------------------------------------------------------------------

/// A serve service with **no in-process trainer** answers from a
/// registry-loaded checkpoint, reporting the hot-loaded
/// `snapshot_version`, with logits bitwise equal to a direct eval of
/// the checkpoint's serving state (the SWA average here — e2train runs
/// average past the midpoint).
#[test]
fn serve_answers_from_registry_checkpoint_without_trainer() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    // A trainer (conceptually: another process) leaves checkpoints in a
    // registry.  No SnapshotCell is shared with it.
    let reg = TempDir::new().unwrap();
    let full_cfg = with_ckpt(ref_cfg(tmp.path(), "e2train", 16), reg.path(), 8);
    Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();

    let registry = CheckpointRegistry::new(reg.path(), RetentionCfg::default());
    let ckpt = registry.load_latest().unwrap().expect("checkpoints were written");
    assert!(ckpt.swa_model.is_some(), "e2train past midpoint has SWA state");

    // Server process: empty cell + registry watcher.
    let manifest = fam.join("e2train.json");
    let cell = Arc::new(SnapshotCell::new());
    let service = ServeService::start(
        &engine,
        &manifest,
        cell.clone(),
        ServeCfg { workers: 2, ..Default::default() },
    )
    .unwrap();
    let _watcher = service.watch_registry(reg.path(), Duration::from_millis(10));
    let t0 = Instant::now();
    while cell.version() == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "watcher never hot-loaded the checkpoint"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let served_version = cell.version();
    assert!(served_version >= 1);

    // Ground truth: direct snapshot eval of the checkpoint's serving
    // state (SWA preferred), through the same padded-batch shape.
    let prog = TrainProgram::load_eval_only(&engine, &manifest).unwrap();
    let snap =
        StateSnapshot::from_model_state(prog.backend(), ckpt.serving_state()).unwrap();
    let hw = prog.manifest.arch.image_size;
    let classes = prog.manifest.arch.num_classes;
    let stride = hw * hw * 3;
    let data = e2train::data::synthetic::generate(classes, 24, hw, 99);

    let client = service.client();
    for i in 0..data.n {
        let px = &data.images[i * stride..(i + 1) * stride];
        let label = data.labels[i];
        let got = client.submit(px, &[label]).unwrap().wait().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].snapshot_version, served_version, "sample {i}");

        let eb = prog.eval_batch();
        let mut bx = vec![0f32; eb * stride];
        bx[..stride].copy_from_slice(px);
        let mut by = vec![-1i32; eb];
        by[0] = label;
        let out = prog
            .eval_batch_snapshot(
                &snap,
                &e2train::runtime::HostTensor::f32(vec![eb, hw, hw, 3], bx),
                &e2train::runtime::HostTensor::i32(vec![eb], by),
            )
            .unwrap();
        let logits = out.logits.unwrap();
        let want = &logits.as_f32().unwrap()[..classes];
        let got_bits: Vec<u32> = got[0].logits.iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "sample {i}: served logits drifted");
    }
    service.shutdown();
}

/// A registry holding checkpoints for a *different* artifact must never
/// poison the snapshot cell: the watcher refuses the layout mismatch
/// and the service keeps waiting (version stays 0) instead of workers
/// failing on every batch.
#[test]
fn watcher_refuses_checkpoints_from_a_different_artifact() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    // e2train checkpoints (extra gate.* tensors) ...
    let reg = TempDir::new().unwrap();
    let full_cfg = with_ckpt(ref_cfg(tmp.path(), "e2train", 12), reg.path(), 6);
    Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();

    // ... served through the sgd32 artifact: never hot-loaded.
    let cell = Arc::new(SnapshotCell::new());
    let service = ServeService::start(
        &engine,
        &fam.join("sgd32.json"),
        cell.clone(),
        ServeCfg::default(),
    )
    .unwrap();
    let _watcher = service.watch_registry(reg.path(), Duration::from_millis(5));
    std::thread::sleep(Duration::from_millis(120));
    assert_eq!(
        cell.version(),
        0,
        "a mismatched checkpoint must be refused, not published"
    );
    service.shutdown();
}

// ---------------------------------------------------------------------
// Corruption + misconfiguration safety
// ---------------------------------------------------------------------

#[test]
fn corrupt_checkpoints_and_wrong_configs_are_rejected_cleanly() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let reg = TempDir::new().unwrap();
    let full_cfg = with_ckpt(ref_cfg(tmp.path(), "e2train", 12), reg.path(), 6);
    Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();

    let registry = CheckpointRegistry::new(reg.path(), RetentionCfg::default());
    let entry = registry.latest().unwrap().unwrap();
    let path = reg.path().join(&entry.file);
    let good = std::fs::read(&path).unwrap();

    // Truncation at several depths: clean errors, never a panic.
    for cut in [0, 10, good.len() / 3, good.len() - 1] {
        std::fs::write(&path, &good[..cut]).unwrap();
        assert!(read_checkpoint(&path).is_err(), "cut {cut} accepted");
        assert!(registry.load(&entry).is_err(), "cut {cut} passed the registry");
    }
    // A flipped byte fails the content hash.
    let mut bad = good.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x01;
    std::fs::write(&path, &bad).unwrap();
    let msg = format!("{:#}", read_checkpoint(&path).unwrap_err());
    assert!(msg.contains("hash"), "unexpected error: {msg}");

    // Restore the good bytes; resume under a drifted config must fail
    // with the fingerprint message, not run.
    std::fs::write(&path, &good).unwrap();
    let ckpt = registry.load_latest().unwrap().unwrap();
    let mut wrong = ref_cfg(tmp.path(), "e2train", 12);
    wrong.seed = 1;
    let err = Trainer::new(&engine, wrong)
        .unwrap()
        .resume(ckpt)
        .unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"));

    // A checkpoint past the configured horizon is rejected too.
    let ckpt = registry.load_latest().unwrap().unwrap();
    let mut short = ref_cfg(tmp.path(), "e2train", 12);
    short.iters = ckpt.iter - 1;
    let err = Trainer::new(&engine, short)
        .unwrap()
        .resume(ckpt)
        .unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint") || format!("{err:#}").contains("iter"));
}

/// Resuming the *final* checkpoint runs zero iterations and re-derives
/// the uninterrupted outcome — useful for re-evaluating a finished run.
#[test]
fn resuming_the_final_checkpoint_rederives_the_outcome() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let reg = TempDir::new().unwrap();
    let full_cfg = with_ckpt(ref_cfg(tmp.path(), "sgd32", 12), reg.path(), 5);
    let full = Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();

    let registry = CheckpointRegistry::new(reg.path(), RetentionCfg::default());
    let last = registry.latest().unwrap().unwrap();
    assert_eq!(last.iter, 12, "final boundary checkpoint exists");
    let ckpt = registry.load(&last).unwrap();
    let out = Trainer::new(&engine, ref_cfg(tmp.path(), "sgd32", 12))
        .unwrap()
        .resume(ckpt)
        .unwrap();
    assert_outcomes_identical(&full, &out, "final-checkpoint resume");
}
