//! Serve determinism and tail correctness (reference backend, runs
//! everywhere).
//!
//! The contract under test: N concurrent clients submitting a fixed
//! sample set through the micro-batching service receive **bitwise**
//! the per-sample logits/losses a serial `evaluate_full`-style pass
//! computes over the same published state — regardless of how the
//! batcher happened to coalesce requests (including a final partial
//! micro-batch padded with label -1), how many workers raced, or
//! whether a new checkpoint was published mid-flight.

use std::sync::Arc;
use std::time::Duration;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::data::{synthetic, Dataset};
use e2train::runtime::{
    write_reference_family, Engine, HostTensor, ModelState, RefFamilySpec,
    SnapshotCell, StateSnapshot, TrainProgram,
};
use e2train::serve::{SampleResult, ServeCfg, ServeService};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

/// Per-sample (logits, loss) ground truth, computed serially in dataset
/// order through the same padded batching `evaluate_full` uses.
fn serial_rows(
    prog: &TrainProgram,
    snap: &StateSnapshot,
    data: &Dataset,
) -> Vec<Vec<f32>> {
    let eb = prog.eval_batch();
    let hw = data.hw;
    let stride = hw * hw * 3;
    let classes = prog.manifest.arch.num_classes;
    let mut rows = Vec::with_capacity(data.n);
    let nb = (data.n + eb - 1) / eb;
    for b in 0..nb {
        let lo = b * eb;
        let take = eb.min(data.n - lo);
        let mut px = vec![0f32; eb * stride];
        px[..take * stride]
            .copy_from_slice(&data.images[lo * stride..(lo + take) * stride]);
        let mut py = vec![-1i32; eb];
        py[..take].copy_from_slice(&data.labels[lo..lo + take]);
        let out = prog
            .eval_batch_snapshot(
                snap,
                &HostTensor::f32(vec![eb, hw, hw, 3], px),
                &HostTensor::i32(vec![eb], py),
            )
            .unwrap();
        let logits = out.logits.expect("reference eval emits logits");
        let lv = logits.as_f32().unwrap();
        for i in 0..take {
            rows.push(lv[i * classes..(i + 1) * classes].to_vec());
        }
    }
    rows
}

/// Drive `clients` concurrent client threads over a disjoint partition
/// of `data` (mixed request sizes 1..=3) and return results keyed by
/// global sample index.
fn concurrent_serve(
    service: &ServeService,
    data: &Dataset,
    clients: usize,
) -> Vec<(usize, SampleResult)> {
    let stride = data.hw * data.hw * 3;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let client = service.client();
            handles.push(scope.spawn(move || {
                let mine: Vec<usize> =
                    (0..data.n).filter(|i| i % clients == c).collect();
                let mut got: Vec<(usize, SampleResult)> = Vec::new();
                let mut cursor = 0usize;
                let mut req_no = 0usize;
                while cursor < mine.len() {
                    let k = (1 + (c + req_no) % 3).min(mine.len() - cursor);
                    let idxs = &mine[cursor..cursor + k];
                    let mut px = Vec::with_capacity(k * stride);
                    let mut py = Vec::with_capacity(k);
                    for &idx in idxs {
                        px.extend_from_slice(
                            &data.images[idx * stride..(idx + 1) * stride],
                        );
                        py.push(data.labels[idx]);
                    }
                    let results = client.submit(&px, &py).unwrap().wait().unwrap();
                    assert_eq!(results.len(), k);
                    for (j, r) in results.into_iter().enumerate() {
                        got.push((idxs[j], r));
                    }
                    cursor += k;
                    req_no += 1;
                }
                got
            }));
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        all
    })
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn concurrent_clients_match_serial_evaluate_full() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let manifest = fam.join("sgd32.json");
    let prog = TrainProgram::load(&engine, &manifest).unwrap();
    let eb = prog.eval_batch();
    // 2 full micro-batches + a 7-sample tail.
    let n = 2 * eb + 7;
    let data = synthetic::generate(10, n, prog.manifest.arch.image_size, 3);

    let state = ModelState::init(&prog.manifest, 5);
    let cell = Arc::new(SnapshotCell::new());
    cell.publish(
        StateSnapshot::from_model_state(prog.backend(), &state).unwrap(),
    );
    let snap = cell.load().unwrap();
    let serial = serial_rows(&prog, &snap, &data);

    let service = ServeService::start(
        &engine,
        &manifest,
        cell.clone(),
        ServeCfg {
            workers: 3,
            queue_cap: 16,
            max_delay: Duration::from_millis(1),
            micro_batch: None,
            ..Default::default()
        },
    )
    .unwrap();
    let results = concurrent_serve(&service, &data, 4);
    let stats = service.shutdown();

    assert_eq!(results.len(), n, "every sample answered exactly once");
    assert_eq!(stats.samples, n);
    assert!(stats.batches > 0);

    let classes = prog.manifest.arch.num_classes;
    let mut serve_correct = 0u64;
    for (idx, r) in &results {
        let expect = &serial[*idx];
        assert_eq!(
            bits(&r.logits),
            bits(expect),
            "sample {idx}: logits differ from the serial pass"
        );
        assert_eq!(r.label, data.labels[*idx]);
        assert_eq!(r.snapshot_version, 1);
        // pred/correct/loss must be the row-rule values of those logits.
        let y = r.label as usize;
        assert!(y < classes);
        assert_eq!(
            r.pred as usize,
            e2train::runtime::row_argmax(expect),
            "sample {idx}"
        );
        assert_eq!(r.correct, e2train::runtime::row_rank(expect, y) == 0);
        assert_eq!(
            r.loss.to_bits(),
            e2train::runtime::row_softmax_loss(expect, y).to_bits(),
            "sample {idx}: loss differs bitwise"
        );
        if r.correct {
            serve_correct += 1;
        }
    }

    // Aggregate accuracy must equal a serial evaluate_full exactly
    // (both are integer correct-counts over the same n).
    let mut cfg = RunCfg::quick(FAM, "sgd32", 4);
    cfg.artifacts_dir = tmp.path().to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 64, n_test: 16, seed: 0 };
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    trainer.set_data(synthetic::generate(10, 64, 8, 0), data.clone());
    let (acc, _, loss) = trainer.evaluate_full(&state).unwrap();
    assert_eq!(serve_correct as f64 / n as f64, acc, "accuracy drifted");
    // Loss sums in different (batch-composition) orders: equal to float
    // tolerance, not bitwise.
    let serve_loss: f64 =
        results.iter().map(|(_, r)| r.loss as f64).sum::<f64>() / n as f64;
    assert!(
        (serve_loss - loss).abs() < 1e-4,
        "serve mean loss {serve_loss} vs serial {loss}"
    );
}

#[test]
fn midflight_snapshot_swap_serves_new_checkpoint_without_draining() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let manifest = fam.join("sgd32.json");
    let prog = TrainProgram::load(&engine, &manifest).unwrap();
    let data = synthetic::generate(10, prog.eval_batch() + 3, 8, 9);

    let state_a = ModelState::init(&prog.manifest, 1);
    let state_b = ModelState::init(&prog.manifest, 2);
    let cell = Arc::new(SnapshotCell::new());
    cell.publish(
        StateSnapshot::from_model_state(prog.backend(), &state_a).unwrap(),
    );
    let serial_a = serial_rows(&prog, &cell.load().unwrap(), &data);

    let service = ServeService::start(
        &engine,
        &manifest,
        cell.clone(),
        ServeCfg { workers: 2, ..Default::default() },
    )
    .unwrap();

    let got_a = concurrent_serve(&service, &data, 2);
    for (idx, r) in &got_a {
        assert_eq!(r.snapshot_version, 1);
        assert_eq!(bits(&r.logits), bits(&serial_a[*idx]));
    }

    // Publish checkpoint B mid-flight: no drain, next requests see v2.
    cell.publish(
        StateSnapshot::from_model_state(prog.backend(), &state_b).unwrap(),
    );
    let serial_b = serial_rows(&prog, &cell.load().unwrap(), &data);
    let got_b = concurrent_serve(&service, &data, 2);
    for (idx, r) in &got_b {
        assert_eq!(r.snapshot_version, 2);
        assert_eq!(
            bits(&r.logits),
            bits(&serial_b[*idx]),
            "sample {idx} not served from the swapped checkpoint"
        );
    }
    service.shutdown();
}

/// The coordinator-side hookup: a training run attached via
/// `set_publisher` publishes checkpoints the service answers from, and
/// the final published state is exactly the run's outcome state.
#[test]
fn trainer_publishes_checkpoints_into_the_service() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let manifest = fam.join("e2train.json");

    let cell = Arc::new(SnapshotCell::new());
    let mut cfg = RunCfg::quick(FAM, "e2train", 20);
    cfg.artifacts_dir = tmp.path().to_path_buf();
    cfg.smd.enabled = false; // every SWA window executes -> publishes
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 96, n_test: 32, seed: 0 };
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    trainer.set_publisher(cell.clone());
    let outcome = trainer.run(None).unwrap();

    // e2train runs SWA: at least one mid-run publish + the final one.
    assert!(cell.version() >= 2, "expected SWA + final publishes");

    let prog = TrainProgram::load(&engine, &manifest).unwrap();
    let data = synthetic::generate(10, prog.eval_batch() + 5, 8, 4);
    let serial =
        serial_rows(&prog, &cell.load().unwrap(), &data);
    // The final published snapshot is the outcome state, bit for bit.
    let from_outcome = serial_rows(
        &prog,
        &StateSnapshot::from_model_state(prog.backend(), &outcome.state).unwrap(),
        &data,
    );
    for (a, b) in serial.iter().zip(from_outcome.iter()) {
        assert_eq!(bits(a), bits(b), "published state != outcome state");
    }

    let service = ServeService::start(
        &engine,
        &manifest,
        cell.clone(),
        ServeCfg::default(),
    )
    .unwrap();
    let got = concurrent_serve(&service, &data, 3);
    let latest = cell.version();
    for (idx, r) in &got {
        assert_eq!(r.snapshot_version, latest);
        assert_eq!(bits(&r.logits), bits(&serial[*idx]));
    }
    service.shutdown();
}

/// Misuse is an error, not a hang: serving before any publish fails the
/// ticket, and submitting after shutdown fails the submit.
#[test]
fn unpublished_state_and_closed_service_fail_fast() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let manifest = fam.join("sgd32.json");
    let cell = Arc::new(SnapshotCell::new()); // nothing published
    let service = ServeService::start(
        &engine,
        &manifest,
        cell,
        ServeCfg::default(),
    )
    .unwrap();
    let client = service.client();
    let stride = client.sample_stride();
    let ticket = client.submit(&vec![0.0; stride], &[1]).unwrap();
    assert!(
        ticket.wait().is_err(),
        "no snapshot published: the ticket must fail, not hang"
    );
    // Shape validation happens at submit time.
    assert!(client.submit(&vec![0.0; stride - 1], &[1]).is_err());
    assert!(client.submit(&[], &[]).is_err());
    assert!(client.submit(&vec![0.0; stride], &[10]).is_err());

    service.shutdown();
    assert!(
        client.submit(&vec![0.0; stride], &[1]).is_err(),
        "submits after shutdown must fail"
    );
}

/// Admission control: a request whose client deadline already expired
/// before dispatch completes with an explicit `expired` error (never a
/// hang, never an eval slot), is counted in `ServeStats::expired`, and
/// live requests around it are unaffected.
#[test]
fn expired_requests_fail_fast_with_expired_error() {
    use std::time::Instant;

    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let manifest = fam.join("sgd32.json");
    let prog = TrainProgram::load(&engine, &manifest).unwrap();
    let data = synthetic::generate(10, 8, 8, 4);
    let stride = 8 * 8 * 3;

    let cell = Arc::new(SnapshotCell::new());
    cell.publish(
        StateSnapshot::from_model_state(
            prog.backend(),
            &ModelState::init(&prog.manifest, 0),
        )
        .unwrap(),
    );
    let service = ServeService::start(
        &engine,
        &manifest,
        cell,
        ServeCfg { workers: 1, ..Default::default() },
    )
    .unwrap();
    let client = service.client();

    // Already-expired two-sample request: fails with the explicit
    // expired error.
    let err = client
        .submit_with_deadline(
            &data.images[..2 * stride],
            &data.labels[..2],
            Some(Instant::now() - Duration::from_millis(1)),
        )
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("expired"),
        "wrong failure: {err:#}"
    );

    // A generous deadline and a no-deadline request still serve fine.
    let ok = client
        .submit_with_deadline(
            &data.images[..stride],
            &data.labels[..1],
            Some(Instant::now() + Duration::from_secs(30)),
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(ok.len(), 1);
    let ok = client.submit(&data.images[..stride], &data.labels[..1]).unwrap();
    assert_eq!(ok.wait().unwrap().len(), 1);

    let stats = service.shutdown();
    assert_eq!(stats.expired, 2, "both expired samples counted");
    assert_eq!(stats.samples, 2, "only live samples completed");
}
