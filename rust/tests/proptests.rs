//! Property-based tests over coordinator invariants, using the in-repo
//! seeded property harness (`util::prop`).  Each property runs across a
//! few hundred randomized cases; failures report the replayable seed.

use e2train::coordinator::{SdScheduler, SmdScheduler};
use e2train::data::{synthetic, AugmentCfg, Sampler};
use e2train::energy::{EnergyBreakdown, EnergyLedger, OpEnergies};
use e2train::optim::LrSchedule;
use e2train::runtime::{fold_sequential, fold_tree, REDUCE_GRAIN};
use e2train::util::json::{parse, Json};
use e2train::util::prop;

#[test]
fn prop_lr_schedule_monotone_nonincreasing() {
    prop::check(200, |rng| {
        let total = rng.range_usize(10, 100_000) as u64;
        let lr0 = rng.range_f64(1e-4, 1.0);
        let s = LrSchedule::paper_default(lr0, total);
        let mut prev = f64::INFINITY;
        for i in 0..8 {
            let at = total * i / 8;
            let lr = s.at(at);
            assert!(lr <= prev + 1e-15, "lr increased at {at}");
            assert!(lr > 0.0);
            prev = lr;
        }
    });
}

#[test]
fn prop_lr_scaling_preserves_relative_boundaries() {
    prop::check(200, |rng| {
        let old = rng.range_usize(100, 1_000_000) as u64;
        let new = rng.range_usize(100, 1_000_000) as u64;
        let s = LrSchedule::paper_default(0.1, old).scaled_to(old, new);
        // decays happen at ~1/2 and ~3/4 of the new budget
        assert_eq!(s.at(0), 0.1);
        assert!(s.at(new) < 0.011);
    });
}

#[test]
fn prop_smd_drop_rate_concentrates() {
    prop::check(30, |rng| {
        let p = rng.range_f64(0.05, 0.95);
        let mut smd = SmdScheduler::new(true, p, rng.next_u64());
        let n = 20_000;
        let mut dropped = 0;
        for _ in 0..n {
            if smd.skip() {
                dropped += 1;
            }
        }
        let emp = dropped as f64 / n as f64;
        assert!((emp - p).abs() < 0.03, "p={p} emp={emp}");
    });
}

#[test]
fn prop_sd_survival_monotone_in_depth() {
    prop::check(200, |rng| {
        let n = rng.range_usize(1, 40);
        let p_l = rng.range_f64(0.0, 1.0);
        let mut sd = SdScheduler::new(n, p_l, rng.next_u64());
        let mask = sd.sample();
        assert_eq!(mask.len(), n);
        assert!(mask.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(sd.mean_survival() >= p_l - 1e-12);
        assert!(sd.mean_survival() <= 1.0 + 1e-12);
    });
}

#[test]
fn prop_sampler_epoch_is_permutation() {
    prop::check(40, |rng| {
        let n = rng.range_usize(2, 40) * 4;
        let batch = 4;
        let data = synthetic::generate(4, n, 4, rng.next_u64());
        let mut s = Sampler::new(
            n,
            batch,
            AugmentCfg { enabled: false, ..Default::default() },
            rng.next_u64(),
        );
        let mut labels = Vec::new();
        for _ in 0..n / batch {
            let (_, y) = s.next_batch(&data);
            match &y.data {
                e2train::runtime::TensorData::I32(v) => labels.extend(v.iter().copied()),
                _ => unreachable!(),
            }
        }
        // one epoch sees exactly the dataset's label multiset
        let mut seen = labels.clone();
        let mut expect = data.labels.clone();
        seen.sort_unstable();
        expect.sort_unstable();
        assert_eq!(seen, expect);
    });
}

#[test]
fn prop_energy_monotone_in_bits_and_activity() {
    prop::check(200, |rng| {
        let e = OpEnergies::default();
        let b1 = rng.range_usize(1, 32) as u32;
        let b2 = rng.range_usize(1, 32) as u32;
        // MAC monotone in each operand width
        if b1 < 32 {
            assert!(e.mac(b1, b2) < e.mac(b1 + 1, b2) + 1e-12);
        }
        // movement linear in width
        let w = rng.range_f64(1.0, 1e6);
        assert!((e.dram(w, 16) - 0.5 * e.dram(w, 32)).abs() < 1e-6);
        assert!((e.sram(w, 8) - 0.25 * e.sram(w, 32)).abs() < 1e-6);
    });
}

#[test]
fn prop_ledger_total_equals_sum_of_charges() {
    prop::check(100, |rng| {
        let mut ledger = EnergyLedger::default();
        let steps = rng.range_usize(1, 50);
        let mut expect = 0.0;
        for i in 0..steps {
            let e = EnergyBreakdown {
                fwd_mac: rng.range_f64(0.0, 1e9),
                bwd_mac: rng.range_f64(0.0, 1e9),
                sram: rng.range_f64(0.0, 1e9),
                dram: rng.range_f64(0.0, 1e9),
                update: rng.range_f64(0.0, 1e9),
            };
            expect += e.total();
            ledger.charge(i as u64, &e, 1.0);
        }
        assert!((ledger.total_joules() - expect * 1e-12).abs() < expect * 1e-20 + 1e-18);
        assert_eq!(ledger.steps_charged, steps as u64);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    prop::check(300, |rng| {
        // Build a random JSON value, print, reparse, compare.
        fn build(rng: &mut e2train::util::Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => {
                    let n = rng.below(8);
                    Json::str(
                        (0..n)
                            .map(|_| {
                                let c = rng.below(96) as u8 + 32;
                                c as char
                            })
                            .collect::<String>(),
                    )
                }
                4 => Json::arr((0..rng.below(4)).map(|_| build(rng, depth - 1))),
                _ => Json::obj(
                    (0..rng.below(4))
                        .map(|i| {
                            let key = format!("k{i}");
                            (key, build(rng, depth - 1))
                        })
                        .collect::<Vec<_>>()
                        .iter()
                        .map(|(k, v)| (k.as_str(), v.clone()))
                        .collect(),
                ),
            }
        }
        let v = build(rng, 3);
        let text = v.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back, v, "roundtrip failed for {text}");
    });
}

// ---------------------------------------------------------------------
// Export → import → continue round-trips: the primitive the checkpoint
// subsystem's bitwise-resume contract rests on.  Each property splits a
// stream at a random point, restores from the exported state, and
// requires the continuation to match the uninterrupted stream exactly.
// ---------------------------------------------------------------------

#[test]
fn prop_rng_state_roundtrip_continues_bitwise() {
    prop::check(200, |rng| {
        let seed = rng.next_u64();
        let split = rng.range_usize(0, 500);
        let mut a = e2train::util::Rng::seed_from_u64(seed);
        for _ in 0..split {
            a.next_u64();
        }
        let mut b = e2train::util::Rng::from_state(a.state()).unwrap();
        for i in 0..128 {
            assert_eq!(a.next_u64(), b.next_u64(), "drift at draw {i}");
        }
        // f64 draws stay aligned too (they consume the same stream)
        assert_eq!(a.f64().to_bits(), b.f64().to_bits());
    });
}

#[test]
fn prop_smd_state_roundtrip_continues_bitwise() {
    prop::check(100, |rng| {
        let p = rng.range_f64(0.05, 0.95);
        let seed = rng.next_u64();
        let split = rng.range_usize(0, 300);
        let mut a = SmdScheduler::new(true, p, seed);
        for _ in 0..split {
            a.skip();
        }
        let st = a.export();
        assert_eq!(st.seen, split as u64);
        let mut b = SmdScheduler::restore(true, p, &st).unwrap();
        assert_eq!(a.observed_drop_rate(), b.observed_drop_rate());
        for i in 0..256 {
            assert_eq!(a.skip(), b.skip(), "drift at iter {i}");
        }
        assert_eq!(a.observed_drop_rate(), b.observed_drop_rate());
        assert_eq!(a.export(), b.export());
        // corrupt states are rejected, not constructed
        let mut dead = st.clone();
        dead.rng = [0; 4];
        assert!(SmdScheduler::restore(true, p, &dead).is_none());
        let mut bad = st.clone();
        bad.skipped = bad.seen + 1;
        assert!(SmdScheduler::restore(true, p, &bad).is_none());
    });
}

#[test]
fn prop_sd_state_roundtrip_continues_bitwise() {
    prop::check(100, |rng| {
        let blocks = rng.range_usize(1, 24);
        let p_l = rng.range_f64(0.0, 1.0);
        let seed = rng.next_u64();
        let split = rng.range_usize(0, 200);
        let mut a = SdScheduler::new(blocks, p_l, seed);
        for _ in 0..split {
            a.sample();
        }
        let st = a.export();
        let mut b = SdScheduler::restore(blocks, p_l, &st).unwrap();
        assert_eq!(a.mean_survival(), b.mean_survival());
        for i in 0..128 {
            assert_eq!(a.sample(), b.sample(), "drift at batch {i}");
        }
        assert_eq!(a.export(), b.export());
        let mut dead = st.clone();
        dead.rng = [0; 4];
        assert!(SdScheduler::restore(blocks, p_l, &dead).is_none());
    });
}

#[test]
fn prop_sampler_state_roundtrip_continues_bitwise() {
    prop::check(40, |rng| {
        let n = rng.range_usize(2, 24) * 4;
        let batch = rng.range_usize(1, 8);
        let seed = rng.next_u64();
        let split = rng.range_usize(0, 40);
        let data = synthetic::generate(4, n, 4, rng.next_u64());
        let augment = if rng.bool(0.5) {
            AugmentCfg::default()
        } else {
            AugmentCfg { enabled: false, ..Default::default() }
        };
        // `a` is the uninterrupted stream; `shadow` replays draws only.
        let mut a = Sampler::new(n, batch, augment, seed);
        let mut shadow = Sampler::new(n, batch, augment, seed);
        for _ in 0..split {
            let _ = a.next_batch(&data);
            shadow.skip_batch();
        }
        // The shadow's exported position equals the real stream's...
        let st = shadow.export();
        assert_eq!(st, a.export());
        // ...and restoring it continues the stream bitwise.
        let mut b = Sampler::restore(&st, n, batch, augment).unwrap();
        for i in 0..24 {
            let (xa, _) = a.next_batch(&data);
            let (xb, _) = b.next_batch(&data);
            let ba: Vec<u32> =
                xa.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> =
                xb.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb, "drift at batch {i}");
        }
    });
}

/// The pipelined reducer's fixed-shape tree fold is bitwise identical
/// to the sequential shard-major fold for *any* workload shape: random
/// element counts on both sides of the tree-splitting grain, random
/// shard counts with uneven (and empty — shards > batch) per-shard row
/// counts, mixed magnitudes, and gradient accumulation layered as
/// several micro-batch folds into the same accumulator.
#[test]
fn prop_tree_reduce_bitwise_matches_sequential_fold() {
    prop::check(120, |rng| {
        // Mostly small (cheap) shapes; one case in four crosses the
        // grain so the tree actually splits.
        let elems = if rng.bool(0.25) {
            rng.range_usize(REDUCE_GRAIN, 2 * REDUCE_GRAIN + 33)
        } else {
            rng.range_usize(1, 128)
        };
        let micro = rng.range_usize(1, 4);
        let shards = rng.range_usize(1, 5);
        let mut acc_tree = vec![0.0f32; elems];
        let mut acc_seq = vec![0.0f32; elems];
        for _ in 0..micro {
            let buffers: Vec<Vec<f32>> = (0..shards)
                .map(|_| {
                    // 0 rows = a shard that held no samples this micro
                    let rows = rng.range_usize(0, 3);
                    (0..rows * elems)
                        .map(|_| {
                            let mag = 10f32.powi(rng.range_usize(0, 8) as i32 - 4);
                            rng.range_f32(-1.0, 1.0) * mag
                        })
                        .collect()
                })
                .collect();
            let views: Vec<&[f32]> = buffers.iter().map(|v| v.as_slice()).collect();
            fold_tree(&mut acc_tree, &views);
            fold_sequential(&mut acc_seq, &views);
        }
        for (i, (a, b)) in acc_tree.iter().zip(&acc_seq).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "tree/sequential bit drift at elem {i} (elems={elems} shards={shards})"
            );
        }
    });
}

#[test]
fn prop_rng_range_bounds() {
    prop::check(300, |rng| {
        let lo = rng.range_f64(-100.0, 100.0);
        let hi = lo + rng.range_f64(0.001, 100.0);
        let v = rng.range_f64(lo, hi);
        assert!(v >= lo && v < hi);
        let n = rng.range_usize(1, 1000);
        assert!(rng.below(n) < n);
    });
}
