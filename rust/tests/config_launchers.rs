//! Every shipped launcher under `rust/configs/` must parse, validate,
//! and round-trip — and the parser must *reject* keys it doesn't know,
//! so a stale or typo'd knob (the way a new `checkpoint`/`shards` field
//! goes quietly dead) fails in CI instead of silently falling back to a
//! default at 3am on somebody's edge box.

use std::path::{Path, PathBuf};

use e2train::config::{BackendChoice, RunCfg};
use e2train::util::json::{parse, Json};

fn configs_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("configs")
}

fn launcher_paths() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(configs_dir())
        .expect("configs/ exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map(|e| e == "json").unwrap_or(false))
        .collect();
    out.sort();
    out
}

#[test]
fn every_shipped_launcher_parses_and_validates() {
    let paths = launcher_paths();
    assert!(
        paths.len() >= 5,
        "expected the shipped launcher set, found {}",
        paths.len()
    );
    for p in &paths {
        let cfg = RunCfg::load(p).unwrap_or_else(|e| {
            panic!("launcher {} failed to load: {e:#}", p.display())
        });
        assert!(cfg.iters > 0, "{}: zero iters", p.display());
        assert!(!cfg.family.is_empty(), "{}", p.display());
        assert!(!cfg.method.is_empty(), "{}", p.display());
        assert!(
            (0.0..=1.0).contains(&cfg.smd.p),
            "{}: smd.p out of range",
            p.display()
        );
        if cfg.checkpoint.every > 0 {
            assert!(
                cfg.checkpoint.dir.is_some(),
                "{}: checkpointing without a registry dir",
                p.display()
            );
            assert!(
                cfg.checkpoint.keep_last >= 1,
                "{}: retention keeps nothing",
                p.display()
            );
        }
        // Round-trip: what we serialize is what we parse.
        let back = RunCfg::from_json(&cfg.to_json())
            .unwrap_or_else(|e| panic!("{}: round-trip failed: {e:#}", p.display()));
        assert_eq!(back.to_json(), cfg.to_json(), "{}", p.display());
    }
}

/// The shipped launcher set includes the new subsystem knobs, so their
/// JSON spelling is pinned by a real file (key drift fails here).
#[test]
fn launcher_set_covers_shards_checkpoint_and_backend_knobs() {
    let mut has_shards = false;
    let mut has_accum = false;
    let mut has_checkpoint = false;
    let mut has_faults = false;
    let mut has_replicate = false;
    let mut backends = Vec::new();
    for p in launcher_paths() {
        let cfg = RunCfg::load(&p).unwrap();
        has_shards |= cfg.shards > 0;
        has_accum |= cfg.accum > 1;
        has_checkpoint |= cfg.checkpoint.every > 0;
        // replication only makes sense over a publishing registry (the
        // parser enforces it; assert here so the shipped file stays an
        // example of the valid shape)
        if cfg.checkpoint.replicate.is_some() {
            has_replicate = true;
            assert!(
                cfg.checkpoint.every > 0,
                "{}: arms `checkpoint.replicate` without checkpointing",
                p.display()
            );
        }
        // a launcher arming faults must also checkpoint, or the
        // supervisor can only ever restart from scratch
        if cfg.faults.enabled() {
            has_faults = true;
            assert!(
                cfg.checkpoint.every > 0,
                "{}: arms `faults` without checkpointing",
                p.display()
            );
        }
        if let Some(b) = cfg.backend {
            backends.push(b);
        }
    }
    assert!(has_shards, "no launcher exercises `shards`");
    assert!(has_accum, "no launcher exercises `accum` (micro-batch accumulation)");
    assert!(has_checkpoint, "no launcher exercises `checkpoint.every`");
    assert!(has_faults, "no launcher arms `faults` (supervised recovery)");
    // Both an explicit single-executor spelling and the sharded one.
    assert!(
        backends.contains(&BackendChoice::Host),
        "no launcher pins backend: \"host\""
    );
    assert!(
        backends.contains(&BackendChoice::Sharded),
        "no launcher pins backend: \"sharded\""
    );
    // ...and the planned spelling, with its catalog + budget knobs.
    assert!(
        backends.contains(&BackendChoice::Auto),
        "no launcher hands the layout to the planner (backend: \"auto\")"
    );
}

/// The planned launcher: `backend: "auto"` owns the whole layout, so an
/// explicit `shards` is a contradiction, and `energy_budget_j` is a
/// planner hint that means nothing without it.
#[test]
fn auto_backend_launcher_is_strictly_validated() {
    let path = configs_dir().join("auto-backend.json");
    let base = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let cfg = RunCfg::load(&path).unwrap();
    assert_eq!(cfg.backend, Some(BackendChoice::Auto));
    assert_eq!(cfg.resolved_backend(), BackendChoice::Auto);
    assert_eq!(cfg.shards, 0, "auto accepts no explicit shards");
    assert!(cfg.energy_budget_j.is_some(), "launcher shows the budget hint");
    assert!(cfg.catalog.is_some(), "launcher pins the catalog file");

    // auto + explicit shards: the planner owns the shard count.
    let mut top = base.as_obj().unwrap().clone();
    top.insert("shards".into(), Json::num(2.0));
    let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
    assert!(err.contains("auto") && err.contains("shards"), "unexpected error: {err}");

    // a budget without auto is a dead hint, rejected not ignored.
    let mut top = base.as_obj().unwrap().clone();
    top.insert("backend".into(), Json::str("resident"));
    let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
    assert!(err.contains("energy_budget_j"), "unexpected error: {err}");

    // a non-positive budget is rejected outright.
    let mut top = base.as_obj().unwrap().clone();
    top.insert("energy_budget_j".into(), Json::num(0.0));
    assert!(RunCfg::from_json(&Json::Obj(top)).is_err());
}

/// `cfg.backend` validation: unknown values, `sharded` without a shard
/// count, and a single-executor backend contradicting `shards` must all
/// fail with clean errors naming the problem — a launcher can't silently
/// run on a different execution path than it names.
#[test]
fn backend_knob_is_strictly_validated() {
    let path = configs_dir().join("backend-matrix.json");
    let base = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    // the shipped matrix launcher itself selects sharded execution
    let cfg = RunCfg::load(&path).unwrap();
    assert_eq!(cfg.backend, Some(BackendChoice::Sharded));
    assert_eq!(cfg.resolved_backend(), BackendChoice::Sharded);
    assert_eq!(cfg.shards, 3);

    // unknown value
    let mut top = base.as_obj().unwrap().clone();
    top.insert("backend".into(), Json::str("gpu-cluster"));
    let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
    assert!(err.contains("gpu-cluster"), "unexpected error: {err}");

    // backend "sharded" without shards
    let mut top = base.as_obj().unwrap().clone();
    top.remove("shards");
    let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
    assert!(err.contains("shards"), "unexpected error: {err}");

    // backend "host" / "resident" with shards set
    for single in ["host", "resident"] {
        let mut top = base.as_obj().unwrap().clone();
        top.insert("backend".into(), Json::str(single));
        let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
        assert!(
            err.contains(single) && err.contains("shards"),
            "unexpected error: {err}"
        );
    }

    // a non-string backend is rejected, not coerced
    let mut top = base.as_obj().unwrap().clone();
    top.insert("backend".into(), Json::num(2.0));
    assert!(RunCfg::from_json(&Json::Obj(top)).is_err());
}

/// The pipelined launcher: `accum` is a sharded-training layout knob,
/// so zero and single-executor combinations are contradictions the
/// parser must name, not defaults it falls back to.
#[test]
fn accum_knob_is_strictly_validated() {
    let path = configs_dir().join("pipelined-4x.json");
    let base = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let cfg = RunCfg::load(&path).unwrap();
    assert_eq!(cfg.backend, Some(BackendChoice::Sharded));
    assert_eq!(cfg.shards, 4);
    assert_eq!(cfg.accum, 4, "launcher pins the accumulation depth");

    // accum 0 means "run no micro-batches": rejected outright.
    let mut top = base.as_obj().unwrap().clone();
    top.insert("accum".into(), Json::num(0.0));
    let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
    assert!(err.contains("accum"), "unexpected error: {err}");

    // accumulation without sharded execution is a dead knob.
    for single in ["host", "resident"] {
        let mut top = base.as_obj().unwrap().clone();
        top.insert("backend".into(), Json::str(single));
        top.remove("shards");
        let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
        assert!(
            err.contains("accum") && err.contains("sharded"),
            "unexpected error: {err}"
        );
    }

    // `auto` hands the layout to the planner, which always probes at
    // accum 1 — an explicit accum is rejected like explicit shards.
    let mut top = base.as_obj().unwrap().clone();
    top.insert("backend".into(), Json::str("auto"));
    top.remove("shards");
    let err = format!("{:#}", RunCfg::from_json(&Json::Obj(top)).unwrap_err());
    assert!(err.contains("accum"), "unexpected error: {err}");

    // absent knob defaults to 1 micro-batch (the non-accumulating step).
    let mut top = base.as_obj().unwrap().clone();
    top.remove("accum");
    let cfg = RunCfg::from_json(&Json::Obj(top)).unwrap();
    assert_eq!(cfg.accum, 1);
}

#[test]
fn unknown_and_stale_keys_are_rejected() {
    // Take a real launcher, inject drifted keys at both levels.
    let path = configs_dir().join("e2train-quick.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let v = parse(&text).unwrap();

    let mut top = v.as_obj().unwrap().clone();
    top.insert("iterations".into(), Json::num(100.0)); // stale spelling
    let err = RunCfg::from_json(&Json::Obj(top)).unwrap_err();
    assert!(format!("{err:#}").contains("iterations"));

    let mut top = v.as_obj().unwrap().clone();
    top.insert(
        "checkpoint".into(),
        Json::obj(vec![
            ("every", Json::num(10.0)),
            ("dir", Json::str("ckpts")),
            ("keep_lats", Json::num(3.0)), // typo'd retention knob
        ]),
    );
    let err = RunCfg::from_json(&Json::Obj(top)).unwrap_err();
    assert!(format!("{err:#}").contains("keep_lats"));

    let mut top = v.as_obj().unwrap().clone();
    top.insert("smd".into(), Json::obj(vec![("prob", Json::num(0.5))]));
    assert!(RunCfg::from_json(&Json::Obj(top)).is_err());
}

/// Keys that belong to the *other* variant of a tagged section are
/// just as dead as typos — the per-kind allowlists reject them.
#[test]
fn cross_variant_keys_are_rejected() {
    let path = configs_dir().join("e2train-quick.json");
    let v = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();

    // synthetic knobs on a cifar_bin source silently no-op'd before
    let mut top = v.as_obj().unwrap().clone();
    top.insert(
        "data".into(),
        Json::obj(vec![
            ("kind", Json::str("cifar_bin")),
            ("dir", Json::str("/data/cifar")),
            ("n_train", Json::num(4096.0)),
        ]),
    );
    let err = RunCfg::from_json(&Json::Obj(top)).unwrap_err();
    assert!(format!("{err:#}").contains("n_train"));

    // step-schedule boundaries on a constant lr are dead too
    let mut top = v.as_obj().unwrap().clone();
    top.insert(
        "lr".into(),
        Json::obj(vec![
            ("kind", Json::str("constant")),
            ("lr0", Json::num(0.1)),
            ("boundaries", Json::arr([Json::num(100.0)])),
        ]),
    );
    let err = RunCfg::from_json(&Json::Obj(top)).unwrap_err();
    assert!(format!("{err:#}").contains("boundaries"));
}
