//! The execution-layer contract, pinned as a full matrix: **every**
//! `StepBackend` — host, resident, sharded for S ∈ {1, 2, 3} — produces
//! bitwise-identical runs for the same seed, and a run interrupted under
//! one backend resumes under any other without a bit of drift.
//!
//! This subsumes and tightens the historical pairwise checks
//! (tests/resident_equivalence.rs, tests/shard_equivalence.rs): the
//! whole matrix is compared against one reference outcome — metrics
//! trace, energy-ledger rows, `psg_frac` telemetry, gate means and the
//! final model state — and the recorded `RunMetrics::backend` /
//! `RunMetrics::shards` attribution is asserted per cell.

use std::path::Path;

use e2train::checkpoint::{CheckpointRegistry, RetentionCfg};
use e2train::config::{BackendChoice, CkptCfg, DataCfg, RunCfg};
use e2train::coordinator::{RunOutcome, Trainer};
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

/// One matrix cell: (label, explicit backend, shard count).
const CELLS: &[(&str, BackendChoice, usize)] = &[
    ("host", BackendChoice::Host, 0),
    ("resident", BackendChoice::Resident, 0),
    ("sharded", BackendChoice::Sharded, 1),
    ("sharded", BackendChoice::Sharded, 2),
    ("sharded", BackendChoice::Sharded, 3),
];

fn ref_cfg(artifacts: &Path, method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, method, iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg.eval_every = 8;
    cfg
}

fn cell_cfg(mut cfg: RunCfg, backend: BackendChoice, shards: usize) -> RunCfg {
    cfg.backend = Some(backend);
    cfg.shards = shards;
    // The host cell also drops prefetch so the legacy synchronous
    // sampling path stays in the matrix.
    if backend == BackendChoice::Host {
        cfg.resident = false;
        cfg.prefetch = false;
    }
    cfg
}

fn with_ckpt(mut cfg: RunCfg, dir: &Path, every: u64) -> RunCfg {
    cfg.checkpoint = CkptCfg {
        every,
        dir: Some(dir.to_path_buf()),
        keep_last: 16,
        keep_every: 0,
        ..CkptCfg::default()
    };
    cfg
}

/// Full bitwise comparison of two run outcomes (everything except wall
/// time, the machine-dependent prefetch depth, and the backend
/// attribution itself).
fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{ctx}: acc");
    assert_eq!(
        a.metrics.final_test_acc_top5, b.metrics.final_test_acc_top5,
        "{ctx}: top5"
    );
    assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{ctx}: loss");
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{ctx}: joules");
    assert_eq!(a.metrics.executed_macs, b.metrics.executed_macs, "{ctx}: macs");
    assert_eq!(a.metrics.steps_run, b.metrics.steps_run, "{ctx}: steps");
    assert_eq!(
        a.metrics.steps_skipped, b.metrics.steps_skipped,
        "{ctx}: skipped"
    );
    assert_eq!(
        a.metrics.mean_gate_fracs, b.metrics.mean_gate_fracs,
        "{ctx}: gate means"
    );
    assert_eq!(
        a.metrics.mean_psg_frac, b.metrics.mean_psg_frac,
        "{ctx}: psg telemetry"
    );
    assert_eq!(a.metrics.trace.len(), b.metrics.trace.len(), "{ctx}: trace len");
    for (x, y) in a.metrics.trace.iter().zip(b.metrics.trace.iter()) {
        assert_eq!(x.iter, y.iter, "{ctx}: trace iter");
        assert_eq!(x.loss, y.loss, "{ctx}: trace loss @{}", x.iter);
        assert_eq!(x.train_acc, y.train_acc, "{ctx}: trace acc @{}", x.iter);
        assert_eq!(x.joules, y.joules, "{ctx}: trace joules @{}", x.iter);
        assert_eq!(x.test_acc, y.test_acc, "{ctx}: trace eval @{}", x.iter);
    }
    assert_eq!(
        a.ledger.steps_charged, b.ledger.steps_charged,
        "{ctx}: ledger steps"
    );
    assert_eq!(a.ledger.macs, b.ledger.macs, "{ctx}: ledger macs");
    assert_eq!(a.ledger.trace, b.ledger.trace, "{ctx}: ledger rows");
    a.state.assert_bitwise_eq(&b.state);
}

/// Every backend cell produces the identical run — sgd32 (plain SGD)
/// and e2train (SMD drops + SWA + learned gates + PSG telemetry).
#[test]
fn all_backends_produce_bitwise_identical_runs() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    for method in ["sgd32", "e2train"] {
        let mut reference: Option<RunOutcome> = None;
        for &(label, backend, shards) in CELLS {
            let cfg = cell_cfg(ref_cfg(tmp.path(), method, 24), backend, shards);
            let out = Trainer::new(&engine, cfg).unwrap().run(None).unwrap();
            // Attribution: the run records which backend executed it.
            assert_eq!(out.metrics.backend, label, "{method} S={shards}");
            assert_eq!(out.metrics.shards, shards, "{method} {label}");
            match &reference {
                None => reference = Some(out),
                Some(r) => assert_outcomes_identical(
                    r,
                    &out,
                    &format!("{method} {label} S={shards} vs host"),
                ),
            }
        }
        // e2train runs must actually exercise the telemetry being
        // compared, or the psg/gate assertions above are vacuous.
        if method == "e2train" {
            let r = reference.as_ref().unwrap();
            assert!(r.metrics.mean_psg_frac.is_some(), "no PSG telemetry");
            assert!(!r.metrics.mean_gate_fracs.is_empty(), "no gate telemetry");
            assert!(r.metrics.steps_skipped > 0, "SMD never dropped a batch");
        }
    }
}

/// Interrupt + resume **across** backends: a run checkpointed under one
/// backend resumes under every other, bitwise equal to the run that
/// never stopped.  (Within-backend resume is pinned by
/// tests/resume_equivalence.rs; this is the cross-cell tightening.)
#[test]
fn interrupt_and_resume_across_backends_is_bitwise() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    // (checkpoint under, resume under) — covers every backend on both
    // sides of the interruption.
    let pairs: &[((BackendChoice, usize), (BackendChoice, usize))] = &[
        ((BackendChoice::Host, 0), (BackendChoice::Sharded, 2)),
        ((BackendChoice::Resident, 0), (BackendChoice::Host, 0)),
        ((BackendChoice::Sharded, 3), (BackendChoice::Resident, 0)),
    ];
    for &((from_b, from_s), (to_b, to_s)) in pairs {
        let reg = TempDir::new().unwrap();
        let full_cfg = cell_cfg(
            with_ckpt(ref_cfg(tmp.path(), "e2train", 18), reg.path(), 6),
            from_b,
            from_s,
        );
        let full = Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();

        let registry = CheckpointRegistry::new(reg.path(), RetentionCfg::default());
        let entries = registry.entries().unwrap();
        assert!(entries.len() >= 3, "expected several boundaries");
        for entry in &entries {
            let ckpt = registry.load(entry).unwrap();
            let resume_cfg = cell_cfg(ref_cfg(tmp.path(), "e2train", 18), to_b, to_s);
            let out = Trainer::new(&engine, resume_cfg)
                .unwrap()
                .resume(ckpt)
                .unwrap();
            assert_eq!(out.metrics.backend, to_b.as_str());
            assert_outcomes_identical(
                &full,
                &out,
                &format!(
                    "{}/S{} ckpt @iter {} -> {}/S{} resume",
                    from_b.as_str(),
                    from_s,
                    entry.iter,
                    to_b.as_str(),
                    to_s
                ),
            );
        }
    }
}
