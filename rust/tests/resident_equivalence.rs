//! Equivalence and determinism guarantees of the resident-state step
//! loop, the prefetch pipeline, and the parallel experiment fan-out —
//! all running on the reference backend, so these execute everywhere
//! (no PJRT runtime or AOT artifacts required).
//!
//! The contract under test: for fixed seeds, the resident+prefetch loop
//! is *bitwise indistinguishable* from the legacy synchronous host path
//! in every reported metric and in the final model state.

use std::path::Path;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::data::synthetic;
use e2train::experiments::{ExpCtx, RunSpec};
use e2train::runtime::{
    write_reference_family, BackendKind, Engine, HostTensor, ModelState, RefFamilySpec,
    TrainProgram,
};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

fn ref_cfg(artifacts: &Path, method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, method, iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg
}

/// Resident + prefetch (the default) vs legacy host + synchronous
/// sampling: identical trace losses, identical periodic and final eval
/// metrics, identical energy, bitwise-identical final state.  `e2train`
/// additionally exercises SWA snapshots (sync_to_host) and SMD skips
/// consuming prefetched batches.
#[test]
fn resident_prefetch_matches_host_sync_path() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    for method in ["sgd32", "e2train"] {
        let engine = Engine::cpu().unwrap();
        let mut host_cfg = ref_cfg(tmp.path(), method, 24);
        host_cfg.resident = false;
        host_cfg.prefetch = false;
        host_cfg.eval_every = 8;
        let mut res_cfg = ref_cfg(tmp.path(), method, 24);
        assert!(res_cfg.resident && res_cfg.prefetch, "defaults changed");
        res_cfg.eval_every = 8;

        let a = Trainer::new(&engine, host_cfg).unwrap().run(None).unwrap();
        let b = Trainer::new(&engine, res_cfg).unwrap().run(None).unwrap();

        assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{method}");
        assert_eq!(a.metrics.final_test_acc_top5, b.metrics.final_test_acc_top5);
        assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{method}");
        assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{method}");
        assert_eq!(a.metrics.steps_run, b.metrics.steps_run);
        assert_eq!(a.metrics.steps_skipped, b.metrics.steps_skipped);
        let la: Vec<f64> = a.metrics.trace.iter().map(|p| p.loss).collect();
        let lb: Vec<f64> = b.metrics.trace.iter().map(|p| p.loss).collect();
        assert_eq!(la, lb, "{method}: per-step losses diverged");
        let ea: Vec<Option<f64>> = a.metrics.trace.iter().map(|p| p.test_acc).collect();
        let eb: Vec<Option<f64>> = b.metrics.trace.iter().map(|p| p.test_acc).collect();
        assert_eq!(ea, eb, "{method}: periodic evals diverged");
        a.state.assert_bitwise_eq(&b.state);
    }
}

#[test]
fn device_state_roundtrip_via_program() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let prog = TrainProgram::load(&engine, &fam.join("e2train.json")).unwrap();
    assert_eq!(prog.backend(), BackendKind::Reference);
    let state = ModelState::init(&prog.manifest, 11);
    let dev = prog.upload_state(state.clone()).unwrap();
    assert_eq!(dev.num_tensors(), state.num_tensors());
    let back = dev.sync_to_host().unwrap();
    state.assert_bitwise_eq(&back);
}

/// The fan-out must be invisible: identical records run-to-run, and
/// identical to serial execution, with compiled programs shared through
/// the engine cache.
#[test]
fn parallel_experiment_fanout_is_deterministic() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let out = TempDir::new().unwrap();
    let engine = Engine::cpu().unwrap();
    let mut ctx = ExpCtx::new(&engine, tmp.path(), out.path(), 10);
    ctx.n_train = 96;
    ctx.n_test = 32;

    let specs = || {
        vec![
            RunSpec::new(FAM, "sgd32", 10, |_| {}),
            RunSpec::new(FAM, "sgd32", 10, |c| {
                c.smd.enabled = true;
                c.smd.p = 0.5;
            }),
            RunSpec::new(FAM, "e2train", 10, |_| {}),
            RunSpec::new(FAM, "e2train", 10, |c| c.alpha = 4.0),
        ]
    };
    let r1 = ctx.run_many(specs()).unwrap();
    let r2 = ctx.run_many(specs()).unwrap();
    assert_eq!(r1.len(), 4);
    for (a, b) in r1.iter().zip(r2.iter()) {
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.joules, b.joules);
        assert_eq!(a.steps_run, b.steps_run);
        assert_eq!(a.steps_skipped, b.steps_skipped);
    }
    // parallel == serial, record by record
    let s0 = ctx.run(FAM, "sgd32", 10, |_| {}).unwrap();
    assert_eq!(s0.acc, r1[0].acc);
    assert_eq!(s0.joules, r1[0].joules);
    let s1 = ctx
        .run(FAM, "sgd32", 10, |c| {
            c.smd.enabled = true;
            c.smd.p = 0.5;
        })
        .unwrap();
    assert_eq!(s1.acc, r1[1].acc);
    assert_eq!(s1.steps_skipped, r1[1].steps_skipped);
    // two methods x (train, eval): every worker shared the same cache
    assert_eq!(engine.cached_count(), 4);
}

/// evaluate_full must cover the tail remainder of the test set (the
/// seed silently dropped up to eval_batch-1 samples) and must work for
/// test sets smaller than one eval batch (the seed errored).
#[test]
fn evaluate_full_covers_tail_remainder() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut cfg = ref_cfg(tmp.path(), "sgd32", 6);
    // 40 = 2 full eval batches of 16 + a tail of 8
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 64, n_test: 40, seed: 3 };
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let out = trainer.run(None).unwrap();
    let state = out.state;

    // Manual ground truth: full batches + a hand-padded tail batch.
    let prog = TrainProgram::load(&engine, &fam.join("sgd32.json")).unwrap();
    let (_, test) = synthetic::generate_split(10, 64, 40, 8, 3);
    let eb = prog.eval_batch();
    assert_eq!(eb, 16);
    let stride = 8 * 8 * 3;
    let mut correct = 0.0;
    let mut loss_sum = 0.0;
    for b in 0..2 {
        let lo = b * eb;
        let x = HostTensor::f32(
            vec![eb, 8, 8, 3],
            test.images[lo * stride..(lo + eb) * stride].to_vec(),
        );
        let y = HostTensor::i32(vec![eb], test.labels[lo..lo + eb].to_vec());
        let em = prog.eval_batch_run(&state, &x, &y).unwrap();
        correct += em.correct;
        loss_sum += em.loss * eb as f64;
    }
    let lo = 2 * eb;
    let rem = 8;
    let mut px = vec![0f32; eb * stride];
    px[..rem * stride].copy_from_slice(&test.images[lo * stride..(lo + rem) * stride]);
    let mut py = vec![-1i32; eb];
    py[..rem].copy_from_slice(&test.labels[lo..lo + rem]);
    let em = prog
        .eval_batch_run(&state, &HostTensor::f32(vec![eb, 8, 8, 3], px), &HostTensor::i32(vec![eb], py))
        .unwrap();
    correct += em.correct;
    loss_sum += em.loss * eb as f64;

    let (acc, _, loss) = trainer.evaluate_full(&state).unwrap();
    assert_eq!(acc, correct / 40.0, "tail samples are not being evaluated");
    assert!((loss - loss_sum / 40.0).abs() < 1e-12);

    // Smaller than one eval batch: works instead of erroring.
    let (train_small, test_small) = synthetic::generate_split(10, 64, 5, 8, 3);
    trainer.set_data(train_small, test_small);
    let (acc_small, acc5_small, loss_small) = trainer.evaluate_full(&state).unwrap();
    assert!((0.0..=1.0).contains(&acc_small));
    assert!(acc_small <= acc5_small + 1e-12);
    assert!(loss_small.is_finite() && loss_small > 0.0);
}

/// Fine-tune handoff across methods on the resident path: state trained
/// under sgd32 migrates by name into an e2train run (gate slots start
/// fresh) and training continues without error.
#[test]
fn finetune_handoff_migrates_resident_state() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let pre = Trainer::new(&engine, ref_cfg(tmp.path(), "sgd32", 12))
        .unwrap()
        .run(None)
        .unwrap();
    let mut ft = Trainer::new(&engine, ref_cfg(tmp.path(), "e2train", 8)).unwrap();
    let out = ft.run(Some(pre.state.clone())).unwrap();
    assert!(out.metrics.final_test_acc >= 0.0);
    // the migrated trunk matches by name; gates exist only in the new state
    assert!(pre.state.by_name("gate.w").is_none());
    assert!(out.state.by_name("gate.w").is_some());
}
