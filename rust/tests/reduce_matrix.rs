//! The pipelined-reduce contract, pinned end-to-end through `Trainer`:
//! the sharded backend's overlapped reducer thread and micro-batch
//! gradient accumulation are **bitwise invisible**.  Every
//! (shards ∈ {1, 2, 3}) × (accum ∈ {1, 2}) cell — all running the
//! default pipelined reduce — produces exactly the single-device
//! resident outcome, and a run checkpointed under one accumulation
//! depth resumes under another without a bit of drift.
//!
//! This is the `reduce-matrix` CI gate.  It complements
//! `tests/backend_matrix.rs` (which pins the backend seam at accum 1
//! and must keep passing unchanged) by sweeping the knobs the pipeline
//! added: the element-axis reduction tree is exercised by every cell,
//! and accum > 1 drives multiple reducer jobs per logical step.

use std::path::Path;

use e2train::checkpoint::{CheckpointRegistry, RetentionCfg};
use e2train::config::{BackendChoice, CkptCfg, DataCfg, RunCfg};
use e2train::coordinator::{RunOutcome, Trainer};
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

/// Sharded matrix cells: (shard count, micro-batches per step).
/// accum 2 with shards 3 over batch 8 leaves micro-batches of 4 split
/// 2/1/1 — deliberately non-divisible on both axes.
const CELLS: &[(usize, usize)] =
    &[(1, 1), (1, 2), (2, 1), (2, 2), (3, 1), (3, 2)];

fn ref_cfg(artifacts: &Path, method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, method, iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg.eval_every = 8;
    cfg
}

fn sharded_cfg(mut cfg: RunCfg, shards: usize, accum: usize) -> RunCfg {
    cfg.backend = Some(BackendChoice::Sharded);
    cfg.shards = shards;
    cfg.accum = accum;
    cfg
}

fn with_ckpt(mut cfg: RunCfg, dir: &Path, every: u64) -> RunCfg {
    cfg.checkpoint = CkptCfg {
        every,
        dir: Some(dir.to_path_buf()),
        keep_last: 16,
        keep_every: 0,
        ..CkptCfg::default()
    };
    cfg
}

/// Full bitwise comparison of two run outcomes (everything except wall
/// time, the machine-dependent prefetch depth, and the backend
/// attribution itself).
fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{ctx}: acc");
    assert_eq!(
        a.metrics.final_test_acc_top5, b.metrics.final_test_acc_top5,
        "{ctx}: top5"
    );
    assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{ctx}: loss");
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{ctx}: joules");
    assert_eq!(a.metrics.executed_macs, b.metrics.executed_macs, "{ctx}: macs");
    assert_eq!(a.metrics.steps_run, b.metrics.steps_run, "{ctx}: steps");
    assert_eq!(
        a.metrics.steps_skipped, b.metrics.steps_skipped,
        "{ctx}: skipped"
    );
    assert_eq!(
        a.metrics.mean_gate_fracs, b.metrics.mean_gate_fracs,
        "{ctx}: gate means"
    );
    assert_eq!(
        a.metrics.mean_psg_frac, b.metrics.mean_psg_frac,
        "{ctx}: psg telemetry"
    );
    assert_eq!(a.metrics.trace.len(), b.metrics.trace.len(), "{ctx}: trace len");
    for (x, y) in a.metrics.trace.iter().zip(b.metrics.trace.iter()) {
        assert_eq!(x.iter, y.iter, "{ctx}: trace iter");
        assert_eq!(x.loss, y.loss, "{ctx}: trace loss @{}", x.iter);
        assert_eq!(x.train_acc, y.train_acc, "{ctx}: trace acc @{}", x.iter);
        assert_eq!(x.joules, y.joules, "{ctx}: trace joules @{}", x.iter);
        assert_eq!(x.test_acc, y.test_acc, "{ctx}: trace eval @{}", x.iter);
    }
    assert_eq!(
        a.ledger.steps_charged, b.ledger.steps_charged,
        "{ctx}: ledger steps"
    );
    assert_eq!(a.ledger.macs, b.ledger.macs, "{ctx}: ledger macs");
    assert_eq!(a.ledger.trace, b.ledger.trace, "{ctx}: ledger rows");
    a.state.assert_bitwise_eq(&b.state);
}

/// Every (shards, accum) cell through the pipelined reducer equals the
/// single-device resident run — sgd32 (plain SGD) and e2train (SMD
/// drops + SWA + learned gates + PSG telemetry).
#[test]
fn pipelined_cells_match_the_resident_run_bitwise() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    for method in ["sgd32", "e2train"] {
        let mut reference_cfg = ref_cfg(tmp.path(), method, 24);
        reference_cfg.backend = Some(BackendChoice::Resident);
        let reference =
            Trainer::new(&engine, reference_cfg).unwrap().run(None).unwrap();

        for &(shards, accum) in CELLS {
            let cfg = sharded_cfg(ref_cfg(tmp.path(), method, 24), shards, accum);
            let out = Trainer::new(&engine, cfg).unwrap().run(None).unwrap();
            assert_eq!(out.metrics.backend, "sharded", "{method} S={shards}");
            assert_eq!(out.metrics.shards, shards, "{method} A={accum}");
            assert_outcomes_identical(
                &reference,
                &out,
                &format!("{method} S={shards} A={accum} vs resident"),
            );
        }
        // e2train runs must actually exercise the telemetry compared
        // above, or the psg/gate assertions are vacuous.
        if method == "e2train" {
            assert!(reference.metrics.mean_psg_frac.is_some(), "no PSG telemetry");
            assert!(
                !reference.metrics.mean_gate_fracs.is_empty(),
                "no gate telemetry"
            );
            assert!(reference.metrics.steps_skipped > 0, "SMD never dropped");
        }
    }
}

/// Interrupt + resume across accumulation depths: `accum` is outside
/// the determinism fingerprint, so a checkpoint written at one depth
/// restores at any other (and on a non-accumulating backend), bitwise
/// equal to the run that never stopped.
#[test]
fn interrupt_and_resume_across_accum_depths_is_bitwise() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    // (checkpoint under (shards, accum), resume under (shards, accum));
    // shards 0 = the resident backend, accum forced to 1.
    let pairs: &[((usize, usize), (usize, usize))] = &[
        ((2, 2), (0, 1)), // pipelined+accumulated -> single device
        ((0, 1), (3, 2)), // single device -> pipelined+accumulated
        ((2, 1), (2, 2)), // same layout, deeper accumulation
    ];
    for &((from_s, from_a), (to_s, to_a)) in pairs {
        let shape = |cfg: RunCfg, s: usize, a: usize| {
            if s == 0 {
                let mut cfg = cfg;
                cfg.backend = Some(BackendChoice::Resident);
                cfg
            } else {
                sharded_cfg(cfg, s, a)
            }
        };
        let reg = TempDir::new().unwrap();
        let full_cfg = shape(
            with_ckpt(ref_cfg(tmp.path(), "e2train", 18), reg.path(), 6),
            from_s,
            from_a,
        );
        let full = Trainer::new(&engine, full_cfg).unwrap().run(None).unwrap();

        let registry = CheckpointRegistry::new(reg.path(), RetentionCfg::default());
        let entries = registry.entries().unwrap();
        assert!(entries.len() >= 3, "expected several boundaries");
        for entry in &entries {
            let ckpt = registry.load(entry).unwrap();
            let resume_cfg = shape(ref_cfg(tmp.path(), "e2train", 18), to_s, to_a);
            let out = Trainer::new(&engine, resume_cfg)
                .unwrap()
                .resume(ckpt)
                .unwrap();
            assert_outcomes_identical(
                &full,
                &out,
                &format!(
                    "S{from_s}/A{from_a} ckpt @iter {} -> S{to_s}/A{to_a} resume",
                    entry.iter
                ),
            );
        }
    }
}
