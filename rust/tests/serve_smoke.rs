//! Tier-1 serve smoke: boots the micro-batching inference service with
//! synthetic concurrent clients at two concurrency levels and records
//! `BENCH_serve.json` at the repo root, so every verified checkout
//! carries a serving-perf snapshot even when the release bench
//! (`scripts/serve_bench.sh`) never runs.  Debug timings are only a
//! smoke signal; the CLI `e2train serve` under `--release` writes the
//! canonical numbers (and, like the runtime smoke, release-sourced
//! files are never clobbered by this test).

use std::path::PathBuf;
use std::time::Duration;

use e2train::experiments::{run_serve_bench, ServeBenchCfg};
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::json::parse;
use e2train::util::tmp::TempDir;

#[test]
fn serve_smoke_records_bench_serve_json() {
    let tmp = TempDir::new().unwrap();
    let spec = RefFamilySpec::tiny();
    let fam = write_reference_family(tmp.path(), &spec).unwrap();
    let engine = Engine::cpu().unwrap();

    let cfg = ServeBenchCfg {
        levels: vec![2, 6],
        requests_per_client: 12,
        samples_per_request: 2,
        workers: 2,
        max_delay: Duration::from_millis(2),
        seed: 0,
        registry: None,
        replica: None,
        source: "cargo-test smoke (debug profile)".into(),
    };
    let report = run_serve_bench(&engine, &fam.join("sgd32.json"), &cfg).unwrap();

    // Schema + per-level sanity.
    assert_eq!(report.at(&["schema"]).as_str(), Some("bench_serve/v1"));
    let levels = report.at(&["levels"]).as_arr().expect("levels array");
    assert_eq!(levels.len(), 2);
    for lvl in levels {
        assert!(lvl.at(&["throughput_sps"]).as_f64().unwrap() > 0.0);
        assert!(lvl.at(&["samples"]).as_f64().unwrap() > 0.0);
        let p50 = lvl.at(&["latency_p50_ms"]).as_f64().unwrap();
        let p99 = lvl.at(&["latency_p99_ms"]).as_f64().unwrap();
        assert!(p50 > 0.0 && p99 >= p50);
        assert!(lvl.at(&["mean_occupancy"]).as_f64().unwrap() >= 1.0);
    }
    // Micro-batching must actually coalesce at the higher concurrency:
    // requests carry 2 samples and stage atomically, so batches hold
    // >= 2 real samples except the rare trailing fragment of a request
    // split at a full-batch boundary — the *mean* stays well above 1.
    let hi = &levels[1];
    assert!(
        hi.at(&["mean_occupancy"]).as_f64().unwrap() > 1.0,
        "no coalescing at 6 concurrent clients"
    );

    // Record at the repo root unless a release run already did.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serve.json");
    let has_release_numbers = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| parse(&t).ok())
        .and_then(|v| v.at(&["source"]).as_str().map(|s| s.contains("release")))
        .unwrap_or(false);
    if has_release_numbers {
        eprintln!("[smoke] BENCH_serve.json holds release numbers; leaving it alone");
    } else {
        std::fs::write(&path, report.to_string()).unwrap();
        assert!(path.exists());
        assert!(!std::fs::read_to_string(&path).unwrap().is_empty());
    }
}
