//! Tier-1 bench smoke: runs the host-vs-resident and prefetch
//! comparisons at reduced scale and records `BENCH_runtime.json` at the
//! repo root, so every verified checkout carries a perf snapshot even
//! when `cargo bench` never runs.  `benches/bench_runtime.rs` overwrites
//! the file with release-profile numbers — those are the canonical
//! record (debug timings here are only a smoke signal).

use std::path::PathBuf;

use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::perf;
use e2train::util::tmp::TempDir;

#[test]
fn bench_smoke_records_bench_runtime_json() {
    let tmp = TempDir::new().unwrap();
    let spec = RefFamilySpec::tiny();
    write_reference_family(tmp.path(), &spec).unwrap();
    let engine = Engine::cpu().unwrap();

    let mut steps = Vec::new();
    for method in ["sgd32", "e2train"] {
        let cmp =
            perf::compare_step_paths(&engine, tmp.path(), &spec.family, method, 3, 15)
                .unwrap();
        assert!(cmp.host_mean_s > 0.0 && cmp.resident_mean_s > 0.0);
        eprintln!(
            "[smoke] {method}: host/resident speedup {:.2}x",
            cmp.speedup()
        );
        steps.push(cmp);
    }
    let prefetch =
        perf::compare_prefetch(&engine, tmp.path(), &spec.family, "sgd32", 30).unwrap();
    assert!(prefetch.steps_per_sec_on > 0.0 && prefetch.steps_per_sec_off > 0.0);

    let report = perf::bench_report(
        "cargo-test smoke (debug profile)",
        &spec.family,
        &steps,
        &prefetch,
    );
    // repo root = <crate>/..
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_runtime.json");
    // Never clobber canonical release numbers (cargo bench) with debug
    // timings — only write when the file is absent or smoke-sourced.
    let has_release_numbers = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| e2train::util::json::parse(&t).ok())
        .and_then(|v| v.at(&["source"]).as_str().map(|s| s.contains("release")))
        .unwrap_or(false);
    if has_release_numbers {
        eprintln!("[smoke] BENCH_runtime.json holds release numbers; leaving it alone");
    } else {
        perf::write_bench_report(&path, &report).unwrap();
        assert!(path.exists());
    }
}
