//! CIFAR-bin streaming ingestion: `data::cifar::open` + the worker-side
//! `CifarFiles::decode` must produce byte-for-byte the dataset the old
//! eager whole-file loader produced, and a Trainer run whose prefetch
//! worker streams + decodes the binaries must be bitwise identical to a
//! run that eagerly loads them and samples synchronously.

use std::path::Path;

use e2train::config::{DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::data::{cifar, prefetch};
use e2train::runtime::{write_reference_family, Engine, RefFamilySpec};
use e2train::util::tmp::TempDir;

const REC: usize = 1 + 3072;
const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];

/// Deterministic pseudo-CIFAR binaries: 5 train files + 1 test file.
fn write_cifar_dir(dir: &Path, per_file: usize, test_records: usize) {
    let mut state = 0x1234_5678u32;
    let mut next = move || -> u8 {
        state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
        (state >> 24) as u8
    };
    let mut file = |n: usize| -> Vec<u8> {
        let mut bytes = Vec::with_capacity(n * REC);
        for _ in 0..n {
            bytes.push(next() % 10);
            for _ in 0..3072 {
                bytes.push(next());
            }
        }
        bytes
    };
    for i in 1..=5 {
        std::fs::write(dir.join(format!("data_batch_{i}.bin")), file(per_file)).unwrap();
    }
    std::fs::write(dir.join("test_batch.bin"), file(test_records)).unwrap();
}

/// The original eager decode algorithm, kept inline as ground truth so
/// the streaming loader is checked against an independent
/// implementation, not against itself.
fn eager_reference_decode(dir: &Path) -> (Vec<i32>, Vec<f32>) {
    let mut labels = Vec::new();
    let mut images = Vec::new();
    for i in 1..=5 {
        let bytes = std::fs::read(dir.join(format!("data_batch_{i}.bin"))).unwrap();
        for rec in bytes.chunks_exact(REC) {
            labels.push(rec[0] as i32);
            for y in 0..32 {
                for x in 0..32 {
                    for c in 0..3 {
                        let v = rec[1 + c * 1024 + y * 32 + x] as f32 / 255.0;
                        images.push((v - MEAN[c]) / STD[c]);
                    }
                }
            }
        }
    }
    (labels, images)
}

#[test]
fn streaming_decode_matches_eager_reference() {
    let dir = TempDir::new().unwrap();
    write_cifar_dir(dir.path(), 32, 24);

    let files = cifar::open(dir.path(), true).unwrap();
    assert_eq!(files.n, 160, "record count from metadata");
    let streamed = files.decode().unwrap();
    assert_eq!(streamed.n, 160);

    let (want_labels, want_images) = eager_reference_decode(dir.path());
    assert_eq!(streamed.labels, want_labels);
    assert_eq!(streamed.images, want_images, "streamed floats drifted");

    assert_eq!(cifar::open(dir.path(), false).unwrap().n, 24);
}

#[test]
fn deferred_prefetch_run_matches_eager_sync_run() {
    let data_dir = TempDir::new().unwrap();
    write_cifar_dir(data_dir.path(), 32, 24);

    // CIFAR needs a 32px/10-class artifact; generate a small 32px
    // reference family for it.
    let art = TempDir::new().unwrap();
    let spec = RefFamilySpec {
        family: "refmlp-c32".into(),
        hw: 32,
        hidden: 16,
        classes: 10,
        batch: 8,
        eval_batch: 16,
        gated_blocks: 4,
    };
    write_reference_family(art.path(), &spec).unwrap();
    let engine = Engine::cpu().unwrap();

    let run = |use_prefetch: bool| {
        let mut cfg = RunCfg::quick("refmlp-c32", "sgd32", 10);
        cfg.artifacts_dir = art.path().to_path_buf();
        cfg.data = DataCfg::CifarBin { dir: data_dir.path().to_path_buf() };
        cfg.prefetch = use_prefetch;
        cfg.eval_every = 4;
        Trainer::new(&engine, cfg).unwrap().run(None).unwrap()
    };

    let eager = run(false); // main-thread eager load + synchronous sampling
    let deferred = run(true); // worker streams + decodes the binaries

    // The deferred path skips the auto-tune probe (no decoded data on
    // the main thread) and keeps the classic double buffer.
    assert_eq!(deferred.metrics.prefetch_depth, Some(prefetch::DEFAULT_DEPTH));
    assert_eq!(eager.metrics.prefetch_depth, None);

    assert_eq!(eager.metrics.final_test_acc, deferred.metrics.final_test_acc);
    assert_eq!(eager.metrics.final_loss, deferred.metrics.final_loss);
    let la: Vec<f64> = eager.metrics.trace.iter().map(|p| p.loss).collect();
    let lb: Vec<f64> = deferred.metrics.trace.iter().map(|p| p.loss).collect();
    assert_eq!(la, lb, "per-step losses diverged between ingestion paths");
    let ea: Vec<Option<f64>> = eager.metrics.trace.iter().map(|p| p.test_acc).collect();
    let eb: Vec<Option<f64>> =
        deferred.metrics.trace.iter().map(|p| p.test_acc).collect();
    assert_eq!(ea, eb, "periodic evals diverged between ingestion paths");
    eager.state.assert_bitwise_eq(&deferred.state);
}

