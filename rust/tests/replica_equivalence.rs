//! Cross-failure-domain equivalence (reference backend, runs
//! everywhere): everything a replica serves back must be **bitwise**
//! what the local registry holds.
//!
//! 1. **Resume from the replica** — after the training box's local
//!    registry is destroyed, resuming from the evacuated copies (any
//!    boundary, via [`RemoteRegistry`]) replays to exactly the
//!    uninterrupted run's trace, ledger and final state.
//! 2. **Serve from the replica** — a serve fleet in another failure
//!    domain hot-loads the replica and answers with logits bitwise
//!    identical to a fleet on the training box's own registry.
//! 3. **Rejection** — truncated transfers and bit-flipped replica
//!    objects never decode: direct loads fail with the hash/trailer
//!    error, and the serve watcher refuses the hot-load, counts it in
//!    `ServeStats::hot_load_rejects`, and keeps its snapshot.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use e2train::checkpoint::{FsRemoteStore, RemoteRegistry, REMOTE_MANIFEST};
use e2train::config::{CkptCfg, DataCfg, RunCfg};
use e2train::coordinator::{RunOutcome, Trainer};
use e2train::data::{synthetic, Dataset};
use e2train::runtime::{
    write_reference_family, Engine, HostTensor, RefFamilySpec, SnapshotCell,
    StateSnapshot, TrainProgram,
};
use e2train::serve::{watch_registry, watch_replica, ServeCfg, ServeService};
use e2train::util::tmp::TempDir;

const FAM: &str = "refmlp-tiny";

fn ref_cfg(artifacts: &Path, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(FAM, "e2train", iters);
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.data = DataCfg::Synthetic { classes: 10, n_train: 128, n_test: 40, seed: 0 };
    cfg.eval_every = 8;
    cfg
}

/// A config that checkpoints into `dir` and evacuates to `replica`.
fn replicated_cfg(artifacts: &Path, dir: &Path, replica: &Path) -> RunCfg {
    let mut cfg = ref_cfg(artifacts, 18);
    cfg.checkpoint = CkptCfg {
        every: 6,
        dir: Some(dir.to_path_buf()),
        keep_last: 16,
        keep_every: 0,
        replicate: Some(replica.to_path_buf()),
        replica: None,
    };
    cfg
}

fn remote(root: &Path) -> RemoteRegistry {
    RemoteRegistry::new(Box::new(FsRemoteStore::new(root)))
}

/// Bitwise outcome comparison (everything inside the determinism
/// contract; wall time, prefetch depth and replication stats excluded).
fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, ctx: &str) {
    assert_eq!(a.metrics.final_test_acc, b.metrics.final_test_acc, "{ctx}: acc");
    assert_eq!(a.metrics.final_loss, b.metrics.final_loss, "{ctx}: loss");
    assert_eq!(a.metrics.total_joules, b.metrics.total_joules, "{ctx}: joules");
    assert_eq!(a.metrics.steps_run, b.metrics.steps_run, "{ctx}: steps");
    assert_eq!(
        a.metrics.steps_skipped, b.metrics.steps_skipped,
        "{ctx}: skipped"
    );
    assert_eq!(a.metrics.trace.len(), b.metrics.trace.len(), "{ctx}: trace len");
    for (x, y) in a.metrics.trace.iter().zip(b.metrics.trace.iter()) {
        assert_eq!(x.iter, y.iter, "{ctx}: trace iter");
        assert_eq!(x.loss, y.loss, "{ctx}: trace loss @{}", x.iter);
        assert_eq!(x.joules, y.joules, "{ctx}: trace joules @{}", x.iter);
        assert_eq!(x.test_acc, y.test_acc, "{ctx}: trace eval @{}", x.iter);
    }
    assert_eq!(a.ledger.steps_charged, b.ledger.steps_charged, "{ctx}: ledger");
    assert_eq!(a.ledger.macs, b.ledger.macs, "{ctx}: ledger macs");
    assert_eq!(a.ledger.trace, b.ledger.trace, "{ctx}: ledger trace");
    a.state.assert_bitwise_eq(&b.state);
}

/// Train a replicated run (registry under `reg`, evacuation into
/// `replica`) and hand back its outcome.
fn replicated_run(
    tmp: &TempDir,
    engine: &Engine,
    reg: &TempDir,
    replica: &TempDir,
) -> RunOutcome {
    let cfg =
        replicated_cfg(tmp.path(), &reg.path().join("ckpts"), replica.path());
    Trainer::new(engine, cfg).unwrap().run(None).unwrap()
}

// ---------------------------------------------------------------------
// 1. Resume from the replica
// ---------------------------------------------------------------------

/// Kill the local registry after a replicated run; resuming any
/// evacuated boundary from the replica replays bitwise to the
/// uninterrupted outcome — the "dead training box" recovery path.
#[test]
fn resume_from_replica_is_bitwise_identical() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();

    // Baseline: same training stream, checkpointing but no replication.
    let base_reg = TempDir::new().unwrap();
    let mut base_cfg = ref_cfg(tmp.path(), 18);
    base_cfg.checkpoint = CkptCfg {
        every: 6,
        dir: Some(base_reg.path().join("ckpts")),
        keep_last: 16,
        keep_every: 0,
        ..CkptCfg::default()
    };
    let baseline = Trainer::new(&engine, base_cfg).unwrap().run(None).unwrap();

    let reg = TempDir::new().unwrap();
    let replica = TempDir::new().unwrap();
    let replicated = replicated_run(&tmp, &engine, &reg, &replica);
    assert_outcomes_identical(&baseline, &replicated, "replication invisibility");

    // The training box dies: its registry is gone for good.
    std::fs::remove_dir_all(reg.path().join("ckpts")).unwrap();

    // Every evacuated boundary resumes bitwise from the replica alone.
    let remote = remote(replica.path());
    let iters: Vec<u64> =
        remote.entries().unwrap().iter().map(|e| e.iter).collect();
    assert_eq!(iters, vec![6, 12, 18], "expected every boundary evacuated");
    for iter in [6, 18] {
        let ckpt = remote.load_iter(iter).unwrap();
        let mut cfg = ckpt.cfg.clone();
        // The resumed box neither checkpoints nor replicates — both
        // knobs are outside the determinism fingerprint.
        cfg.checkpoint = CkptCfg::default();
        let out = Trainer::new(&engine, cfg).unwrap().resume(ckpt).unwrap();
        assert_outcomes_identical(
            &baseline,
            &out,
            &format!("resume from replica @{iter}"),
        );
    }
}

// ---------------------------------------------------------------------
// 2. Serve from the replica
// ---------------------------------------------------------------------

/// Per-sample logits ground truth: serially, through the same padded
/// batching `evaluate_full` uses (see tests/serve_equivalence.rs).
fn serial_rows(
    prog: &TrainProgram,
    snap: &StateSnapshot,
    data: &Dataset,
) -> Vec<Vec<f32>> {
    let eb = prog.eval_batch();
    let hw = data.hw;
    let stride = hw * hw * 3;
    let classes = prog.manifest.arch.num_classes;
    let mut rows = Vec::with_capacity(data.n);
    let nb = (data.n + eb - 1) / eb;
    for b in 0..nb {
        let lo = b * eb;
        let take = eb.min(data.n - lo);
        let mut px = vec![0f32; eb * stride];
        px[..take * stride]
            .copy_from_slice(&data.images[lo * stride..(lo + take) * stride]);
        let mut py = vec![-1i32; eb];
        py[..take].copy_from_slice(&data.labels[lo..lo + take]);
        let out = prog
            .eval_batch_snapshot(
                snap,
                &HostTensor::f32(vec![eb, hw, hw, 3], px),
                &HostTensor::i32(vec![eb], py),
            )
            .unwrap();
        let logits = out.logits.expect("reference eval emits logits");
        let lv = logits.as_f32().unwrap();
        for i in 0..take {
            rows.push(lv[i * classes..(i + 1) * classes].to_vec());
        }
    }
    rows
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn wait_version(cell: &SnapshotCell, what: &str) {
    let t0 = Instant::now();
    while cell.version() == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "{what}: watcher never hot-loaded"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Two watchers — one on the local registry, one on the replica root —
/// publish the same snapshot bit for bit, and a service answering from
/// the replica-fed cell serves logits bitwise identical to the
/// local-registry ground truth.
#[test]
fn serve_from_replica_matches_local_registry_serving() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let reg = TempDir::new().unwrap();
    let replica = TempDir::new().unwrap();
    replicated_run(&tmp, &engine, &reg, &replica);

    let manifest = fam.join("e2train.json");
    let prog = TrainProgram::load(&engine, &manifest).unwrap();
    let spec = Arc::new(prog.manifest.state_spec());

    let cell_local = Arc::new(SnapshotCell::new());
    let _wl = watch_registry(
        cell_local.clone(),
        prog.backend(),
        spec.clone(),
        &reg.path().join("ckpts"),
        Duration::from_millis(5),
    );
    let cell_replica = Arc::new(SnapshotCell::new());
    let _wr = watch_replica(
        cell_replica.clone(),
        prog.backend(),
        spec.clone(),
        replica.path(),
        Duration::from_millis(5),
    );
    wait_version(&cell_local, "local");
    wait_version(&cell_replica, "replica");

    let data = synthetic::generate(
        10,
        prog.eval_batch() + 3,
        prog.manifest.arch.image_size,
        7,
    );
    let local_rows = serial_rows(&prog, &cell_local.load().unwrap(), &data);
    let replica_rows = serial_rows(&prog, &cell_replica.load().unwrap(), &data);
    for (i, (a, b)) in local_rows.iter().zip(replica_rows.iter()).enumerate() {
        assert_eq!(bits(a), bits(b), "sample {i}: replica snapshot differs");
    }

    // End to end: a service on the replica-fed cell answers with the
    // local ground truth, bit for bit.
    let service = ServeService::start(
        &engine,
        &manifest,
        cell_replica.clone(),
        ServeCfg { workers: 2, ..Default::default() },
    )
    .unwrap();
    let client = service.client();
    let stride = data.hw * data.hw * 3;
    for i in 0..data.n {
        let got = client
            .submit(&data.images[i * stride..(i + 1) * stride], &[data.labels[i]])
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(
            bits(&got[0].logits),
            bits(&local_rows[i]),
            "sample {i}: served-from-replica logits differ"
        );
    }
    service.shutdown();
}

// ---------------------------------------------------------------------
// 3. Corrupt / truncated replicas are rejected
// ---------------------------------------------------------------------

/// Direct loads: truncations at several cut points and a mid-file
/// bit-flip all fail verification — never a silently-wrong resume.
#[test]
fn corrupt_or_truncated_replica_objects_fail_to_load() {
    let tmp = TempDir::new().unwrap();
    write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let reg = TempDir::new().unwrap();
    let replica = TempDir::new().unwrap();
    replicated_run(&tmp, &engine, &reg, &replica);

    let rr = remote(replica.path());
    let entry = rr.latest().unwrap().expect("replica populated");
    let obj = replica.path().join(&entry.file);
    let good = std::fs::read(&obj).unwrap();
    assert_eq!(good.len() as u64, entry.bytes);

    // Truncated transfers at representative cut points.
    for cut in [0usize, 10, good.len() / 3, good.len() - 1] {
        std::fs::write(&obj, &good[..cut]).unwrap();
        let err = rr.load(&entry).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("truncated or replica corrupt"),
            "cut {cut}: wrong error: {msg}"
        );
    }

    // A single flipped byte (same length) must fail too.
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    std::fs::write(&obj, &flipped).unwrap();
    assert!(rr.load(&entry).is_err(), "bit-flip decoded");

    // A torn remote manifest is an error on the pull side (the caller's
    // retry loop absorbs it) — not an empty listing.
    std::fs::write(replica.path().join(REMOTE_MANIFEST), b"{\"schema\": \"ckpt_reg")
        .unwrap();
    assert!(rr.entries().is_err(), "torn manifest read as a listing");

    // Intact bytes load again (the entry in hand needs no manifest).
    std::fs::write(&obj, &good).unwrap();
    assert_eq!(rr.load(&entry).unwrap().iter, entry.iter);
}

/// Watcher-level rejection: a bit-flipped newest replica object is
/// refused by the hot-load integrity gate, counted in
/// `ServeStats::hot_load_rejects`, and the snapshot cell stays empty.
#[test]
fn serve_watcher_rejects_corrupt_replica_and_counts_it() {
    let tmp = TempDir::new().unwrap();
    let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
    let engine = Engine::cpu().unwrap();
    let reg = TempDir::new().unwrap();
    let replica = TempDir::new().unwrap();
    replicated_run(&tmp, &engine, &reg, &replica);

    // Flip one payload byte of the newest evacuated checkpoint.
    let rr = remote(replica.path());
    let entry = rr.latest().unwrap().expect("replica populated");
    let obj = replica.path().join(&entry.file);
    let mut bytes = std::fs::read(&obj).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&obj, &bytes).unwrap();

    let cell = Arc::new(SnapshotCell::new());
    let service = ServeService::start(
        &engine,
        &fam.join("e2train.json"),
        cell.clone(),
        ServeCfg::default(),
    )
    .unwrap();
    let _w = service.watch_replica(replica.path(), Duration::from_millis(5));

    let t0 = Instant::now();
    loop {
        let stats = service.stats();
        if stats.hot_load_rejects >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "corrupt replica checkpoint was never rejected"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    // The reject is terminal for that checkpoint: nothing was admitted.
    std::thread::sleep(Duration::from_millis(30));
    assert_eq!(cell.version(), 0, "corrupt checkpoint was hot-loaded");
    service.shutdown();
}
