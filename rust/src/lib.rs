//! # E²-Train — energy-efficient CNN training (NeurIPS 2019 reproduction)
//!
//! A three-layer system: this rust crate is the Layer-3 coordinator that
//! owns the training loop, data pipeline, energy accounting and all
//! experiment harnesses; Layer-2 (JAX model fwd/bwd) and Layer-1 (Pallas
//! kernels) are compiled ahead-of-time by `python/compile/` into HLO-text
//! artifacts that the [`runtime`] executes via PJRT.  Python never runs
//! on the training path.
//!
//! The paper's three techniques map to:
//! * **SMD** (stochastic mini-batch dropping) — [`coordinator::smd`]
//! * **SLU** (selective layer update) — learned gates inside the AOT
//!   train step + per-block accounting in [`energy`]
//! * **PSG** (predictive sign gradient) — the Pallas `psg_select` kernel
//!   baked into the `psg`/`e2train` artifacts + datapath-width modelling
//!   in [`energy::model`]

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod util;
