//! Supervised recovery: the retry loop around a training run.
//!
//! [`Trainer::run_supervised`] wraps the ordinary run in a supervisor
//! that classifies failures **transient vs fatal**, restores from the
//! latest readable registry checkpoint, and retries with bounded,
//! exponentially backed-off delays.  Because a resumed run is bitwise
//! identical to the run that never stopped (tests/resume_equivalence.rs)
//! and the sharded backend additionally recovers failed shards in place
//! (`runtime::shard`), a supervised run that survives its faults ends
//! **bitwise identical** — trace, energy ledger, final state — to a
//! fault-free run of the same config (tests/fault_matrix.rs).  The only
//! observable differences live outside the determinism contract:
//! `RunMetrics::recoveries` and the wall clock.
//!
//! Classification is deliberately conservative: injected faults
//! (`util::fault`) and unrecognized errors are transient — a crashed
//! worker, a torn manifest read, a failed checkpoint write are all
//! things a restart can outlive.  Fatal is reserved for errors a retry
//! provably cannot fix: a checkpoint whose config fingerprint or state
//! spec contradicts this run, or a checkpoint past the run's horizon.
//! Those fail fast with the original error.
//!
//! Backoff is deterministic: delays derive from a seeded
//! [`Rng`](crate::util::rng::Rng) (run seed ⊕ fault seed), so a
//! supervised run's retry timing — like everything else in the repo —
//! replays exactly.

use std::time::Duration;

use anyhow::Result;

use crate::checkpoint::{
    CheckpointData, CheckpointRegistry, FsRemoteStore, RemoteRegistry, RetentionCfg,
};
use crate::config::RunCfg;
use crate::util::fault::{injected_site, is_injected, FaultPlan};
use crate::util::rng::Rng;

use super::trainer::{RunOutcome, Trainer};

/// Whether a failed attempt is worth retrying.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// A restart from the latest checkpoint can outlive this.
    Transient,
    /// Retrying reproduces the same failure — surface it now.
    Fatal,
}

/// Error messages that no retry can fix: configuration/artifact
/// contradictions detected at resume time ([`Trainer::resume`] and its
/// state-spec check).  Matched against the full context chain.
const FATAL_MARKERS: &[&str] = &[
    // checkpoint fingerprint != this run's determinism fingerprint
    "does not match this run's",
    // checkpoint tensors vs the artifact's state spec
    "do not match artifact",
    "does not match the artifact",
    // checkpoint past the configured horizon
    "but the run is configured for",
];

/// Classify one failed attempt.  Injected faults are transient by
/// construction; config/artifact contradictions are fatal; everything
/// else defaults to transient (a retry against a crashed worker or a
/// flaky disk is cheap, and the retry budget bounds the damage).
pub fn classify(err: &anyhow::Error) -> Severity {
    if is_injected(err) {
        return Severity::Transient;
    }
    let msg = format!("{err:#}");
    if FATAL_MARKERS.iter().any(|m| msg.contains(m)) {
        return Severity::Fatal;
    }
    Severity::Transient
}

/// Deterministic exponential backoff: attempt `k` waits
/// `base << min(k, 6)` ms plus a seeded jitter in `[0, base]` ms.
struct Backoff {
    rng: Rng,
    base_ms: u64,
    k: u32,
}

impl Backoff {
    fn new(base_ms: u64, seed: u64) -> Self {
        Self { rng: Rng::seed_from_u64(seed), base_ms: base_ms.max(1), k: 0 }
    }

    fn next_delay(&mut self) -> Duration {
        let exp = self.base_ms << self.k.min(6);
        self.k += 1;
        let jitter = self.rng.below(self.base_ms as usize + 1) as u64;
        Duration::from_millis(exp + jitter)
    }
}

/// The newest checkpoint this run can restore from — the recovery
/// ladder: **local registry → replica → fresh**.  The local registry is
/// walked newest→oldest, *skipping* checkpoints that fail to load
/// (truncated file, hash mismatch) — one corrupt checkpoint costs
/// `checkpoint.every` replayed steps, not the run.  When nothing local
/// is readable and `checkpoint.replica` names a replica root, the same
/// walk runs against the remote registry (fetch-and-verify through
/// [`RemoteRegistry`], cached next to the local registry when there is
/// one) — so a box that lost its whole disk resumes from the evacuated
/// copies.  `None` when neither rung holds anything readable (the
/// supervisor then restarts from scratch, which is equally
/// deterministic).  A torn *manifest* read — local or remote — and a
/// transient replica read error propagate as errors: they are
/// themselves transient faults the caller's retry loop absorbs with its
/// deterministic capped backoff.
fn latest_restore_point(
    cfg: &RunCfg,
    faults: Option<&std::sync::Arc<FaultPlan>>,
) -> Result<Option<CheckpointData>> {
    if cfg.checkpoint.every > 0 {
        if let Some(dir) = cfg.checkpoint.dir.clone() {
            let mut registry = CheckpointRegistry::new(
                dir,
                RetentionCfg {
                    keep_last: cfg.checkpoint.keep_last,
                    keep_every: cfg.checkpoint.keep_every,
                },
            );
            if let Some(p) = faults {
                registry = registry.with_faults(p.clone());
            }
            for entry in registry.entries()?.iter().rev() {
                match registry.load(entry) {
                    Ok(data) => return Ok(Some(data)),
                    Err(e) => eprintln!(
                        "[supervise] checkpoint {} unreadable ({e:#}); trying an older one",
                        entry.file
                    ),
                }
            }
        }
    }
    if let Some(root) = &cfg.checkpoint.replica {
        let mut store = FsRemoteStore::new(root);
        if let Some(p) = faults {
            store = store.with_faults(p.clone());
        }
        let mut remote = RemoteRegistry::new(Box::new(store));
        if let Some(dir) = &cfg.checkpoint.dir {
            remote = remote.with_cache(dir.join(".replica-cache"));
        }
        for entry in remote.entries()?.iter().rev() {
            match remote.load(entry) {
                Ok(data) => {
                    eprintln!(
                        "[supervise] local registry empty; restoring iter {} from \
                         replica {}",
                        data.iter,
                        remote.describe()
                    );
                    return Ok(Some(data));
                }
                Err(e) => eprintln!(
                    "[supervise] replica checkpoint {} unreadable ({e:#}); trying an \
                     older one",
                    entry.file
                ),
            }
        }
    }
    Ok(None)
}

impl Trainer<'_> {
    /// Run under supervision: on a transient failure, restore from the
    /// latest readable checkpoint (or restart from scratch when none
    /// exists) and retry, up to `cfg.faults.max_retries` recoveries with
    /// deterministic exponential backoff.  Fatal errors — a checkpoint
    /// whose fingerprint or state spec contradicts this run — fail fast.
    ///
    /// The fault plan comes from `cfg.faults` (seeded by the run seed);
    /// a plan already armed via [`Trainer::set_faults`] is reused
    /// instead, so tests can hold the handle and assert firings.  The
    /// plan's hit counters live across attempts — an injected fault
    /// with `times: 1` stays spent after the restart, which is what
    /// makes recovery convergent.
    pub fn run_supervised(&mut self) -> Result<RunOutcome> {
        let plan = match self.faults() {
            Some(p) => p,
            None => {
                let p = FaultPlan::from_cfg(&self.cfg.faults, self.cfg.seed)?;
                self.set_faults(p.clone());
                p
            }
        };
        let max_retries = self.cfg.faults.max_retries;
        let mut backoff = Backoff::new(
            self.cfg.faults.backoff_ms,
            self.cfg.seed ^ self.cfg.faults.seed ^ 0xb0ff,
        );
        let mut failures: u64 = 0;
        loop {
            let attempt = match latest_restore_point(&self.cfg, Some(&plan)) {
                Ok(Some(ckpt)) => {
                    if failures > 0 {
                        eprintln!(
                            "[supervise] restoring from checkpoint iter {}",
                            ckpt.iter
                        );
                    }
                    self.resume(ckpt)
                }
                Ok(None) => self.run(None),
                Err(e) => Err(e),
            };
            let err = match attempt {
                Ok(mut out) => {
                    out.metrics.recoveries = failures;
                    return Ok(out);
                }
                Err(e) => e,
            };
            if classify(&err) == Severity::Fatal {
                return Err(err.context("supervised run hit a fatal (non-retryable) error"));
            }
            failures += 1;
            if failures > max_retries {
                return Err(err.context(format!(
                    "supervised run retry budget exhausted ({max_retries} retries)"
                )));
            }
            let delay = backoff.next_delay();
            self.obs().recovery(
                injected_site(&err).unwrap_or("unknown"),
                failures,
                delay.as_millis() as u64,
            );
            eprintln!(
                "[supervise] attempt {failures} failed ({err:#}); retrying from the \
                 latest checkpoint in {}ms",
                delay.as_millis()
            );
            std::thread::sleep(delay);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;
    use crate::util::fault::{self, InjectedFault};

    #[test]
    fn classification_rules() {
        let injected = anyhow::Error::new(InjectedFault::new(fault::SITE_TRAIN_STEP))
            .context("step 7 failed");
        assert_eq!(classify(&injected), Severity::Transient);

        let fatal = anyhow!(
            "checkpoint fingerprint deadbeef does not match this run's cafebabe"
        );
        assert_eq!(classify(&fatal), Severity::Fatal);
        let fatal2 = anyhow!("checkpoint is at iter 40 but the run is configured for 20 iters");
        assert_eq!(classify(&fatal2), Severity::Fatal);

        // unknown errors default to transient (the budget bounds them)
        assert_eq!(classify(&anyhow!("disk fell over")), Severity::Transient);
    }

    #[test]
    fn backoff_is_deterministic_and_monotonic_in_exponent() {
        let delays = |seed| {
            let mut b = Backoff::new(10, seed);
            (0..9).map(|_| b.next_delay().as_millis() as u64).collect::<Vec<_>>()
        };
        let a = delays(7);
        assert_eq!(a, delays(7), "same seed must replay the same delays");
        assert_ne!(a, delays(8), "different seed should jitter differently");
        for (k, d) in a.iter().enumerate() {
            let exp = 10u64 << (k as u32).min(6);
            assert!(*d >= exp && *d <= exp + 10, "attempt {k}: {d}ms out of range");
        }
        // the shift saturates at 6 so delays stay bounded
        assert!(a[8] <= (10 << 6) + 10);
    }
}
