//! Stochastic depth baseline [66] — the "random version of SLU" the
//! paper compares against (Sec. 4.3).
//!
//! Per mini-batch, each gateable block survives with a probability that
//! decays linearly with depth from 1.0 to `p_l`; a dropped block is
//! skipped in both passes (the coordinator feeds the sampled mask into
//! the `sd` artifact's `mask` input).  `calibrated(target)` solves for
//! the p_l giving a requested mean drop ratio, which is how the paper
//! matches SD's dropping ratio to SLU's for a fair comparison.

use crate::util::Rng;

/// Exported scheduler position (`checkpoint` subsystem).  The survival
/// curve is derived from `(num_blocks, p_l)` — config, not state — so
/// only the RNG stream needs capturing.
#[derive(Debug, Clone, PartialEq)]
pub struct SdState {
    pub rng: [u64; 4],
}

pub struct SdScheduler {
    rng: Rng,
    survival: Vec<f64>,
}

impl SdScheduler {
    /// Linear-decay survival over `num_blocks` gateable blocks.
    pub fn new(num_blocks: usize, p_l: f64, seed: u64) -> Self {
        let survival = (0..num_blocks)
            .map(|i| {
                let frac = (i + 1) as f64 / num_blocks.max(1) as f64;
                1.0 - frac * (1.0 - p_l)
            })
            .collect();
        Self { rng: Rng::seed_from_u64(seed), survival }
    }

    /// p_l such that the *mean* survival equals `mean_active` — matches
    /// SD's drop ratio to a measured SLU skipping ratio.
    pub fn calibrated(num_blocks: usize, mean_active: f64, seed: u64) -> Self {
        // mean survival of linear decay = 1 - (1-p_l)*(n+1)/(2n)
        let n = num_blocks.max(1) as f64;
        let p_l = 1.0 - (1.0 - mean_active) * 2.0 * n / (n + 1.0);
        Self::new(num_blocks, p_l.clamp(0.0, 1.0), seed)
    }

    /// Export the stream position for a checkpoint.
    pub fn export(&self) -> SdState {
        SdState { rng: self.rng.state() }
    }

    /// Rebuild mid-stream with the schedule re-derived from config;
    /// `None` for a corrupt (all-zero) RNG state.
    pub fn restore(num_blocks: usize, p_l: f64, st: &SdState) -> Option<Self> {
        let mut s = Self::new(num_blocks, p_l, 0);
        s.rng = Rng::from_state(st.rng)?;
        Some(s)
    }

    /// Sample a per-block {0,1} mask for one mini-batch.
    pub fn sample(&mut self) -> Vec<f32> {
        self.survival
            .iter()
            .map(|&p| if self.rng.bool(p) { 1.0 } else { 0.0 })
            .collect()
    }

    pub fn mean_survival(&self) -> f64 {
        self.survival.iter().sum::<f64>() / self.survival.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_decay_shape() {
        let s = SdScheduler::new(4, 0.5, 0);
        assert!((s.survival[0] - 0.875).abs() < 1e-12);
        assert!((s.survival[3] - 0.5).abs() < 1e-12);
        assert!(s.survival.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn sample_respects_probabilities() {
        let mut s = SdScheduler::new(3, 0.2, 11);
        let mut counts = [0f64; 3];
        let trials = 20_000;
        for _ in 0..trials {
            for (c, v) in counts.iter_mut().zip(s.sample()) {
                *c += v as f64;
            }
        }
        for (i, c) in counts.iter().enumerate() {
            let emp = c / trials as f64;
            assert!((emp - s.survival[i]).abs() < 0.02, "block {i}: {emp}");
        }
    }

    #[test]
    fn calibration_hits_target() {
        // Feasible targets: mean survival of a clamped linear decay is at
        // least (n-1)/(2n), so targets must sit above that floor.
        for target in [0.5, 0.6, 0.8, 0.95] {
            let s = SdScheduler::calibrated(9, target, 0);
            assert!((s.mean_survival() - target).abs() < 1e-9, "{target}");
        }
    }

    #[test]
    fn calibration_clamps() {
        let s = SdScheduler::calibrated(3, 0.05, 0);
        assert!(s.survival.iter().all(|p| (0.0..=1.0).contains(p)));
    }
}
