//! Layer-3 coordinator — the paper's training *system*.
//!
//! * [`smd`] — stochastic mini-batch dropping (data level, Sec. 3.1)
//! * [`sd`] — stochastic-depth baseline scheduler [66] (Sec. 4.3)
//! * [`planner`] — the planning layer: `backend = "auto"` resolves into
//!   a concrete layout against the calibrated cost catalog
//!   (`obs::catalog`), with predicted-vs-actual accounting per run.
//! * [`trainer`] — the orchestrated step loop: sampling, SMD, SD masks,
//!   AOT step execution, SWA, energy charging, eval, metrics.
//! * [`supervisor`] — supervised recovery: transient-vs-fatal error
//!   classification, restore-from-latest-checkpoint, bounded retries
//!   with deterministic backoff ([`Trainer::run_supervised`]).
//!
//! SLU and PSG live inside the AOT artifacts (the gates and the
//! psg_select kernel are part of the lowered train step); the coordinator
//! consumes their per-step telemetry (`gate_fracs`, `psg_frac`) to charge
//! the energy ledger — mirroring how the paper's FPGA measurements
//! attribute savings.

pub mod planner;
pub mod sd;
pub mod smd;
pub mod supervisor;
pub mod trainer;

pub use sd::{SdScheduler, SdState};
pub use smd::{SmdScheduler, SmdState};
pub use supervisor::Severity;
pub use trainer::{RunOutcome, Trainer};
