//! Stochastic mini-batch dropping (Sec. 3.1) — the data-level knob.
//!
//! At each iteration the scheduler decides, with probability `p`
//! (default 0.5), to skip the mini-batch entirely: no forward, no
//! backward, no energy.  All other protocol (LR schedule indexed by the
//! *iteration counter*, not by executed steps) is unchanged, exactly as
//! the paper specifies.

use crate::util::Rng;

/// Exported scheduler position (`checkpoint` subsystem): the RNG stream
/// plus the drop counters, so a restored scheduler continues the exact
/// skip/keep sequence *and* reports the same observed drop rate.
#[derive(Debug, Clone, PartialEq)]
pub struct SmdState {
    pub rng: [u64; 4],
    pub skipped: u64,
    pub seen: u64,
}

pub struct SmdScheduler {
    rng: Rng,
    pub p: f64,
    pub enabled: bool,
    skipped: u64,
    seen: u64,
}

impl SmdScheduler {
    pub fn new(enabled: bool, p: f64, seed: u64) -> Self {
        Self { rng: Rng::seed_from_u64(seed), p, enabled, skipped: 0, seen: 0 }
    }

    /// Export the stream position for a checkpoint.
    pub fn export(&self) -> SmdState {
        SmdState { rng: self.rng.state(), skipped: self.skipped, seen: self.seen }
    }

    /// Rebuild mid-stream; `None` for a corrupt (all-zero) RNG state or
    /// counters that contradict each other.
    pub fn restore(enabled: bool, p: f64, st: &SmdState) -> Option<Self> {
        if st.skipped > st.seen {
            return None;
        }
        Some(Self {
            rng: Rng::from_state(st.rng)?,
            p,
            enabled,
            skipped: st.skipped,
            seen: st.seen,
        })
    }

    /// Should this iteration's mini-batch be dropped?
    pub fn skip(&mut self) -> bool {
        self.seen += 1;
        if !self.enabled {
            return false;
        }
        let s = self.rng.bool(self.p);
        if s {
            self.skipped += 1;
        }
        s
    }

    /// Fraction of iterations dropped so far.
    pub fn observed_drop_rate(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.skipped as f64 / self.seen as f64
        }
    }

    /// Expected energy ratio vs. running every iteration: SMD with drop
    /// probability p for T iters consumes (1-p)·T steps of energy.
    pub fn expected_energy_ratio(&self) -> f64 {
        if self.enabled {
            1.0 - self.p
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_never_skips() {
        let mut s = SmdScheduler::new(false, 0.5, 0);
        assert!((0..100).all(|_| !s.skip()));
        assert_eq!(s.observed_drop_rate(), 0.0);
    }

    #[test]
    fn drop_rate_approaches_p() {
        let mut s = SmdScheduler::new(true, 0.5, 42);
        for _ in 0..10_000 {
            s.skip();
        }
        assert!((s.observed_drop_rate() - 0.5).abs() < 0.02);
        assert_eq!(s.expected_energy_ratio(), 0.5);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = SmdScheduler::new(true, 0.5, 7);
        let mut b = SmdScheduler::new(true, 0.5, 7);
        let va: Vec<bool> = (0..64).map(|_| a.skip()).collect();
        let vb: Vec<bool> = (0..64).map(|_| b.skip()).collect();
        assert_eq!(va, vb);
    }

    #[test]
    fn arbitrary_p() {
        let mut s = SmdScheduler::new(true, 0.25, 3);
        for _ in 0..20_000 {
            s.skip();
        }
        assert!((s.observed_drop_rate() - 0.25).abs() < 0.02);
    }
}
