//! The training orchestrator: one `Trainer` owns a run end-to-end —
//! artifact loading, state init, data pipeline, the step loop with
//! SMD/SD/SWA hooks, per-step energy charging, eval, and metrics.
//!
//! Everything here is rust; the only compute delegated outwards is the
//! AOT train/eval executable (PJRT CPU).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{DataCfg, RunCfg};
use crate::data::{cifar, synthetic, AugmentCfg, Dataset, Sampler};
use crate::energy::{EnergyLedger, EnergyModel};
use crate::metrics::{Mean, RunMetrics};
use crate::optim::SwaState;
use crate::runtime::{Engine, HostTensor, ModelState, StepHyper, TrainProgram};

use super::sd::SdScheduler;
use super::smd::SmdScheduler;

/// Outcome of a full run (metrics + the final state for reuse, e.g. the
/// fine-tuning experiment).
pub struct RunOutcome {
    pub metrics: RunMetrics,
    pub state: ModelState,
    pub ledger: EnergyLedger,
}

pub struct Trainer<'e> {
    engine: &'e Engine,
    pub cfg: RunCfg,
    pub program: TrainProgram,
    pub energy: EnergyModel,
    train_set: Dataset,
    test_set: Dataset,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: RunCfg) -> Result<Self> {
        let program = TrainProgram::load(engine, &cfg.manifest_path())?;
        let energy = EnergyModel::from_manifest(&program.manifest);
        let (train_set, test_set) = Self::load_data(&cfg, &program)?;
        Ok(Self { engine, cfg, program, energy, train_set, test_set })
    }

    fn load_data(cfg: &RunCfg, program: &TrainProgram) -> Result<(Dataset, Dataset)> {
        let hw = program.manifest.arch.image_size;
        let classes = program.manifest.arch.num_classes;
        match &cfg.data {
            DataCfg::Synthetic { classes: c, n_train, n_test, seed } => {
                if *c != classes {
                    return Err(anyhow!(
                        "config classes {} != artifact classes {}",
                        c,
                        classes
                    ));
                }
                Ok(synthetic::generate_split(
                    classes, *n_train, *n_test, hw, *seed,
                ))
            }
            DataCfg::CifarBin { dir } => {
                if hw != 32 || classes != 10 {
                    return Err(anyhow!("CIFAR binaries need a 32px/10-class artifact"));
                }
                Ok((cifar::load(dir, true)?, cifar::load(dir, false)?))
            }
        }
    }

    /// Replace the datasets (fine-tuning experiment, Sec. 4.5).
    pub fn set_data(&mut self, train: Dataset, test: Dataset) {
        self.train_set = train;
        self.test_set = test;
    }

    /// Run the configured number of iterations starting from a fresh
    /// init (or from `from_state` when resuming / fine-tuning).
    pub fn run(&mut self, from_state: Option<ModelState>) -> Result<RunOutcome> {
        let t0 = Instant::now();
        let m = &self.program.manifest;
        let mut state = match from_state {
            // Name-based migration handles method changes (e.g. resuming
            // a sgd32-pretrained trunk under e2train, which adds gates).
            Some(s) => ModelState::init_from(m, self.cfg.seed, &s),
            None => ModelState::init(m, self.cfg.seed),
        };
        let mut sampler = Sampler::new(
            self.train_set.n,
            self.program.batch(),
            AugmentCfg::default(),
            self.cfg.seed ^ 0xda7a,
        );
        let mut smd =
            SmdScheduler::new(self.cfg.smd.enabled, self.cfg.smd.p, self.cfg.seed ^ 0x50d);
        let num_gated = m.num_gated();
        let mut sd = SdScheduler::new(num_gated, self.cfg.sd.p_l, self.cfg.seed ^ 0x5d);
        let needs_mask = m.method.gating == "mask";

        let mut swa = SwaState::new(self.cfg.iters / 2, (self.cfg.iters / 20).max(1));
        let mut swa_model: Option<ModelState> = None;

        let mut ledger = EnergyLedger::default();
        let mut metrics = RunMetrics::default();
        let mut gate_means: Vec<Mean> = vec![Mean::default(); num_gated];
        let mut psg_mean = Mean::default();
        let record_every = (self.cfg.iters / 50).max(1);

        for iter in 0..self.cfg.iters {
            let lr = self.cfg.lr.at(iter) as f32;
            if smd.skip() {
                // SMD: the batch is consumed (sampling with limited
                // replacement, Sec. 3.1) but never executed or charged.
                let _ = sampler.next_batch(&self.train_set);
                ledger.skip();
                continue;
            }
            let (x, y) = sampler.next_batch(&self.train_set);
            let mask = if needs_mask { Some(sd.sample()) } else { None };
            let hp = StepHyper {
                lr,
                alpha: self.cfg.alpha as f32,
                beta: self.cfg.beta as f32,
            };
            let sm = self.program.step(&mut state, &x, &y, hp, mask.as_deref())?;

            // Energy: SD masks are per-batch gate fractions too.
            let fracs: Vec<f64> = if !sm.gate_fracs.is_empty() {
                sm.gate_fracs.clone()
            } else if let Some(mk) = &mask {
                mk.iter().map(|&v| v as f64).collect()
            } else {
                vec![]
            };
            let e = self.energy.train_step(&m.method, &fracs, sm.psg_frac);
            ledger.charge(iter, &e, self.energy.step_macs(&fracs));

            for (g, f) in gate_means.iter_mut().zip(fracs.iter()) {
                g.push(*f);
            }
            if let Some(p) = sm.psg_frac {
                psg_mean.push(p);
            }

            // SWA (enabled for PSG-family runs, Sec. 4.1).
            if self.cfg.swa && swa.should_average(iter) {
                let w = swa.observe();
                match &mut swa_model {
                    None => swa_model = Some(state.clone()),
                    Some(sw) => {
                        sw.average_params_from(&state, w, self.program.num_params)
                    }
                }
            }

            if iter % record_every == 0 || iter + 1 == self.cfg.iters {
                let train_acc = sm.correct / self.program.batch() as f64;
                let test_acc = if self.cfg.eval_every > 0
                    && iter % self.cfg.eval_every == 0
                {
                    Some(self.evaluate(&state)?.0)
                } else {
                    None
                };
                metrics.record(iter, sm.loss, train_acc, ledger.total_joules(), test_acc);
            }
        }

        // Final evaluation — SWA weights if averaging ran.
        let final_state = swa_model.unwrap_or_else(|| state.clone());
        let (acc, acc5, loss) = self.evaluate_full(&final_state)?;
        metrics.final_test_acc = acc;
        metrics.final_test_acc_top5 = acc5;
        metrics.final_loss = loss;
        metrics.total_joules = ledger.total_joules();
        metrics.executed_macs = ledger.macs;
        metrics.steps_run = ledger.steps_charged;
        metrics.steps_skipped = ledger.steps_skipped;
        metrics.wall_seconds = t0.elapsed().as_secs_f64();
        metrics.mean_gate_fracs = gate_means.iter().map(|g| g.get()).collect();
        metrics.mean_psg_frac =
            if psg_mean.count() > 0 { Some(psg_mean.get()) } else { None };

        eprintln!(
            "[run] {}/{}: acc {:.4}, {:.2} J, {} steps ({} skipped), {:.1}s",
            self.cfg.family,
            self.cfg.method,
            acc,
            metrics.total_joules,
            metrics.steps_run,
            metrics.steps_skipped,
            metrics.wall_seconds
        );
        Ok(RunOutcome { metrics, state: final_state, ledger })
    }

    fn evaluate(&self, state: &ModelState) -> Result<(f64, f64)> {
        let (acc, acc5, _) = self.evaluate_full(state)?;
        Ok((acc, acc5))
    }

    /// Accuracy over the full test set in eval_batch chunks.
    pub fn evaluate_full(&self, state: &ModelState) -> Result<(f64, f64, f64)> {
        let eb = self.program.eval_batch();
        let hw = self.test_set.hw;
        let stride = hw * hw * 3;
        let mut correct = 0.0;
        let mut correct5 = 0.0;
        let mut loss = 0.0;
        let mut total = 0usize;
        let nb = self.test_set.n / eb;
        for b in 0..nb.max(1).min(self.test_set.n / eb.min(self.test_set.n).max(1)) {
            let lo = b * eb;
            if lo + eb > self.test_set.n {
                break;
            }
            let x = HostTensor::f32(
                vec![eb, hw, hw, 3],
                self.test_set.images[lo * stride..(lo + eb) * stride].to_vec(),
            );
            let y = HostTensor::i32(
                vec![eb],
                self.test_set.labels[lo..lo + eb].to_vec(),
            );
            let em = self.program.eval_batch_run(state, &x, &y)?;
            correct += em.correct;
            correct5 += em.correct5;
            loss += em.loss * eb as f64;
            total += eb;
        }
        if total == 0 {
            return Err(anyhow!("test set smaller than eval batch"));
        }
        Ok((
            correct / total as f64,
            correct5 / total as f64,
            loss / total as f64,
        ))
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }
}
