//! The training orchestrator: one `Trainer` owns a run end-to-end —
//! artifact loading, state init, data pipeline, the step loop with
//! SMD/SD/SWA hooks, per-step energy charging, eval, and metrics.
//!
//! The step loop is buffer-resident and overlapped by default:
//!
//! * model state lives in a [`DeviceState`] across steps (only metric
//!   outputs sync to host each iteration; `sync_to_host` runs only for
//!   SWA snapshots / fine-tune handoff / end-of-run);
//! * batch assembly + augmentation run on a background prefetch thread
//!   behind a bounded channel whose depth is auto-tuned to the measured
//!   augment/step time ratio (`data::prefetch::auto_depth`), so data
//!   prep overlaps executable dispatch — an SMD skip consumes a staged
//!   batch without stalling.
//!
//! `cfg.resident = false` / `cfg.prefetch = false` select the legacy
//! synchronous host path; for fixed seeds both paths produce
//! bitwise-identical metrics (tests/resident_equivalence.rs).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{DataCfg, RunCfg};
use crate::data::{cifar, prefetch, synthetic, AugmentCfg, Dataset, Prefetcher, Sampler};
use crate::energy::{EnergyLedger, EnergyModel};
use crate::metrics::{Mean, RunMetrics};
use crate::optim::SwaState;
use crate::runtime::{
    DeviceState, Engine, EvalMetrics, HostTensor, ModelState, SnapshotCell,
    StateSnapshot, StepHyper, TrainProgram,
};

use super::sd::SdScheduler;
use super::smd::SmdScheduler;

/// Outcome of a full run (metrics + the final state for reuse, e.g. the
/// fine-tuning experiment).
pub struct RunOutcome {
    pub metrics: RunMetrics,
    pub state: ModelState,
    pub ledger: EnergyLedger,
}

/// Where the model state lives during the step loop.
enum LoopState {
    /// Legacy host path: full state converts in/out every step.
    Host(ModelState),
    /// Resident path: state stays in backend-native buffers.
    Device(DeviceState),
}

impl LoopState {
    /// Materialize a host copy (SWA snapshots).
    fn snapshot(&self) -> Result<ModelState> {
        match self {
            LoopState::Host(s) => Ok(s.clone()),
            LoopState::Device(d) => d.sync_to_host(),
        }
    }

    /// Consume into a host state (end of run).
    fn into_model_state(self) -> Result<ModelState> {
        match self {
            LoopState::Host(s) => Ok(s),
            LoopState::Device(d) => d.into_host(),
        }
    }
}

/// The training batch stream: synchronous sampling or the prefetch
/// worker.  Both produce the identical deterministic stream for a seed.
enum BatchSource {
    Sync(Sampler),
    Prefetch {
        /// The probe batches the depth auto-tuner assembled (and timed)
        /// synchronously — the head of the stream, replayed before the
        /// worker's output so the stream stays batch-for-batch
        /// identical to the synchronous path.
        staged: VecDeque<(HostTensor, HostTensor)>,
        pre: Prefetcher,
    },
}

impl BatchSource {
    fn next_batch(&mut self, data: &Dataset) -> (HostTensor, HostTensor) {
        match self {
            BatchSource::Sync(s) => s.next_batch(data),
            BatchSource::Prefetch { staged, pre } => {
                staged.pop_front().unwrap_or_else(|| pre.next_batch())
            }
        }
    }
}

pub struct Trainer<'e> {
    engine: &'e Engine,
    pub cfg: RunCfg,
    pub program: TrainProgram,
    pub energy: EnergyModel,
    train_set: Arc<Dataset>,
    test_set: Dataset,
    /// Checkpoint publish point for an attached serve pool: when set,
    /// the run publishes each refreshed SWA average and the final state
    /// into the cell (mid-flight — the serve queue never drains).
    publish: Option<Arc<SnapshotCell>>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: RunCfg) -> Result<Self> {
        let program = TrainProgram::load(engine, &cfg.manifest_path())?;
        let energy = EnergyModel::from_manifest(&program.manifest);
        let (train_set, test_set) = Self::load_data(&cfg, &program)?;
        Ok(Self {
            engine,
            cfg,
            program,
            energy,
            train_set: Arc::new(train_set),
            test_set,
            publish: None,
        })
    }

    /// Attach a serve-side snapshot cell; subsequent runs publish
    /// checkpoints into it (SWA refreshes + the final state).
    pub fn set_publisher(&mut self, cell: Arc<SnapshotCell>) {
        self.publish = Some(cell);
    }

    fn load_data(cfg: &RunCfg, program: &TrainProgram) -> Result<(Dataset, Dataset)> {
        let hw = program.manifest.arch.image_size;
        let classes = program.manifest.arch.num_classes;
        match &cfg.data {
            DataCfg::Synthetic { classes: c, n_train, n_test, seed } => {
                if *c != classes {
                    return Err(anyhow!(
                        "config classes {} != artifact classes {}",
                        c,
                        classes
                    ));
                }
                Ok(synthetic::generate_split(
                    classes, *n_train, *n_test, hw, *seed,
                ))
            }
            DataCfg::CifarBin { dir } => {
                if hw != 32 || classes != 10 {
                    return Err(anyhow!("CIFAR binaries need a 32px/10-class artifact"));
                }
                Ok((cifar::load(dir, true)?, cifar::load(dir, false)?))
            }
        }
    }

    /// Replace the datasets (fine-tuning experiment, Sec. 4.5).
    pub fn set_data(&mut self, train: Dataset, test: Dataset) {
        self.train_set = Arc::new(train);
        self.test_set = test;
    }

    /// Run the configured number of iterations starting from a fresh
    /// init (or from `from_state` when resuming / fine-tuning).
    pub fn run(&mut self, from_state: Option<ModelState>) -> Result<RunOutcome> {
        let m = &self.program.manifest;
        let init_state = match from_state {
            // Name-based migration handles method changes (e.g. resuming
            // a sgd32-pretrained trunk under e2train, which adds gates).
            Some(s) => ModelState::init_from(m, self.cfg.seed, &s),
            None => ModelState::init(m, self.cfg.seed),
        };
        let mut loop_state = if self.cfg.resident {
            LoopState::Device(self.program.upload_state(init_state)?)
        } else {
            LoopState::Host(init_state)
        };
        let num_gated = m.num_gated();
        let needs_mask = m.method.gating == "mask";
        let sampler_seed = self.cfg.seed ^ 0xda7a;
        let mut prefetch_depth: Option<usize> = None;
        // Assembly time of the probe batches: they are the stream's
        // real first batches (replayed to the loop), so their cost
        // belongs on the wall clock even though they were built before
        // it starts — keeps the prefetch-on/off comparison fair.
        let mut wall_offset_s = 0.0;
        let mut source = if self.cfg.prefetch {
            // Depth auto-tuning: assemble (and time) the first batches
            // of the real stream synchronously, time one throwaway step
            // on a cloned state, and size the channel to the measured
            // augment/step ratio.  The probe batches are replayed to
            // the loop and the sampler hands over mid-stream, so the
            // batch stream is bit-identical to the synchronous path.
            const PROBE_BATCHES: usize = 2;
            let mut sampler = Sampler::new(
                self.train_set.n,
                self.program.batch(),
                AugmentCfg::default(),
                sampler_seed,
            );
            let t0 = Instant::now();
            let staged: VecDeque<(HostTensor, HostTensor)> = (0..PROBE_BATCHES)
                .map(|_| sampler.next_batch(&self.train_set))
                .collect();
            wall_offset_s = t0.elapsed().as_secs_f64();
            let augment_mean = wall_offset_s / PROBE_BATCHES as f64;
            let step_mean = self.probe_step_time(
                &loop_state,
                staged.front().expect("probe batches"),
                needs_mask,
                num_gated,
            )?;
            let depth = prefetch::auto_depth(augment_mean, step_mean);
            prefetch_depth = Some(depth);
            BatchSource::Prefetch {
                staged,
                pre: Prefetcher::spawn_from(sampler, self.train_set.clone(), depth),
            }
        } else {
            BatchSource::Sync(Sampler::new(
                self.train_set.n,
                self.program.batch(),
                AugmentCfg::default(),
                sampler_seed,
            ))
        };
        let mut smd =
            SmdScheduler::new(self.cfg.smd.enabled, self.cfg.smd.p, self.cfg.seed ^ 0x50d);
        let mut sd = SdScheduler::new(num_gated, self.cfg.sd.p_l, self.cfg.seed ^ 0x5d);

        let mut swa = SwaState::new(self.cfg.iters / 2, (self.cfg.iters / 20).max(1));
        let mut swa_model: Option<ModelState> = None;

        let mut ledger = EnergyLedger::default();
        let mut metrics = RunMetrics::default();
        let mut gate_means: Vec<Mean> = vec![Mean::default(); num_gated];
        let mut psg_mean = Mean::default();
        let record_every = (self.cfg.iters / 50).max(1);

        // Clock the loop itself, after pipeline setup.  The auto-tune
        // probe's extra throwaway step (prefetch-on only) stays off the
        // clock, but its batch assemblies were added via wall_offset_s
        // above — so the prefetch-on vs prefetch-off steps/s comparison
        // in BENCH_runtime.json measures the same work on both paths.
        let t0 = Instant::now();
        for iter in 0..self.cfg.iters {
            let lr = self.cfg.lr.at(iter) as f32;
            if smd.skip() {
                // SMD: the batch is consumed (sampling with limited
                // replacement, Sec. 3.1) but never executed or charged.
                // With prefetch on, the staged batch is simply dropped —
                // no stall.
                let _ = source.next_batch(&self.train_set);
                ledger.skip();
                continue;
            }
            let (x, y) = source.next_batch(&self.train_set);
            let mask = if needs_mask { Some(sd.sample()) } else { None };
            let hp = StepHyper {
                lr,
                alpha: self.cfg.alpha as f32,
                beta: self.cfg.beta as f32,
            };
            let sm = match &mut loop_state {
                LoopState::Host(st) => {
                    self.program.step(st, &x, &y, hp, mask.as_deref())?
                }
                LoopState::Device(ds) => {
                    self.program.step_device(ds, &x, &y, hp, mask.as_deref())?
                }
            };

            // Energy: SD masks are per-batch gate fractions too.
            let fracs: Vec<f64> = if !sm.gate_fracs.is_empty() {
                sm.gate_fracs.clone()
            } else if let Some(mk) = &mask {
                mk.iter().map(|&v| v as f64).collect()
            } else {
                vec![]
            };
            let e = self.energy.train_step(&m.method, &fracs, sm.psg_frac);
            ledger.charge(iter, &e, self.energy.step_macs(&fracs));

            for (g, f) in gate_means.iter_mut().zip(fracs.iter()) {
                g.push(*f);
            }
            if let Some(p) = sm.psg_frac {
                psg_mean.push(p);
            }

            // SWA (enabled for PSG-family runs, Sec. 4.1).  This is one
            // of the few places resident state syncs to host.
            if self.cfg.swa && swa.should_average(iter) {
                let w = swa.observe();
                let snap = loop_state.snapshot()?;
                match &mut swa_model {
                    None => swa_model = Some(snap),
                    Some(sw) => {
                        sw.average_params_from(&snap, w, self.program.num_params)
                    }
                }
                // Publish the refreshed SWA checkpoint to an attached
                // serve pool — mid-flight, the serve queue never drains.
                if let (Some(cell), Some(sw)) = (&self.publish, &swa_model) {
                    cell.publish(StateSnapshot::from_model_state(
                        self.program.backend(),
                        sw,
                    )?);
                }
            }

            if iter % record_every == 0 || iter + 1 == self.cfg.iters {
                let train_acc = sm.correct / self.program.batch() as f64;
                let test_acc = if self.cfg.eval_every > 0
                    && iter % self.cfg.eval_every == 0
                {
                    Some(self.evaluate_loop_state(&loop_state)?.0)
                } else {
                    None
                };
                metrics.record(iter, sm.loss, train_acc, ledger.total_joules(), test_acc);
            }
        }

        // Final evaluation — SWA weights if averaging ran.
        let final_state = match swa_model {
            Some(sw) => sw,
            None => loop_state.into_model_state()?,
        };
        // Publish the final checkpoint (SWA weights when averaging ran).
        if let Some(cell) = &self.publish {
            cell.publish(StateSnapshot::from_model_state(
                self.program.backend(),
                &final_state,
            )?);
        }
        let (acc, acc5, loss) = self.evaluate_full(&final_state)?;
        metrics.final_test_acc = acc;
        metrics.final_test_acc_top5 = acc5;
        metrics.final_loss = loss;
        metrics.total_joules = ledger.total_joules();
        metrics.executed_macs = ledger.macs;
        metrics.steps_run = ledger.steps_charged;
        metrics.steps_skipped = ledger.steps_skipped;
        metrics.wall_seconds = t0.elapsed().as_secs_f64() + wall_offset_s;
        metrics.mean_gate_fracs = gate_means.iter().map(|g| g.get()).collect();
        metrics.mean_psg_frac =
            if psg_mean.count() > 0 { Some(psg_mean.get()) } else { None };
        metrics.prefetch_depth = prefetch_depth;

        eprintln!(
            "[run] {}/{}: acc {:.4}, {:.2} J, {} steps ({} skipped), {:.1}s",
            self.cfg.family,
            self.cfg.method,
            acc,
            metrics.total_joules,
            metrics.steps_run,
            metrics.steps_skipped,
            metrics.wall_seconds
        );
        Ok(RunOutcome { metrics, state: final_state, ledger })
    }

    /// Time one train step on a **cloned** state — the depth auto-tuner's
    /// denominator.  The clone guarantees the probe is invisible: the
    /// real state, RNG streams and metrics are untouched, so prefetch
    /// on/off stay bitwise equivalent.
    fn probe_step_time(
        &self,
        ls: &LoopState,
        batch: &(HostTensor, HostTensor),
        needs_mask: bool,
        num_gated: usize,
    ) -> Result<f64> {
        let mask: Option<Vec<f32>> = if needs_mask {
            Some(vec![1.0; num_gated])
        } else {
            None
        };
        let hp = StepHyper {
            lr: self.cfg.lr.at(0) as f32,
            alpha: self.cfg.alpha as f32,
            beta: self.cfg.beta as f32,
        };
        let (x, y) = batch;
        Ok(match ls {
            LoopState::Host(s) => {
                let mut probe = s.clone();
                let t0 = Instant::now();
                self.program.step(&mut probe, x, y, hp, mask.as_deref())?;
                t0.elapsed().as_secs_f64()
            }
            LoopState::Device(d) => {
                let mut probe = d.clone();
                let t0 = Instant::now();
                self.program
                    .step_device(&mut probe, x, y, hp, mask.as_deref())?;
                t0.elapsed().as_secs_f64()
            }
        })
    }

    fn evaluate_loop_state(&self, ls: &LoopState) -> Result<(f64, f64, f64)> {
        match ls {
            LoopState::Host(s) => self.evaluate_full(s),
            LoopState::Device(d) => self.evaluate_full_device(d),
        }
    }

    /// (accuracy, top5, loss) over the full test set in `eval_batch`
    /// chunks, host-path state.
    pub fn evaluate_full(&self, state: &ModelState) -> Result<(f64, f64, f64)> {
        self.eval_batches(|x, y| self.program.eval_batch_run(state, x, y))
    }

    /// Same, straight from resident state — the model never syncs to
    /// host, only metric scalars come back per batch.
    pub fn evaluate_full_device(&self, state: &DeviceState) -> Result<(f64, f64, f64)> {
        self.eval_batches(|x, y| self.program.eval_batch_device(state, x, y))
    }

    /// Drive `run_batch` over the whole test set, including the tail
    /// remainder when `eval_batch` does not divide it: the last chunk is
    /// padded with zero images and label `-1`.  Padded rows contribute
    /// nothing to any metric (`one_hot(-1) == 0` zeroes their loss and
    /// `-1` never matches a prediction), so totals are normalized by the
    /// true sample count.  The seed runtime silently dropped up to
    /// `eval_batch - 1` trailing samples — and errored on test sets
    /// smaller than one eval batch, which now just work.
    fn eval_batches(
        &self,
        mut run_batch: impl FnMut(&HostTensor, &HostTensor) -> Result<EvalMetrics>,
    ) -> Result<(f64, f64, f64)> {
        let eb = self.program.eval_batch();
        let hw = self.test_set.hw;
        let stride = hw * hw * 3;
        let n = self.test_set.n;
        if n == 0 {
            return Err(anyhow!("empty test set"));
        }
        let mut correct = 0.0;
        let mut correct5 = 0.0;
        let mut loss_sum = 0.0;
        let nb = n / eb;
        for b in 0..nb {
            let lo = b * eb;
            let x = HostTensor::f32(
                vec![eb, hw, hw, 3],
                self.test_set.images[lo * stride..(lo + eb) * stride].to_vec(),
            );
            let y = HostTensor::i32(
                vec![eb],
                self.test_set.labels[lo..lo + eb].to_vec(),
            );
            let em = run_batch(&x, &y)?;
            correct += em.correct;
            correct5 += em.correct5;
            loss_sum += em.loss * eb as f64;
        }
        let rem = n % eb;
        if rem > 0 {
            let lo = nb * eb;
            let mut px = vec![0f32; eb * stride];
            px[..rem * stride]
                .copy_from_slice(&self.test_set.images[lo * stride..(lo + rem) * stride]);
            let mut py = vec![-1i32; eb];
            py[..rem].copy_from_slice(&self.test_set.labels[lo..lo + rem]);
            let em = run_batch(
                &HostTensor::f32(vec![eb, hw, hw, 3], px),
                &HostTensor::i32(vec![eb], py),
            )?;
            correct += em.correct;
            correct5 += em.correct5;
            loss_sum += em.loss * eb as f64;
        }
        Ok((
            correct / n as f64,
            correct5 / n as f64,
            loss_sum / n as f64,
        ))
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }
}
