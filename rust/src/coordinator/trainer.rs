//! The training orchestrator: one `Trainer` owns a run end-to-end —
//! artifact loading, state init, data pipeline, the step loop with
//! SMD/SD/SWA hooks, per-step energy charging, eval, and metrics.
//!
//! The step loop is buffer-resident and overlapped by default:
//!
//! * model state lives in backend-native buffers across steps (only
//!   metric outputs sync to host each iteration; a full host sync runs
//!   only for SWA snapshots / fine-tune handoff / end-of-run);
//! * batch assembly + augmentation run on a background prefetch thread
//!   behind a bounded channel whose depth is auto-tuned to the measured
//!   augment/step time ratio (`data::prefetch::auto_depth`), so data
//!   prep overlaps executable dispatch — an SMD skip consumes a staged
//!   batch without stalling.
//!
//! **Where** a step executes is not this module's business: the loop is
//! written once against the [`StepBackend`] trait (`runtime::exec`) and
//! `cfg.backend` picks the strategy — `host` (legacy full-state
//! round-trip), `resident` (the default described above), or `sharded`
//! (data-parallel over an engine pool with a deterministic host-side
//! all-reduce).  With the knob unset, the legacy `resident` / `shards`
//! fields map onto the same three choices.  All backends are bitwise
//! interchangeable for a fixed seed — SMD drops, SWA, publishing,
//! checkpointing and eval go through the trait, so
//! tests/backend_matrix.rs pins the full matrix (and
//! tests/{resident,shard}_equivalence.rs the historical pairwise
//! contracts).  SMD-dropped iterations consume the whole batch — shard
//! slicing happens inside the sharded backend, downstream of the batch
//! stream.
//!
//! `cfg.checkpoint.every > 0` publishes a durable `ckpt/v1` checkpoint
//! (`crate::checkpoint`) at every boundary, off the host-side state via
//! a background writer.  The checkpoint captures the complete loop
//! state — model/momenta/gates/run_mean, the SWA accumulator, every RNG
//! stream at its exact position (a *shadow sampler* replays the batch
//! stream's draws on this thread, so the position is exportable even
//! while the live sampler runs ahead on the prefetch worker), the
//! energy ledger and metric accumulators — so [`Trainer::resume`]
//! continues **bitwise identically** to the run that never stopped, on
//! any execution path (tests/resume_equivalence.rs).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::checkpoint::{
    CheckpointData, CheckpointRegistry, CheckpointWriter, FsRemoteStore, Replicator,
    RetentionCfg,
};
use crate::config::{BackendChoice, DataCfg, RunCfg};
use crate::data::{
    cifar, prefetch, synthetic, AugmentCfg, Dataset, Prefetcher, Sampler, SamplerState,
};
use crate::energy::{EnergyLedger, EnergyModel};
use crate::metrics::{Mean, RunMetrics};
use crate::obs::catalog::{Catalog, CatalogKey, Observation, PlanRecord};
use crate::obs::{Obs, TraceKey};
use crate::optim::SwaState;
use crate::runtime::{
    prepare_backend, Engine, EvalMetrics, HostTensor, ModelState, SnapshotCell,
    StateSnapshot, StepBackend, StepHyper, TrainProgram,
};
use crate::util::fault::{self, FaultPlan};

use super::planner;
use super::sd::SdScheduler;
use super::smd::SmdScheduler;

/// Outcome of a full run (metrics + the final state for reuse, e.g. the
/// fine-tuning experiment).
pub struct RunOutcome {
    pub metrics: RunMetrics,
    pub state: ModelState,
    pub ledger: EnergyLedger,
}

/// The training batch stream: synchronous sampling or the prefetch
/// worker.  Both produce the identical deterministic stream for a seed.
enum BatchSource {
    Sync {
        sampler: Sampler,
        data: Arc<Dataset>,
    },
    Prefetch {
        /// The probe batches the depth auto-tuner assembled (and timed)
        /// synchronously — the head of the stream, replayed before the
        /// worker's output so the stream stays batch-for-batch
        /// identical to the synchronous path.
        staged: VecDeque<(HostTensor, HostTensor)>,
        pre: Prefetcher,
    },
}

impl BatchSource {
    fn next_batch(&mut self, obs: &Obs) -> Result<(HostTensor, HostTensor)> {
        match self {
            BatchSource::Sync { sampler, data } => {
                // Synchronous sampling assembles the batch right here on
                // the step loop's thread — that *is* the augment phase.
                // (With prefetch on, the worker records it instead, and
                // the consumer's pull time lands under `prefetch-stall`.)
                let t0 = Instant::now();
                let b = sampler.next_batch(data);
                obs.record(crate::obs::PHASE_AUGMENT, t0.elapsed());
                Ok(b)
            }
            BatchSource::Prefetch { staged, pre } => match staged.pop_front() {
                Some(b) => Ok(b),
                // Surfaces a deferred CIFAR decode failure as a clean
                // run error instead of a worker-died panic.
                None => pre.next_batch(),
            },
        }
    }
}

/// How a run starts: a fresh init (optionally warm-started by name
/// migration, the fine-tune path) or an exact checkpoint restore.
enum Start {
    Fresh(Option<ModelState>),
    Resume(Box<CheckpointData>),
}

/// Where the batch stream starts: a fresh seed or an exported mid-run
/// position.  Threaded into every batch-source variant *and* the shadow
/// sampler, so all of them stand at the same point of the same stream.
enum SamplerStart {
    Seed(u64),
    State(SamplerState),
}

impl SamplerStart {
    fn build(&self, dataset_len: usize, batch: usize, augment: AugmentCfg) -> Result<Sampler> {
        match self {
            SamplerStart::Seed(s) => Ok(Sampler::new(dataset_len, batch, augment, *s)),
            SamplerStart::State(st) => Sampler::restore(st, dataset_len, batch, augment),
        }
    }
}

/// Assemble one checkpoint from the loop's live state (free function so
/// the borrow of each piece stays explicit at the call sites).  The
/// model comes off [`StepBackend::export_for_checkpoint`] — host-side by
/// contract, which is what makes checkpoints backend-agnostic.
#[allow(clippy::too_many_arguments)]
fn snapshot_checkpoint(
    cfg: &RunCfg,
    iter: u64,
    backend: &dyn StepBackend,
    shadow: &Sampler,
    smd: &SmdScheduler,
    sd: &SdScheduler,
    swa: &SwaState,
    swa_model: &Option<ModelState>,
    ledger: &EnergyLedger,
    metrics: &RunMetrics,
    gate_means: &[Mean],
    psg_mean: &Mean,
) -> Result<CheckpointData> {
    Ok(CheckpointData {
        iter,
        cfg: cfg.clone(),
        model: backend.export_for_checkpoint()?,
        swa_model: swa_model.clone(),
        swa: swa.clone(),
        sampler: shadow.export(),
        smd: smd.export(),
        sd: sd.export(),
        ledger: ledger.clone(),
        trace: metrics.trace.clone(),
        gate_means: gate_means.to_vec(),
        psg_mean: psg_mean.clone(),
    })
}

/// Where the training set lives before the step loop starts.
enum TrainData {
    /// Fully decoded, in memory (synthetic data, an eager CIFAR load,
    /// or a `set_data` override).
    Ready(Arc<Dataset>),
    /// CIFAR binaries validated but not decoded: the prefetch worker
    /// streams + decodes them itself, so the main thread never
    /// materializes the training set (ROADMAP: CIFAR-bin ingestion on
    /// the prefetch worker).
    DeferredCifar(cifar::CifarFiles),
}

pub struct Trainer<'e> {
    engine: &'e Engine,
    pub cfg: RunCfg,
    pub program: TrainProgram,
    pub energy: EnergyModel,
    train_data: TrainData,
    test_set: Dataset,
    /// Checkpoint publish point for an attached serve pool: when set,
    /// the run publishes each refreshed SWA average and the final state
    /// into the cell (mid-flight — the serve queue never drains).
    publish: Option<Arc<SnapshotCell>>,
    /// Armed fault-injection plan (tests / supervised runs): threaded
    /// into the prefetch worker, the checkpoint registry and the
    /// execution backend, plus the trainer's own `engine.train_step`
    /// site.  `None` (the default) injects nothing anywhere.
    faults: Option<Arc<FaultPlan>>,
    /// The observability hub, threaded (like `faults`) into the
    /// prefetch worker, the checkpoint registry/writer and the
    /// execution backend.  Aggregates are always collected; the JSONL
    /// event log is recorded only when `cfg.trace_out` is set.  Inert
    /// either way: tests/obs_invariance.rs pins that a traced run is
    /// bitwise identical to an untraced one.
    obs: Obs,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: RunCfg) -> Result<Self> {
        // Launcher files validate at parse time; programmatic configs
        // get the same contradiction check here.
        cfg.validate_backend()?;
        let program = TrainProgram::load(engine, &cfg.manifest_path())?;
        let energy = EnergyModel::from_manifest(&program.manifest);
        let (train_data, test_set) = Self::load_data(&cfg, &program)?;
        let obs = Obs::new(cfg.trace_out.is_some());
        Ok(Self {
            engine,
            cfg,
            program,
            energy,
            train_data,
            test_set,
            publish: None,
            faults: None,
            obs,
        })
    }

    /// Attach a serve-side snapshot cell; subsequent runs publish
    /// checkpoints into it (SWA refreshes + the final state).
    pub fn set_publisher(&mut self, cell: Arc<SnapshotCell>) {
        self.publish = Some(cell);
    }

    /// Arm a fault-injection plan for subsequent runs.
    /// [`Trainer::run_supervised`] builds one from `cfg.faults`
    /// automatically; tests set an explicit plan here so they can hold
    /// the handle and assert which sites actually fired.
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// The armed fault plan, if any.
    pub fn faults(&self) -> Option<Arc<FaultPlan>> {
        self.faults.clone()
    }

    /// The observability handle (shared hub; cheap to clone).  The
    /// supervisor uses it to record structured recovery events into the
    /// same trace the run's spans land in.
    pub fn obs(&self) -> Obs {
        self.obs.clone()
    }

    fn load_data(cfg: &RunCfg, program: &TrainProgram) -> Result<(TrainData, Dataset)> {
        let hw = program.manifest.arch.image_size;
        let classes = program.manifest.arch.num_classes;
        match &cfg.data {
            DataCfg::Synthetic { classes: c, n_train, n_test, seed } => {
                if *c != classes {
                    return Err(anyhow!(
                        "config classes {} != artifact classes {}",
                        c,
                        classes
                    ));
                }
                let (train, test) =
                    synthetic::generate_split(classes, *n_train, *n_test, hw, *seed);
                Ok((TrainData::Ready(Arc::new(train)), test))
            }
            DataCfg::CifarBin { dir } => {
                if hw != 32 || classes != 10 {
                    return Err(anyhow!("CIFAR binaries need a 32px/10-class artifact"));
                }
                // The (small) test set loads eagerly — eval runs on this
                // thread.  The train set is only *validated* here when
                // prefetching: the worker streams + decodes it, so run
                // start never blocks on the full decode.
                let test = cifar::load(dir, false)?;
                let train = if cfg.prefetch {
                    TrainData::DeferredCifar(cifar::open(dir, true)?)
                } else {
                    TrainData::Ready(Arc::new(cifar::load(dir, true)?))
                };
                Ok((train, test))
            }
        }
    }

    /// Replace the datasets (fine-tuning experiment, Sec. 4.5).
    pub fn set_data(&mut self, train: Dataset, test: Dataset) {
        self.train_data = TrainData::Ready(Arc::new(train));
        self.test_set = test;
    }

    /// The decoded training set, materializing a deferred CIFAR source
    /// on the calling thread (synchronous-sampling path only; with
    /// prefetch on, the worker decodes instead).
    fn train_set(&mut self) -> Result<Arc<Dataset>> {
        if let TrainData::DeferredCifar(files) = &self.train_data {
            let data = Arc::new(files.decode()?);
            self.train_data = TrainData::Ready(data);
        }
        match &self.train_data {
            TrainData::Ready(d) => Ok(d.clone()),
            TrainData::DeferredCifar(_) => unreachable!("materialized above"),
        }
    }

    /// Run the configured number of iterations starting from a fresh
    /// init (or from `from_state` when warm-starting / fine-tuning).
    pub fn run(&mut self, from_state: Option<ModelState>) -> Result<RunOutcome> {
        self.run_inner(Start::Fresh(from_state))
    }

    /// Continue a checkpointed run from its exact loop state.  For a
    /// matching configuration the continuation is **bitwise identical**
    /// to the run that never stopped — metrics trace, energy ledger and
    /// final model state (tests/resume_equivalence.rs).  The execution
    /// layout may legally differ (a resident checkpoint can resume
    /// sharded and vice versa — those paths are bitwise interchangeable);
    /// anything determinism-relevant must match, enforced through the
    /// config fingerprint.
    pub fn resume(&mut self, ckpt: CheckpointData) -> Result<RunOutcome> {
        let want = self.cfg.fingerprint();
        let got = ckpt.cfg.fingerprint();
        if got != want {
            return Err(anyhow!(
                "checkpoint fingerprint {got} does not match this run's {want}: \
                 resume requires the identical determinism-relevant config \
                 (family/method/iters/seed/lr/data/smd/sd/eval_every/swa/alpha/beta)"
            ));
        }
        if ckpt.iter > self.cfg.iters {
            return Err(anyhow!(
                "checkpoint is at iter {} but the run is configured for {} iters",
                ckpt.iter,
                self.cfg.iters
            ));
        }
        self.run_inner(Start::Resume(Box::new(ckpt)))
    }

    /// Validate that a checkpoint's model (and SWA) state belongs to
    /// this artifact — [`ModelState::matches_spec`] against the
    /// manifest's state spec, the same comparison the serve registry
    /// watcher applies before hot-loading.  (The fingerprint already
    /// pins family/method; this catches a checkpoint file paired with
    /// a drifted artifact.)
    fn check_resume_state(&self, ck: &CheckpointData) -> Result<()> {
        let spec = self.program.manifest.state_spec();
        if !ck.model.matches_spec(&spec) {
            return Err(anyhow!(
                "checkpoint state tensors do not match artifact {}/{} \
                 (names/shapes in manifest order)",
                self.cfg.family,
                self.cfg.method
            ));
        }
        if let Some(sw) = &ck.swa_model {
            if !sw.matches_spec(&spec) {
                return Err(anyhow!(
                    "checkpoint SWA state does not match the artifact's state layout"
                ));
            }
        }
        Ok(())
    }

    fn run_inner(&mut self, start: Start) -> Result<RunOutcome> {
        // Training-set length without materializing a deferred CIFAR
        // source (its record count comes from file metadata) — the
        // shadow sampler and restore validation need it.
        let train_len = match &self.train_data {
            TrainData::Ready(d) => d.n,
            TrainData::DeferredCifar(f) => f.n,
        };
        let num_gated = self.program.manifest.num_gated();

        // Loop-state defaults for a fresh run; a resume overwrites all
        // of them wholesale from the checkpoint.
        let mut start_iter = 0u64;
        let mut sampler_start = SamplerStart::Seed(self.cfg.seed ^ 0xda7a);
        let mut smd =
            SmdScheduler::new(self.cfg.smd.enabled, self.cfg.smd.p, self.cfg.seed ^ 0x50d);
        let mut sd = SdScheduler::new(num_gated, self.cfg.sd.p_l, self.cfg.seed ^ 0x5d);
        let mut swa = SwaState::new(self.cfg.iters / 2, (self.cfg.iters / 20).max(1));
        let mut swa_model: Option<ModelState> = None;
        let mut ledger = EnergyLedger::default();
        let mut metrics = RunMetrics::default();
        let mut gate_means: Vec<Mean> = vec![Mean::default(); num_gated];
        let mut psg_mean = Mean::default();

        let init_state = match start {
            Start::Fresh(from_state) => match from_state {
                // Name-based migration handles method changes (e.g.
                // resuming a sgd32-pretrained trunk under e2train,
                // which adds gates).
                Some(s) => {
                    ModelState::init_from(&self.program.manifest, self.cfg.seed, &s)
                }
                None => ModelState::init(&self.program.manifest, self.cfg.seed),
            },
            Start::Resume(ck) => {
                self.check_resume_state(&ck)?;
                let ck = *ck;
                if ck.gate_means.len() != num_gated {
                    return Err(anyhow!(
                        "checkpoint tracks {} gates, artifact has {num_gated}",
                        ck.gate_means.len()
                    ));
                }
                start_iter = ck.iter;
                sampler_start = SamplerStart::State(ck.sampler);
                smd = SmdScheduler::restore(self.cfg.smd.enabled, self.cfg.smd.p, &ck.smd)
                    .ok_or_else(|| anyhow!("checkpoint SMD scheduler state is corrupt"))?;
                sd = SdScheduler::restore(num_gated, self.cfg.sd.p_l, &ck.sd)
                    .ok_or_else(|| anyhow!("checkpoint SD scheduler state is corrupt"))?;
                swa = ck.swa;
                swa_model = ck.swa_model;
                ledger = ck.ledger;
                metrics.trace = ck.trace;
                gate_means = ck.gate_means;
                psg_mean = ck.psg_mean;
                ck.model
            }
        };

        // The planning layer: `backend = "auto"` resolves into a
        // concrete layout here, against the calibrated cost catalog —
        // before any backend exists, and strictly outside the
        // determinism fingerprint (a plan only sets layout knobs, which
        // are bitwise interchangeable by the backend-matrix contract).
        let mut choice = self.cfg.resolved_backend();
        let mut run_shards = self.cfg.shards;
        let mut run_prefetch = self.cfg.prefetch;
        let mut pinned_depth: Option<usize> = None;
        let mut plan_record: Option<PlanRecord> = None;
        let catalog_path = planner::catalog_path(&self.cfg);
        if choice == BackendChoice::Auto {
            let path = catalog_path
                .as_deref()
                .expect("auto always resolves a catalog path");
            let mut catalog = Catalog::load_or_empty(path)?;
            let plan = planner::plan_run(
                &planner::PlanInputs {
                    engine: self.engine,
                    program: &self.program,
                    cfg: &self.cfg,
                    init: &init_state,
                    data: match &self.train_data {
                        TrainData::Ready(d) => Some(d),
                        TrainData::DeferredCifar(_) => None,
                    },
                },
                &mut catalog,
            )?;
            if plan.record.probed {
                // Probe measurements are real calibration — persist
                // them now so they survive even a run that later fails.
                catalog.save(path)?;
            }
            eprintln!(
                "[plan] auto -> {}/s{} prefetch={} depth={:?}: predicted \
                 {:.1} steps/s, {} J/step{}",
                plan.record.backend,
                plan.record.shards,
                plan.record.prefetch,
                plan.record.prefetch_depth,
                plan.record.predicted_sps,
                if plan.record.predicted_j_per_step > 0.0 {
                    format!("{:.4}", plan.record.predicted_j_per_step)
                } else {
                    "?".into()
                },
                if plan.record.probed { " (probe-calibrated)" } else { "" },
            );
            choice = plan.choice;
            run_shards = plan.shards;
            run_prefetch = plan.prefetch;
            pinned_depth = plan.prefetch_depth;
            plan_record = Some(plan.record);
        }
        // The synchronous-sampling path needs the decoded train set on
        // this thread; materialize a deferred CIFAR source now that the
        // plan (or the config) has fixed prefetch on/off.
        let sync_data = if run_prefetch { None } else { Some(self.train_set()?) };
        let m = &self.program.manifest;

        // The execution layer: everything below this line is
        // backend-agnostic — swapping host/resident/sharded (or a
        // future real-PJRT collective impl) changes nothing in the loop.
        let mut backend = prepare_backend(
            self.engine,
            &self.program,
            &self.cfg.manifest_path(),
            choice,
            run_shards,
            self.cfg.accum,
            init_state,
        )?;
        if let Some(p) = &self.faults {
            backend.set_faults(p.clone());
        }
        backend.set_obs(self.obs.clone());
        // Catalog key: every trace row this run emits is attributable
        // to (family, method, backend, shards, batch) — the shape the
        // cost/energy catalog (ROADMAP) ingests.
        self.obs.set_key(TraceKey {
            family: self.cfg.family.clone(),
            method: self.cfg.method.clone(),
            backend: backend.name().to_string(),
            shards: backend.shard_count(),
            batch: self.program.batch(),
        });
        let needs_mask = m.method.gating == "mask";

        // Durable checkpointing: a background writer over the registry,
        // plus the shadow sampler that tracks the batch stream's
        // position on this thread (the live sampler may be ahead on the
        // prefetch worker; consumption order is what a checkpoint must
        // capture).  Both restart from `sampler_start`, so shadow and
        // stream stand at the same point on fresh *and* resumed runs.
        let ckpt_every = self.cfg.checkpoint.every;
        let mut ckpt_writer: Option<CheckpointWriter> = None;
        let mut shadow: Option<Sampler> = None;
        let mut prune_failures = None;
        let mut replicator: Option<Replicator> = None;
        if ckpt_every > 0 {
            let dir = self.cfg.checkpoint.dir.clone().ok_or_else(|| {
                anyhow!("checkpoint.every = {ckpt_every} but checkpoint.dir is unset")
            })?;
            let mut registry = CheckpointRegistry::new(
                &dir,
                RetentionCfg {
                    keep_last: self.cfg.checkpoint.keep_last,
                    keep_every: self.cfg.checkpoint.keep_every,
                },
            );
            if let Some(p) = &self.faults {
                registry = registry.with_faults(p.clone());
            }
            registry = registry.with_obs(self.obs.clone());
            prune_failures = Some(registry.prune_failure_counter());
            // Off-box evacuation: the replicator follows the manifest
            // and pushes each published checkpoint to the remote root.
            // The shared watermark pins retention — prune never removes
            // an entry the replicator has not finished evacuating.
            if let Some(root) = self.cfg.checkpoint.replicate.clone() {
                let watermark =
                    std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
                registry = registry.with_replication_floor(watermark.clone());
                let mut store = FsRemoteStore::new(root);
                if let Some(p) = &self.faults {
                    store = store.with_faults(p.clone());
                }
                replicator = Some(Replicator::spawn(
                    &dir,
                    Box::new(store),
                    watermark,
                    self.obs.clone(),
                    std::time::Duration::from_millis(10),
                ));
            }
            ckpt_writer = Some(CheckpointWriter::spawn(registry));
            shadow = Some(sampler_start.build(
                train_len,
                self.program.batch(),
                AugmentCfg::default(),
            )?);
        }

        let mut prefetch_depth: Option<usize> = None;
        // Assembly time of the probe batches: they are the stream's
        // real first batches (replayed to the loop), so their cost
        // belongs on the wall clock even though they were built before
        // it starts — keeps the prefetch-on/off comparison fair.
        let mut wall_offset_s = 0.0;
        let mut source = match (&self.train_data, run_prefetch) {
            (TrainData::DeferredCifar(files), true) => {
                // Stream + decode the CIFAR binaries on the worker.  The
                // depth auto-tuner needs decoded probe batches, so
                // deferred ingestion keeps the classic double buffer —
                // unless a plan pinned the depth from the catalog; the
                // batch stream itself is bit-identical (the worker
                // builds the same sampler start over the same records —
                // a fresh seed, or the restored mid-run position).
                let depth = pinned_depth.unwrap_or(prefetch::DEFAULT_DEPTH);
                prefetch_depth = Some(depth);
                let files = files.clone();
                let batch = self.program.batch();
                let pre = match &sampler_start {
                    SamplerStart::Seed(s) => Prefetcher::spawn_deferred_opts(
                        move || files.decode(),
                        batch,
                        AugmentCfg::default(),
                        *s,
                        depth,
                        self.faults.clone(),
                        self.obs.clone(),
                    )?,
                    SamplerStart::State(st) => Prefetcher::spawn_deferred_resume_opts(
                        move || files.decode(),
                        batch,
                        AugmentCfg::default(),
                        st.clone(),
                        depth,
                        self.faults.clone(),
                        self.obs.clone(),
                    )?,
                };
                BatchSource::Prefetch { staged: VecDeque::new(), pre }
            }
            (TrainData::Ready(data), true) if pinned_depth.is_some() => {
                // Planned run: the depth came from the catalog, so the
                // auto-tune probe is skipped and the worker owns the
                // stream from batch 0.  Bitwise identical to the probing
                // path below — its probe batches are merely a replayed
                // head of the same stream, and its throwaway step is
                // invisible by the `probe_step` contract.
                let depth = pinned_depth.expect("guard");
                prefetch_depth = Some(depth);
                let data = data.clone();
                let sampler = sampler_start.build(
                    data.n,
                    self.program.batch(),
                    AugmentCfg::default(),
                )?;
                BatchSource::Prefetch {
                    staged: VecDeque::new(),
                    pre: Prefetcher::spawn_from_opts(
                        sampler,
                        data,
                        depth,
                        self.faults.clone(),
                        self.obs.clone(),
                    )?,
                }
            }
            (TrainData::Ready(data), true) => {
                // Depth auto-tuning: assemble (and time) the first batches
                // of the real stream synchronously, time one throwaway step
                // on a cloned state, and size the channel to the measured
                // augment/step ratio.  The probe batches are replayed to
                // the loop and the sampler hands over mid-stream, so the
                // batch stream is bit-identical to the synchronous path.
                const PROBE_BATCHES: usize = 2;
                let data = data.clone();
                let mut sampler = sampler_start.build(
                    data.n,
                    self.program.batch(),
                    AugmentCfg::default(),
                )?;
                let t0 = Instant::now();
                let staged: VecDeque<(HostTensor, HostTensor)> = (0..PROBE_BATCHES)
                    .map(|_| sampler.next_batch(&data))
                    .collect();
                wall_offset_s = t0.elapsed().as_secs_f64();
                // The probe batches are real stream batches assembled on
                // this thread; their augment time belongs in the trace
                // like any other batch's.
                self.obs.record(crate::obs::PHASE_AUGMENT, t0.elapsed());
                let augment_mean = wall_offset_s / PROBE_BATCHES as f64;
                let step_mean = self.probe_step_time(
                    backend.as_mut(),
                    staged.front().expect("probe batches"),
                    needs_mask,
                    num_gated,
                )?;
                let depth = prefetch::auto_depth(augment_mean, step_mean);
                prefetch_depth = Some(depth);
                BatchSource::Prefetch {
                    staged,
                    pre: Prefetcher::spawn_from_opts(
                        sampler,
                        data,
                        depth,
                        self.faults.clone(),
                        self.obs.clone(),
                    )?,
                }
            }
            (_, false) => {
                let data = sync_data.expect("materialized above");
                let sampler = sampler_start.build(
                    data.n,
                    self.program.batch(),
                    AugmentCfg::default(),
                )?;
                BatchSource::Sync { sampler, data }
            }
        };
        let record_every = (self.cfg.iters / 50).max(1);

        // Clock the loop itself, after pipeline setup.  The auto-tune
        // probe's extra throwaway step (prefetch-on only) stays off the
        // clock, but its batch assemblies were added via wall_offset_s
        // above — so the prefetch-on vs prefetch-off steps/s comparison
        // in BENCH_runtime.json measures the same work on both paths.
        let t0 = Instant::now();
        for iter in start_iter..self.cfg.iters {
            // Checkpoint at the boundary *before* executing `iter`: the
            // loop state here is exactly the state after `iter - 1`, so
            // the file is identical whether the process died at this
            // point or kept going — which is what makes "interrupt at k
            // + resume" indistinguishable from never stopping.  The
            // boundary the run started from is skipped (it is already
            // on disk).
            if let (Some(w), Some(sh)) = (&ckpt_writer, &shadow) {
                if iter != start_iter && iter % ckpt_every == 0 {
                    w.submit(snapshot_checkpoint(
                        &self.cfg, iter, backend.as_ref(), sh, &smd, &sd, &swa,
                        &swa_model, &ledger, &metrics, &gate_means, &psg_mean,
                    )?)?;
                }
            }
            let lr = self.cfg.lr.at(iter) as f32;
            if smd.skip() {
                // SMD: the batch is consumed (sampling with limited
                // replacement, Sec. 3.1) but never executed or charged.
                // With prefetch on, the staged batch is simply dropped —
                // no stall.  A dropped iteration consumes the *whole*
                // batch, all shard slices included — slicing happens
                // inside the sharded step, downstream of this stream.
                let _ = source.next_batch(&self.obs)?;
                if let Some(sh) = shadow.as_mut() {
                    sh.skip_batch();
                }
                ledger.skip();
                continue;
            }
            let (x, y) = source.next_batch(&self.obs)?;
            if let Some(sh) = shadow.as_mut() {
                sh.skip_batch();
            }
            let mask = if needs_mask { Some(sd.sample()) } else { None };
            let hp = StepHyper {
                lr,
                alpha: self.cfg.alpha as f32,
                beta: self.cfg.beta as f32,
            };
            // The step-level fault site: a transient engine failure at
            // the trainer's own boundary (the backend-local sites live
            // below the `StepBackend` trait).
            if let Some(p) = &self.faults {
                p.check(fault::SITE_TRAIN_STEP)?;
            }
            let t_step = Instant::now();
            let sm = backend.train_step(&x, &y, hp, mask.as_deref())?;
            self.obs.record(crate::obs::PHASE_STEP_EXEC, t_step.elapsed());

            // Energy: SD masks are per-batch gate fractions too.
            let fracs: Vec<f64> = if !sm.gate_fracs.is_empty() {
                sm.gate_fracs.clone()
            } else if let Some(mk) = &mask {
                mk.iter().map(|&v| v as f64).collect()
            } else {
                vec![]
            };
            let e = self.energy.train_step(&m.method, &fracs, sm.psg_frac);
            ledger.charge(iter, &e, self.energy.step_macs(&fracs));

            for (g, f) in gate_means.iter_mut().zip(fracs.iter()) {
                g.push(*f);
            }
            if let Some(p) = sm.psg_frac {
                psg_mean.push(p);
            }

            // SWA (enabled for PSG-family runs, Sec. 4.1).  This is one
            // of the few places resident state syncs to host.
            if self.cfg.swa && swa.should_average(iter) {
                let w = swa.observe();
                let snap = backend.sync_master()?;
                match &mut swa_model {
                    None => swa_model = Some(snap),
                    Some(sw) => {
                        sw.average_params_from(&snap, w, self.program.num_params)
                    }
                }
                // Publish the refreshed SWA checkpoint to an attached
                // serve pool — mid-flight, the serve queue never drains.
                if let (Some(cell), Some(sw)) = (&self.publish, &swa_model) {
                    cell.publish(StateSnapshot::from_model_state(
                        self.program.backend(),
                        sw,
                    )?);
                }
            }

            if iter % record_every == 0 || iter + 1 == self.cfg.iters {
                let train_acc = sm.correct / self.program.batch() as f64;
                let test_acc = if self.cfg.eval_every > 0
                    && iter % self.cfg.eval_every == 0
                {
                    Some(self.evaluate_backend(backend.as_ref())?.0)
                } else {
                    None
                };
                metrics.record(iter, sm.loss, train_acc, ledger.total_joules(), test_acc);
            }
        }

        // Final checkpoint at the `iters` boundary (regardless of
        // divisibility): resuming it re-derives the final outcome, and
        // a registry watcher serving this run picks up the last weights
        // (SWA average included via the checkpoint's serving state).
        if let (Some(w), Some(sh)) = (&ckpt_writer, &shadow) {
            if self.cfg.iters != start_iter {
                w.submit(snapshot_checkpoint(
                    &self.cfg, self.cfg.iters, backend.as_ref(), sh, &smd, &sd,
                    &swa, &swa_model, &ledger, &metrics, &gate_means, &psg_mean,
                )?)?;
            }
        }
        if let Some(w) = ckpt_writer.take() {
            let published = w.finish()?;
            eprintln!(
                "[ckpt] {published} checkpoint(s) published -> {}",
                self.cfg
                    .checkpoint
                    .dir
                    .as_deref()
                    .unwrap_or_else(|| std::path::Path::new("?"))
                    .display()
            );
        }
        // Drain the replicator *after* the writer: its final sync picks
        // up the boundary checkpoint published above.  A parked upload
        // error fails the run here — under supervision that is a
        // transient the next attempt outlives (staged bytes resume).
        let mut replica_report = None;
        if let Some(r) = replicator.take() {
            let report = r.finish()?;
            eprintln!(
                "[replicate] {} checkpoint(s) evacuated ({} bytes, {} resumed, \
                 {} vanished) -> {}",
                report.uploaded,
                report.bytes,
                report.retries,
                report.skipped_vanished,
                self.cfg
                    .checkpoint
                    .replicate
                    .as_deref()
                    .unwrap_or_else(|| std::path::Path::new("?"))
                    .display()
            );
            replica_report = Some(report);
        }

        // Bench/metrics attribution: which execution backend ran the
        // loop, and over how many shards (0 = single-executor).
        metrics.backend = backend.name().to_string();
        metrics.shards = backend.shard_count();

        // Final evaluation — SWA weights if averaging ran.
        let final_state = match swa_model {
            Some(sw) => sw,
            None => backend.into_state()?,
        };
        // Publish the final checkpoint (SWA weights when averaging ran).
        if let Some(cell) = &self.publish {
            cell.publish(StateSnapshot::from_model_state(
                self.program.backend(),
                &final_state,
            )?);
        }
        let (acc, acc5, loss) = self.evaluate_full(&final_state)?;
        metrics.final_test_acc = acc;
        metrics.final_test_acc_top5 = acc5;
        metrics.final_loss = loss;
        metrics.total_joules = ledger.total_joules();
        metrics.executed_macs = ledger.macs;
        metrics.steps_run = ledger.steps_charged;
        metrics.steps_skipped = ledger.steps_skipped;
        metrics.wall_seconds = t0.elapsed().as_secs_f64() + wall_offset_s;
        metrics.mean_gate_fracs = gate_means.iter().map(|g| g.get()).collect();
        metrics.mean_psg_frac =
            if psg_mean.count() > 0 { Some(psg_mean.get()) } else { None };
        metrics.prefetch_depth = prefetch_depth;
        if let Some(c) = &prune_failures {
            metrics.prune_failures = c.load(std::sync::atomic::Ordering::Relaxed);
        }
        if let Some(r) = &replica_report {
            metrics.replica_lag_iters = r.lag_iters;
            metrics.replica_bytes = r.bytes;
            metrics.replica_retries = r.retries;
            metrics.replica_skipped_vanished = r.skipped_vanished;
        }

        // Planning-layer accounting: actuals measured on the same obs
        // substrate the predictions came from, then the catalog learns
        // this run.  Ordered before the trace snapshot below so the
        // `plan` row carries the final predicted-vs-actual numbers.
        let step_hist = self.obs.phase_histogram(crate::obs::PHASE_STEP_EXEC);
        if let Some(mut rec) = plan_record {
            let actual_sps = step_hist
                .as_ref()
                .map(|h| 1e9 / h.mean().max(1.0))
                .unwrap_or(0.0);
            let actual_jps = if ledger.steps_charged > 0 {
                ledger.total_joules() / ledger.steps_charged as f64
            } else {
                0.0
            };
            rec.record_actuals(actual_sps, actual_jps);
            eprintln!(
                "[plan] predicted {:.1} steps/s vs actual {:.1} ({:+.1}%)",
                rec.predicted_sps,
                rec.actual_sps,
                rec.sps_rel_err * 100.0
            );
            self.obs.set_plan(rec.clone());
            metrics.plan = Some(rec);
        }
        if let Some(path) = &catalog_path {
            // Recalibration: fold this run's measured step/augment
            // distributions and its charged energy into the catalog
            // (reloaded — another run may have written since planning).
            let mut run_obs = Observation {
                joules: ledger.total_joules(),
                joule_steps: ledger.steps_charged,
                ..Default::default()
            };
            if let Some(h) = step_hist {
                run_obs.step_ns = h;
            }
            if let Some(h) = self.obs.phase_histogram(crate::obs::PHASE_AUGMENT) {
                run_obs.augment_ns = h;
            }
            if let Some(h) = self.obs.phase_histogram(crate::obs::PHASE_SHARD_REDUCE) {
                run_obs.reduce_ns = h;
            }
            if run_obs.step_ns.count() > 0 {
                let mut catalog = Catalog::load_or_empty(path)?;
                catalog.observe(
                    CatalogKey {
                        family: self.cfg.family.clone(),
                        method: self.cfg.method.clone(),
                        backend: metrics.backend.clone(),
                        shards: metrics.shards,
                        batch: self.program.batch(),
                    },
                    &run_obs,
                );
                catalog.save(path)?;
                eprintln!("[obs] catalog recalibrated -> {}", path.display());
            }
        }

        // Fold the per-phase summary into the run metrics and, when
        // requested, write the full `obs_trace/v1` event log.  Both are
        // strictly observability-plane: nothing upstream of this point
        // read a clock that fed the training stream.
        if let Some(trace) = self.obs.snapshot() {
            metrics.obs = Some(trace.summary.clone());
            if let Some(p) = &self.cfg.trace_out {
                trace.write(p)?;
                eprintln!("[obs] trace -> {}", p.display());
            }
        }

        eprintln!(
            "[run] {}/{}: acc {:.4}, {:.2} J, {} steps ({} skipped), {:.1}s",
            self.cfg.family,
            self.cfg.method,
            acc,
            metrics.total_joules,
            metrics.steps_run,
            metrics.steps_skipped,
            metrics.wall_seconds
        );
        Ok(RunOutcome { metrics, state: final_state, ledger })
    }

    /// Time one train step without perturbing the run — the depth
    /// auto-tuner's denominator.  [`StepBackend::probe_step`] guarantees
    /// invisibility (clone-and-step or step-and-restore), so prefetch
    /// on/off stay bitwise equivalent on every backend.
    fn probe_step_time(
        &self,
        backend: &mut dyn StepBackend,
        batch: &(HostTensor, HostTensor),
        needs_mask: bool,
        num_gated: usize,
    ) -> Result<f64> {
        let mask: Option<Vec<f32>> = if needs_mask {
            Some(vec![1.0; num_gated])
        } else {
            None
        };
        let hp = StepHyper {
            lr: self.cfg.lr.at(0) as f32,
            alpha: self.cfg.alpha as f32,
            beta: self.cfg.beta as f32,
        };
        let (x, y) = batch;
        backend.probe_step(x, y, hp, mask.as_deref())
    }

    /// Periodic eval against the live training state, through the
    /// backend's cheapest route (resident state evaluates in place; a
    /// host-side master evaluates directly).
    fn evaluate_backend(&self, backend: &dyn StepBackend) -> Result<(f64, f64, f64)> {
        self.eval_batches(|x, y| backend.eval_batch(x, y))
    }

    /// (accuracy, top5, loss) over the full test set in `eval_batch`
    /// chunks, host-path state.
    pub fn evaluate_full(&self, state: &ModelState) -> Result<(f64, f64, f64)> {
        self.eval_batches(|x, y| self.program.eval_batch_run(state, x, y))
    }

    /// Drive `run_batch` over the whole test set, including the tail
    /// remainder when `eval_batch` does not divide it: the last chunk is
    /// padded with zero images and label `-1`.  Padded rows contribute
    /// nothing to any metric (`one_hot(-1) == 0` zeroes their loss and
    /// `-1` never matches a prediction), so totals are normalized by the
    /// true sample count.  The seed runtime silently dropped up to
    /// `eval_batch - 1` trailing samples — and errored on test sets
    /// smaller than one eval batch, which now just work.
    fn eval_batches(
        &self,
        mut run_batch: impl FnMut(&HostTensor, &HostTensor) -> Result<EvalMetrics>,
    ) -> Result<(f64, f64, f64)> {
        let eb = self.program.eval_batch();
        let hw = self.test_set.hw;
        let stride = hw * hw * 3;
        let n = self.test_set.n;
        if n == 0 {
            return Err(anyhow!("empty test set"));
        }
        let mut correct = 0.0;
        let mut correct5 = 0.0;
        let mut loss_sum = 0.0;
        let nb = n / eb;
        for b in 0..nb {
            let lo = b * eb;
            let x = HostTensor::f32(
                vec![eb, hw, hw, 3],
                self.test_set.images[lo * stride..(lo + eb) * stride].to_vec(),
            );
            let y = HostTensor::i32(
                vec![eb],
                self.test_set.labels[lo..lo + eb].to_vec(),
            );
            let em = run_batch(&x, &y)?;
            correct += em.correct;
            correct5 += em.correct5;
            loss_sum += em.loss * eb as f64;
        }
        let rem = n % eb;
        if rem > 0 {
            let lo = nb * eb;
            let mut px = vec![0f32; eb * stride];
            px[..rem * stride]
                .copy_from_slice(&self.test_set.images[lo * stride..(lo + rem) * stride]);
            let mut py = vec![-1i32; eb];
            py[..rem].copy_from_slice(&self.test_set.labels[lo..lo + rem]);
            let em = run_batch(
                &HostTensor::f32(vec![eb, hw, hw, 3], px),
                &HostTensor::i32(vec![eb], py),
            )?;
            correct += em.correct;
            correct5 += em.correct5;
            loss_sum += em.loss * eb as f64;
        }
        Ok((
            correct / n as f64,
            correct5 / n as f64,
            loss_sum / n as f64,
        ))
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }
}
