//! The planning layer: `cfg.backend = "auto"` resolves into a concrete
//! execution layout here, against the calibrated cost catalog
//! ([`crate::obs::catalog`]).
//!
//! The planner enumerates candidate plans — backend × shard count ×
//! prefetch on/off (with a pinned channel depth) — predicts steps/sec
//! and J/step for each from the catalog's measured histograms, and
//! picks the fastest plan that fits the optional `cfg.energy_budget_j`
//! hint.  When a candidate's catalog key has never been measured, a
//! short seeded calibration probe times it live ([`PROBE_STEPS`]
//! invisible `probe_step`s on a cloned init state) and folds the
//! measurement into the catalog, so the very first `auto` run already
//! plans from real numbers.
//!
//! Planning is a pure layout choice: every candidate is bitwise
//! interchangeable by the backend-matrix contract, probe steps restore
//! state by the `probe_step` contract, and the probe sampler is a
//! throwaway (the run builds its own from the same start later) — so
//! an `auto` run is bitwise identical to the same plan requested
//! explicitly (tests/planner_matrix.rs).
//!
//! Selection is deterministic for a given catalog: candidates are
//! enumerated in a fixed order (host, resident, sharded S=1..3; within
//! each, prefetch-on before prefetch-off) and compared strictly, so
//! equal predictions resolve to the earliest candidate.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::{BackendChoice, RunCfg};
use crate::data::{prefetch, AugmentCfg, Dataset, Sampler};
use crate::obs::catalog::{
    Catalog, CatalogKey, Observation, PlanRecord, DEFAULT_CATALOG_FILE, SERVE_BACKEND,
};
use crate::runtime::{prepare_backend, Engine, ModelState, StepHyper, TrainProgram};

/// Steps each calibration probe times.  Probes are invisible
/// (`StepBackend::probe_step` restores state), so this costs wall time
/// only — never determinism.
pub const PROBE_STEPS: usize = 2;

/// Largest data-parallel shard count the planner considers.
pub const MAX_PLAN_SHARDS: usize = 3;

/// Everything `plan_run` needs from the trainer, borrowed — the plan it
/// returns owns no part of this.
pub struct PlanInputs<'a> {
    pub engine: &'a Engine,
    pub program: &'a TrainProgram,
    pub cfg: &'a RunCfg,
    /// The run's initial model state; probes step a clone of it.
    pub init: &'a ModelState,
    /// In-memory training set for calibration probes; `None` when the
    /// source streams from disk (deferred CIFAR) — missing keys then
    /// drop their candidates instead of probing.
    pub data: Option<&'a Arc<Dataset>>,
}

/// A chosen execution layout, ready to hand to `prepare_backend`, plus
/// the [`PlanRecord`] that carries its predictions into the run's
/// metrics and trace.
pub struct Plan {
    pub choice: BackendChoice,
    pub shards: usize,
    pub prefetch: bool,
    /// Pinned prefetch channel depth (None ⇒ prefetch off, or the
    /// fallback plan that lets the run auto-tune as usual).
    pub prefetch_depth: Option<usize>,
    pub record: PlanRecord,
}

/// One evaluated candidate: a layout with its predictions attached.
struct PlanEval {
    choice: BackendChoice,
    shards: usize,
    prefetch: bool,
    depth: Option<usize>,
    /// Predicted steps/sec.
    sps: f64,
    /// Predicted J/step (None = no energy ever charged for this
    /// workload, not even under another layout).
    jps: Option<f64>,
}

/// Where this run's catalog lives: an explicit `cfg.catalog` wins;
/// `backend = "auto"` without one uses [`DEFAULT_CATALOG_FILE`] in the
/// working directory (next to the BENCH reports).  `None` means the
/// run neither reads nor recalibrates a catalog — explicitly opting a
/// non-auto run in is what `cfg.catalog` is for.
pub fn catalog_path(cfg: &RunCfg) -> Option<PathBuf> {
    match (&cfg.catalog, cfg.resolved_backend()) {
        (Some(p), _) => Some(p.clone()),
        (None, BackendChoice::Auto) => Some(PathBuf::from(DEFAULT_CATALOG_FILE)),
        (None, _) => None,
    }
}

/// Resolve `backend = "auto"` into a concrete plan.  Probe measurements
/// (if any ran) are folded into `catalog`; the caller persists it.
pub fn plan_run(inp: &PlanInputs, catalog: &mut Catalog) -> Result<Plan> {
    let batch = inp.program.batch();
    // SD masks are rejected by the sharded backend (mask gating is a
    // whole-batch contract), so those candidates never enter the race.
    let needs_mask = inp.program.manifest.method.gating == "mask";
    let mut candidates = vec![(BackendChoice::Host, 0usize), (BackendChoice::Resident, 0)];
    if !needs_mask {
        for s in 1..=MAX_PLAN_SHARDS {
            candidates.push((BackendChoice::Sharded, s));
        }
    }

    let mut probed = false;
    let mut evals: Vec<PlanEval> = Vec::new();
    for (choice, shards) in candidates {
        let key = CatalogKey {
            family: inp.cfg.family.clone(),
            method: inp.cfg.method.clone(),
            backend: choice.as_str().to_string(),
            shards,
            batch,
        };
        let known = catalog
            .get(&key)
            .map(|e| e.step_ns.count() > 0)
            .unwrap_or(false);
        if !known {
            let Some(data) = inp.data else {
                // Streaming source: nothing to probe with — the key
                // stays unknown and the candidate drops out.
                continue;
            };
            match probe_candidate(inp, data, choice, shards, needs_mask) {
                Ok(o) => {
                    catalog.observe(key.clone(), &o);
                    probed = true;
                }
                Err(e) => {
                    // e.g. the artifact ships no grad program for the
                    // sharded path — the candidate is not runnable here.
                    eprintln!(
                        "[plan] candidate {}/s{shards} dropped: {e:#}",
                        choice.as_str()
                    );
                    continue;
                }
            }
        }
        let entry = catalog.get(&key).expect("known or just probed");
        let Some(step) = entry.step_mean_ns() else { continue };
        // Overlapped reduce: the measured step wall already includes the
        // host reduce serial after shard compute (overlap-off probes, or
        // pre-pipeline catalogs).  With the reducer pipelined the step
        // costs max(compute, reduce), not their sum — credit back the
        // hidden leg.  Entries without reduce data are left untouched.
        let step = match entry.reduce_mean_ns() {
            Some(reduce) if reduce < step => (step - reduce).max(reduce),
            _ => step,
        };
        let aug = entry
            .augment_mean_ns()
            .or_else(|| augment_any_layout(catalog, inp.cfg, batch))
            .unwrap_or(0.0);
        let jps = entry.j_per_step().or_else(|| {
            catalog.j_per_step_any_layout(&inp.cfg.family, &inp.cfg.method, batch)
        });
        // With the pipeline on, batch assembly overlaps dispatch: the
        // slower leg bounds throughput.  Off, the legs serialize.
        // Prefetch-on enumerates first so equal predictions (augment
        // cost unknown/zero) keep the pipelined default.
        let depth = prefetch::auto_depth(aug / 1e9, step / 1e9);
        evals.push(PlanEval {
            choice,
            shards,
            prefetch: true,
            depth: Some(depth),
            sps: 1e9 / step.max(aug).max(1.0),
            jps,
        });
        evals.push(PlanEval {
            choice,
            shards,
            prefetch: false,
            depth: None,
            sps: 1e9 / (step + aug).max(1.0),
            jps,
        });
    }

    if evals.is_empty() {
        // Nothing measured and nothing probeable: fall back to the
        // system default layout rather than failing the run.  Depth
        // stays unpinned so the run auto-tunes as a non-planned run
        // would.
        eprintln!("[plan] empty catalog and no probeable source; defaulting to resident");
        let record = PlanRecord {
            backend: BackendChoice::Resident.as_str().to_string(),
            prefetch: true,
            probed,
            ..Default::default()
        };
        return Ok(Plan {
            choice: BackendChoice::Resident,
            shards: 0,
            prefetch: true,
            prefetch_depth: None,
            record,
        });
    }

    // The budget compares predicted whole-run energy, so scale J/step
    // by the steps that will actually execute (SMD drops are never
    // charged).
    let expected_steps = {
        let keep = if inp.cfg.smd.enabled { 1.0 - inp.cfg.smd.p } else { 1.0 };
        inp.cfg.iters as f64 * keep
    };
    let pick = &evals[select(&evals, inp.cfg.energy_budget_j, expected_steps)];
    let record = PlanRecord {
        backend: pick.choice.as_str().to_string(),
        shards: pick.shards,
        prefetch: pick.prefetch,
        prefetch_depth: pick.depth,
        probed,
        predicted_sps: pick.sps,
        predicted_j_per_step: pick.jps.unwrap_or(0.0),
        ..Default::default()
    };
    Ok(Plan {
        choice: pick.choice,
        shards: pick.shards,
        prefetch: pick.prefetch,
        prefetch_depth: pick.depth,
        record,
    })
}

/// Pick the index of the winning candidate: highest predicted
/// steps/sec, under the optional whole-run energy budget.  Strict
/// comparisons over the fixed enumeration order make ties
/// deterministic.
fn select(evals: &[PlanEval], budget: Option<f64>, expected_steps: f64) -> usize {
    let total = |e: &PlanEval| e.jps.map(|j| j * expected_steps);
    let fastest = |ix: Vec<usize>| {
        ix.iter()
            .copied()
            .fold(ix[0], |best, i| if evals[i].sps > evals[best].sps { i } else { best })
    };
    if let Some(b) = budget {
        // A candidate with unknown energy is taken at its word — there
        // is nothing to compare it against.
        let fits: Vec<usize> = (0..evals.len())
            .filter(|&i| total(&evals[i]).map(|t| t <= b).unwrap_or(true))
            .collect();
        if !fits.is_empty() {
            return fastest(fits);
        }
        // Nothing fits: minimize predicted energy (every candidate has
        // a known total here, or `fits` would be non-empty).
        return (0..evals.len()).fold(0, |best, i| {
            match (total(&evals[i]), total(&evals[best])) {
                (Some(a), Some(bst)) if a < bst => i,
                _ => best,
            }
        });
    }
    fastest((0..evals.len()).collect())
}

/// Augment cost is layout-invariant (batch assembly happens upstream
/// of the backend), so any sibling training entry that measured it
/// predicts it for a layout never run before.
fn augment_any_layout(catalog: &Catalog, cfg: &RunCfg, batch: usize) -> Option<f64> {
    catalog
        .entries()
        .find(|e| {
            e.key.family == cfg.family
                && e.key.method == cfg.method
                && e.key.batch == batch
                && e.key.backend != SERVE_BACKEND
                && e.augment_ns.count() > 0
        })
        .map(|e| e.augment_ns.mean())
}

/// Time one missing-key candidate live: [`PROBE_STEPS`] batches from a
/// throwaway sampler (the run builds its own from the same start later,
/// so the real stream is untouched), each assembled (timed as augment)
/// and stepped through the invisible `probe_step`.
fn probe_candidate(
    inp: &PlanInputs,
    data: &Arc<Dataset>,
    choice: BackendChoice,
    shards: usize,
    needs_mask: bool,
) -> Result<Observation> {
    // Probes always run accum = 1: accum is bitwise inert and the
    // catalog keys layouts by (backend, shards, batch) only.
    let mut backend = prepare_backend(
        inp.engine,
        inp.program,
        &inp.cfg.manifest_path(),
        choice,
        shards,
        1,
        inp.init.clone(),
    )?;
    let mut sampler = Sampler::new(
        data.n,
        inp.program.batch(),
        AugmentCfg::default(),
        inp.cfg.seed ^ 0xda7a,
    );
    let mask: Option<Vec<f32>> =
        needs_mask.then(|| vec![1.0; inp.program.manifest.num_gated()]);
    let hp = StepHyper {
        lr: inp.cfg.lr.at(0) as f32,
        alpha: inp.cfg.alpha as f32,
        beta: inp.cfg.beta as f32,
    };
    let mut obs = Observation { probe: true, ..Default::default() };
    for _ in 0..PROBE_STEPS {
        let t0 = Instant::now();
        let (x, y) = sampler.next_batch(data);
        obs.augment_ns.observe((t0.elapsed().as_nanos() as u64).max(1));
        let secs = backend.probe_step(&x, &y, hp, mask.as_deref())?;
        obs.step_ns.observe(((secs * 1e9) as u64).max(1));
    }
    Ok(obs)
}

/// Serve-side planning: pick the micro-batch with the highest predicted
/// samples/sec from the catalog's [`SERVE_BACKEND`] entries for this
/// (family, method).  Returns `(micro_batch, predicted_samples_per_sec)`;
/// `None` until a serve bench has measured something.
pub fn choose_micro_batch(catalog: &Catalog, family: &str, method: &str) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for e in catalog.entries() {
        if e.key.backend != SERVE_BACKEND || e.key.family != family || e.key.method != method {
            continue;
        }
        let Some(mean) = e.step_mean_ns() else { continue };
        let sps = e.key.batch as f64 * 1e9 / mean.max(1.0);
        // Strict > over the catalog's BTreeMap order keeps ties
        // deterministic (smallest micro-batch wins).
        if best.map(|(_, b)| sps > b).unwrap_or(true) {
            best = Some((e.key.batch, sps));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        choice: BackendChoice,
        shards: usize,
        prefetch: bool,
        sps: f64,
        jps: Option<f64>,
    ) -> PlanEval {
        PlanEval { choice, shards, prefetch, depth: prefetch.then_some(2), sps, jps }
    }

    #[test]
    fn selection_is_fastest_and_ties_resolve_to_enumeration_order() {
        let evals = vec![
            ev(BackendChoice::Host, 0, true, 100.0, Some(0.2)),
            ev(BackendChoice::Host, 0, false, 80.0, Some(0.2)),
            ev(BackendChoice::Resident, 0, true, 140.0, Some(0.2)),
            ev(BackendChoice::Sharded, 2, true, 140.0, Some(0.3)),
        ];
        // No budget: fastest wins; the tie at 140.0 goes to the earlier
        // candidate (resident), not the later sharded one.
        assert_eq!(select(&evals, None, 100.0), 2);
    }

    #[test]
    fn energy_budget_filters_then_minimizes() {
        let evals = vec![
            ev(BackendChoice::Host, 0, true, 100.0, Some(0.5)),
            ev(BackendChoice::Resident, 0, true, 200.0, Some(1.0)),
            ev(BackendChoice::Sharded, 2, true, 300.0, Some(2.0)),
        ];
        // Budget admits host + resident (totals 50 / 100 over 100
        // steps): the faster of those wins even though sharded is
        // faster still.
        assert_eq!(select(&evals, Some(100.0), 100.0), 1);
        // Budget admits nothing: minimum predicted energy wins.
        assert_eq!(select(&evals, Some(10.0), 100.0), 0);
        // Unknown energy is taken at its word under a budget.
        let evals2 = vec![
            ev(BackendChoice::Host, 0, true, 100.0, Some(0.5)),
            ev(BackendChoice::Resident, 0, true, 400.0, None),
        ];
        assert_eq!(select(&evals2, Some(1.0), 100.0), 1);
    }

    #[test]
    fn micro_batch_comes_from_serve_entries_only() {
        let mut cat = Catalog::new();
        let serve_key = |b: usize| CatalogKey {
            family: "refmlp-tiny".into(),
            method: "sgd32".into(),
            backend: SERVE_BACKEND.into(),
            shards: 0,
            batch: b,
        };
        assert_eq!(choose_micro_batch(&cat, "refmlp-tiny", "sgd32"), None);
        // b=4 at 1ms/infer = 4000 samples/s; b=8 at 4ms = 2000.
        let mut o4 = Observation::default();
        o4.step_ns.observe(1_000_000);
        cat.observe(serve_key(4), &o4);
        let mut o8 = Observation::default();
        o8.step_ns.observe(4_000_000);
        cat.observe(serve_key(8), &o8);
        // A training entry with the same batch must not leak in.
        let mut t = Observation::default();
        t.step_ns.observe(1);
        cat.observe(
            CatalogKey {
                family: "refmlp-tiny".into(),
                method: "sgd32".into(),
                backend: "host".into(),
                shards: 0,
                batch: 4,
            },
            &t,
        );
        let (mb, sps) = choose_micro_batch(&cat, "refmlp-tiny", "sgd32").unwrap();
        assert_eq!(mb, 4);
        assert!(sps > 2_000.0 && sps < 8_000.0, "{sps}");
        assert_eq!(choose_micro_batch(&cat, "other", "sgd32"), None);
    }

    #[test]
    fn catalog_path_prefers_explicit_then_auto_default() {
        let mut cfg = RunCfg::quick("refmlp-tiny", "sgd32", 4);
        assert_eq!(catalog_path(&cfg), None);
        cfg.backend = Some(BackendChoice::Auto);
        assert_eq!(catalog_path(&cfg), Some(PathBuf::from(DEFAULT_CATALOG_FILE)));
        cfg.catalog = Some(PathBuf::from("custom/cat.json"));
        assert_eq!(catalog_path(&cfg), Some(PathBuf::from("custom/cat.json")));
    }
}
