//! The `serve` bench scenario: N closed-loop synthetic clients hammer
//! the micro-batching inference service and we record throughput,
//! latency percentiles and micro-batch occupancy per concurrency level
//! into `BENCH_serve.json` (schema `bench_serve/v1`, see PERF.md).
//!
//! The point of measuring ≥2 concurrency levels is the occupancy curve:
//! a single client rarely fills a micro-batch before the deadline, so
//! the fixed per-launch cost is unamortized; as concurrency grows the
//! batcher coalesces more samples per launch and throughput rises
//! faster than latency — the serving analogue of the training-side
//! energy savings this repo reproduces.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::planner;
use crate::data::synthetic;
use crate::obs::catalog::{Catalog, CatalogKey, Observation, SERVE_BACKEND};
use crate::obs::{Obs, PHASE_SERVE_INFER};
use crate::runtime::{
    write_reference_family, BackendKind, Engine, ModelState, RefFamilySpec,
    SnapshotCell, StateSnapshot, TrainProgram,
};
use crate::serve::{ServeCfg, ServeService};
use crate::util::tmp::TempDir;
use crate::util::Json;

/// Bench workload shape.
#[derive(Debug, Clone)]
pub struct ServeBenchCfg {
    /// Client concurrency levels to sweep (≥2 for the occupancy curve).
    pub levels: Vec<usize>,
    pub requests_per_client: usize,
    /// Samples per request (1 = pure single-sample traffic).
    pub samples_per_request: usize,
    /// Serve worker threads.
    pub workers: usize,
    /// Batcher flush deadline.
    pub max_delay: Duration,
    pub seed: u64,
    /// Serve weights from this checkpoint registry instead of a
    /// freshly-initialized state: no in-process trainer — the bench
    /// waits for the watcher's first hot-load, exercising the
    /// cross-process publish path end-to-end.
    pub registry: Option<PathBuf>,
    /// Serve from a **replicated** registry root instead (the replica a
    /// training box evacuates to via `checkpoint.replicate`): same
    /// hot-load path, but every fetch is hash- and trailer-verified —
    /// a serve fleet in another failure domain needs no local registry.
    /// Mutually exclusive with `registry`.
    pub replica: Option<PathBuf>,
    /// Explicit serve micro-batch override (`None`: the artifact's
    /// eval batch, or the catalog's pick under `auto_micro_batch`).
    pub micro_batch: Option<usize>,
    /// Let the planner pick the micro-batch with the highest predicted
    /// samples/sec from the catalog's measured serve entries
    /// (`e2train serve --micro-batch auto`).
    pub auto_micro_batch: bool,
    /// Cost catalog (`obs_catalog/v1`) to plan from; the bench's
    /// measured serve-infer spans recalibrate it afterwards.
    pub catalog: Option<PathBuf>,
    /// Provenance string recorded in the report (producer + profile).
    pub source: String,
}

impl Default for ServeBenchCfg {
    fn default() -> Self {
        Self {
            levels: vec![2, 8],
            requests_per_client: 32,
            samples_per_request: 2,
            workers: 2,
            max_delay: Duration::from_millis(2),
            seed: 0,
            registry: None,
            replica: None,
            micro_batch: None,
            auto_micro_batch: false,
            catalog: None,
            source: "serve_bench".into(),
        }
    }
}

/// Resolve the manifest the bench serves: an explicitly requested
/// family must exist (a typo'd `--family` silently benching the tiny
/// fixture would mislabel `BENCH_serve.json`); with no family given,
/// fall back to a generated reference fixture.  The returned `TempDir`
/// guard (fixture case) must outlive the bench run.
pub fn resolve_bench_family(
    artifacts: &Path,
    family: Option<&str>,
    fixture: &RefFamilySpec,
) -> Result<(PathBuf, Option<TempDir>)> {
    if let Some(f) = family {
        let p = artifacts.join(f).join("sgd32.json");
        if !p.exists() {
            bail!(
                "family {f} has no sgd32 artifact under {} (omit --family to bench \
                 the generated reference fixture)",
                artifacts.display()
            );
        }
        return Ok((p, None));
    }
    let tmp = TempDir::new()?;
    let fam = write_reference_family(tmp.path(), fixture)?;
    Ok((fam.join("sgd32.json"), Some(tmp)))
}

/// Block until the watcher publishes its first snapshot (a checkpoint
/// must already exist — or soon appear — under `src`); `kind` labels
/// the source in the timeout message and the progress line.
fn wait_first_snapshot(cell: &SnapshotCell, src: &Path, kind: &str) -> Result<()> {
    let t0 = Instant::now();
    while cell.version() == 0 {
        if t0.elapsed() > Duration::from_secs(10) {
            bail!("no checkpoint appeared under {kind} {} within 10s", src.display());
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("serve: {kind} {} -> snapshot v{}", src.display(), cell.version());
    Ok(())
}

/// Run the sweep and return the `bench_serve/v1` report.
pub fn run_serve_bench(
    engine: &Engine,
    manifest_path: &Path,
    cfg: &ServeBenchCfg,
) -> Result<Json> {
    // Eval-only: the bench never trains, so the probe skips the
    // train-program compile just like the serve workers do.
    let probe = TrainProgram::load_eval_only(engine, manifest_path)?;
    let hw = probe.manifest.arch.image_size;
    let classes = probe.manifest.arch.num_classes;
    let stride = hw * hw * 3;

    // Serve-side planning: an explicit micro-batch wins; `auto` asks
    // the catalog for the fastest measured one; otherwise the
    // artifact's eval batch.  Either way the measured serve-infer
    // spans recalibrate the catalog at the end when one is attached.
    let mut catalog = cfg.catalog.as_deref().map(Catalog::load_or_empty).transpose()?;
    let mut predicted_sps: Option<f64> = None;
    let default_mb = probe.eval_batch();
    let (micro_batch, mb_source) = if let Some(m) = cfg.micro_batch {
        (m.max(1), "explicit")
    } else if cfg.auto_micro_batch {
        let cat = catalog
            .as_ref()
            .ok_or_else(|| anyhow!("--micro-batch auto needs a catalog (--catalog <path>)"))?;
        match planner::choose_micro_batch(cat, probe.family(), probe.method()) {
            Some((m, sps)) => {
                println!(
                    "serve: catalog picked micro-batch {m} (predicted {sps:.0} samples/s)"
                );
                predicted_sps = Some(sps);
                (m, "catalog")
            }
            None => {
                println!(
                    "serve: catalog has no serve entries for {}/{} yet; \
                     defaulting micro-batch to {default_mb}",
                    probe.family(),
                    probe.method()
                );
                (default_mb, "default")
            }
        }
    } else {
        (default_mb, "default")
    };

    // Shared resident state for the whole sweep: a freshly-initialized
    // snapshot by default (the serve integration with a live trainer is
    // exercised by tests/serve_equivalence.rs), or — with a registry —
    // whatever checkpoint a trainer process last published there,
    // hot-loaded by the watcher with no in-process trainer at all.
    let cell = Arc::new(SnapshotCell::new());
    let _watcher = match (&cfg.registry, &cfg.replica) {
        (Some(_), Some(_)) => {
            bail!("--registry and --replica are mutually exclusive (one source of truth)")
        }
        (None, None) => {
            let state = ModelState::init(&probe.manifest, cfg.seed);
            cell.publish(StateSnapshot::from_model_state(probe.backend(), &state)?);
            None
        }
        (Some(dir), None) => {
            let w = crate::serve::watch_registry(
                cell.clone(),
                probe.backend(),
                Arc::new(probe.manifest.state_spec()),
                dir,
                Duration::from_millis(50),
            );
            wait_first_snapshot(&cell, dir, "registry")?;
            Some(w)
        }
        (None, Some(root)) => {
            let w = crate::serve::watch_replica(
                cell.clone(),
                probe.backend(),
                Arc::new(probe.manifest.state_spec()),
                root,
                Duration::from_millis(50),
            );
            wait_first_snapshot(&cell, root, "replica")?;
            Some(w)
        }
    };

    let data = synthetic::generate(classes, 256, hw, cfg.seed);
    let req_size = cfg.samples_per_request.max(1);

    let mut rows = Vec::new();
    // Measured serve-infer spans across all levels (same micro-batch ⇒
    // same catalog key), folded back into the catalog after the sweep.
    let mut measured = Observation::default();
    for &clients in &cfg.levels {
        let clients = clients.max(1);
        let obs = Obs::new(false);
        let service = ServeService::start(
            engine,
            manifest_path,
            cell.clone(),
            ServeCfg {
                workers: cfg.workers,
                queue_cap: (clients * 2).max(16),
                max_delay: cfg.max_delay,
                micro_batch: Some(micro_batch),
                obs: obs.clone(),
                ..Default::default()
            },
        )?;
        let t0 = Instant::now();
        let samples_done = std::thread::scope(|scope| -> Result<usize> {
            let mut handles = Vec::new();
            for c in 0..clients {
                let client = service.client();
                let data = &data;
                handles.push(scope.spawn(move || -> Result<usize> {
                    let mut done = 0usize;
                    for r in 0..cfg.requests_per_client {
                        // Deterministic per-(client, request) sample walk.
                        let base = (c * cfg.requests_per_client + r) * req_size;
                        let mut px = Vec::with_capacity(req_size * stride);
                        let mut py = Vec::with_capacity(req_size);
                        for j in 0..req_size {
                            let idx = (base + j) % data.n;
                            px.extend_from_slice(
                                &data.images[idx * stride..(idx + 1) * stride],
                            );
                            py.push(data.labels[idx]);
                        }
                        done += client.submit(&px, &py)?.wait()?.len();
                    }
                    Ok(done)
                }));
            }
            let mut total = 0;
            for h in handles {
                total += h.join().map_err(|_| anyhow!("serve client panicked"))??;
            }
            Ok(total)
        })?;
        let wall = t0.elapsed().as_secs_f64();
        let stats = service.shutdown();
        if let Some(h) = obs.phase_histogram(PHASE_SERVE_INFER) {
            measured.step_ns.merge(&h);
        }
        println!(
            "serve: {clients:>3} clients  {:>8.1} samp/s  p50 {:>7.3}ms  p99 {:>7.3}ms  occupancy {:>5.2}/{micro_batch} ({} batches)",
            samples_done as f64 / wall.max(1e-9),
            stats.latency_p50_s * 1e3,
            stats.latency_p99_s * 1e3,
            stats.occupancy_mean,
            stats.batches,
        );
        rows.push(Json::obj(vec![
            ("clients", Json::num(clients as f64)),
            (
                "requests",
                Json::num((clients * cfg.requests_per_client) as f64),
            ),
            ("samples", Json::num(samples_done as f64)),
            (
                "throughput_sps",
                Json::num(samples_done as f64 / wall.max(1e-9)),
            ),
            ("latency_p50_ms", Json::num(stats.latency_p50_s * 1e3)),
            ("latency_p99_ms", Json::num(stats.latency_p99_s * 1e3)),
            ("latency_mean_ms", Json::num(stats.latency_mean_s * 1e3)),
            ("mean_occupancy", Json::num(stats.occupancy_mean)),
            ("batches", Json::num(stats.batches as f64)),
            ("expired", Json::num(stats.expired as f64)),
            ("wall_s", Json::num(wall)),
        ]));
    }

    // Close the loop: the bench's own measurements become the serve
    // entry the next `--micro-batch auto` plans from.
    if let (Some(cat), Some(path)) = (catalog.as_mut(), cfg.catalog.as_deref()) {
        if measured.step_ns.count() > 0 {
            cat.observe(
                CatalogKey {
                    family: probe.family().to_string(),
                    method: probe.method().to_string(),
                    backend: SERVE_BACKEND.to_string(),
                    shards: 0,
                    batch: micro_batch,
                },
                &measured,
            );
            cat.save(path)?;
            println!("serve: catalog recalibrated -> {}", path.display());
        }
    }

    Ok(Json::obj(vec![
        ("schema", Json::str("bench_serve/v1")),
        ("source", Json::str(&cfg.source)),
        ("family", Json::str(probe.family())),
        ("method", Json::str(probe.method())),
        (
            "backend",
            Json::str(match probe.backend() {
                BackendKind::Reference => "reference",
                BackendKind::Pjrt => "pjrt",
            }),
        ),
        ("micro_batch", Json::num(micro_batch as f64)),
        ("micro_batch_source", Json::str(mb_source)),
        (
            "predicted_sps",
            predicted_sps.map(Json::num).unwrap_or(Json::Null),
        ),
        ("workers", Json::num(cfg.workers as f64)),
        (
            "max_delay_ms",
            Json::num(cfg.max_delay.as_secs_f64() * 1e3),
        ),
        (
            "samples_per_request",
            Json::num(req_size as f64),
        ),
        ("levels", Json::Arr(rows)),
    ]))
}
