//! Experiment harness: one entry per table/figure of the paper's
//! evaluation (Sec. 4).  Each experiment builds the workload, runs every
//! method it compares, prints the paper's rows side-by-side with the
//! measured values, and writes a JSON record under `results/`.
//!
//! | id     | paper artifact                                   |
//! |--------|--------------------------------------------------|
//! | fig3a  | SMD vs SMB across energy ratios                  |
//! | fig3b  | SMD vs SMB + increased learning rates            |
//! | tab1   | SMD on other datasets/backbones                  |
//! | fig4   | SLU vs SD (vs SLU+SMD) accuracy-vs-energy        |
//! | tab2   | SGD-32b / 8-bit / SignSGD / PSG                  |
//! | tab3   | E2-Train at 20/40/60% skipping, beta sweep       |
//! | fig5   | convergence curves (accuracy vs energy)          |
//! | tab4   | ResNet-110-class + MobileNetV2, C10/C100         |
//! | finetune | Sec. 4.5 adaptation experiment                 |
//!
//! Absolute accuracies differ from the paper (synthetic data, scaled
//! models, CPU budget — DESIGN.md §Substitutions); the comparisons the
//! paper makes (who wins, and by roughly what energy factor) are the
//! reproduction target.  EXPERIMENTS.md records paper-vs-measured.

mod runs;
pub mod serve_bench;
pub mod shard_bench;

pub use runs::{ExpCtx, RunRecord, RunSpec};
pub use serve_bench::{resolve_bench_family, run_serve_bench, ServeBenchCfg};
pub use shard_bench::{run_shard_bench, ShardBenchCfg};

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::energy::EnergyModel;
use crate::runtime::{Engine, Manifest};
use crate::util::Json;

/// Shorthand for a JSON object row.
fn row(pairs: Vec<(&str, Json)>) -> Json {
    Json::obj(pairs)
}

/// Dispatch an experiment by id.
pub fn run_experiment(id: &str, iters: u64, artifacts: &Path, out: &Path) -> Result<()> {
    std::fs::create_dir_all(out)?;
    let engine = Engine::cpu()?;
    let ctx = ExpCtx::new(&engine, artifacts, out, iters);
    match id {
        "fig3a" => fig3a(&ctx),
        "fig3b" => fig3b(&ctx),
        "tab1" => tab1(&ctx),
        "fig4" => fig4(&ctx),
        "tab2" => tab2(&ctx),
        "tab3" => tab3(&ctx),
        "fig5" => fig5(&ctx),
        "tab4" => tab4(&ctx),
        "finetune" => finetune(&ctx),
        "all" => {
            for e in [
                "fig3a", "fig3b", "tab1", "fig4", "tab2", "tab3", "fig5", "tab4",
                "finetune",
            ] {
                println!("\n================ {e} ================");
                run_experiment(e, iters, artifacts, out)?;
            }
            Ok(())
        }
        other => Err(anyhow!("unknown experiment id {other}")),
    }
}

/// Small default family: every coordinator feature in CI-scale time.
const FAM: &str = "resnet8-c10-tiny";
/// The ablation family standing in for ResNet-74 (same 6n+2 structure).
const FAM_MID: &str = "resnet20-c10";
const FAM_C100: &str = "resnet20-c100";
const FAM_MBV2: &str = "mbv2-c10-tiny";

// ==========================================================================
// Fig. 3a — SMD vs SMB across training-energy ratios
// ==========================================================================

fn fig3a(ctx: &ExpCtx) -> Result<()> {
    println!("Fig 3a: SMD vs SMB, ResNet-74-class ablation ({FAM})");
    println!("paper: SMD beats SMB by 0.39%..0.86% at every matched energy ratio\n");
    let t = ctx.iters;
    let ratios = [0.5, 7.0 / 12.0, 2.0 / 3.0, 0.75, 5.0 / 6.0, 11.0 / 12.0, 1.0];
    // All 15 runs are independent: anchor + one (SMB, SMD) pair per
    // ratio, fanned out across threads.
    let mut specs = vec![RunSpec::new(FAM, "sgd32", t, |_| {})]; // SMB @ ratio 1 anchor
    for &r in &ratios {
        // SMB: fewer iterations, LR schedule scaled proportionally.
        let smb_iters = (t as f64 * r) as u64;
        specs.push(RunSpec::new(FAM, "sgd32", smb_iters, |_| {}));
        // SMD: same *expected executed steps* via drop prob 1-r over T.
        specs.push(RunSpec::new(FAM, "sgd32", t, move |c| {
            c.smd.enabled = true;
            c.smd.p = 1.0 - r;
        }));
    }
    let recs = ctx.run_many(specs)?;
    let base = &recs[0];
    let mut rows = Vec::new();
    for (i, &r) in ratios.iter().enumerate() {
        let smb = &recs[1 + 2 * i];
        let smd = &recs[2 + 2 * i];
        println!(
            "ratio {:>5.3}  SMB acc {:>6.2}%  (J {:>8.2})   SMD acc {:>6.2}%  (J {:>8.2})  Δ {:+.2}%",
            r,
            smb.acc * 100.0,
            smb.joules,
            smd.acc * 100.0,
            smd.joules,
            (smd.acc - smb.acc) * 100.0
        );
        rows.push(row(vec![
            ("ratio", Json::num(r)),
            ("smb_acc", Json::num(smb.acc)),
            ("smd_acc", Json::num(smd.acc)),
            ("smb_joules", Json::num(smb.joules)),
            ("smd_joules", Json::num(smd.joules)),
        ]));
    }
    println!(
        "\nanchor SMB@1.0: acc {:.2}% J {:.2}",
        base.acc * 100.0,
        base.joules
    );
    ctx.save_json("fig3a", &row(vec![("rows", Json::Arr(rows))]))
}

// ==========================================================================
// Fig. 3b — SMD vs SMB with increased learning rates, equal energy budget
// ==========================================================================

fn fig3b(ctx: &ExpCtx) -> Result<()> {
    println!("Fig 3b: SMD vs SMB + tuned LR at equal (2/3) energy budget");
    println!("paper: SMD keeps >= 0.22% advantage over the best SMB LR\n");
    let t = ctx.iters;
    let smb_iters = t * 2 / 3;
    // The LR grid and the SMD run are mutually independent: fan out.
    let lr0s: Vec<f64> = (10..=20).step_by(2).map(|lr100| lr100 as f64 / 100.0).collect();
    let mut specs: Vec<RunSpec> = lr0s
        .iter()
        .map(|&lr0| {
            RunSpec::new(FAM, "sgd32", smb_iters, move |c| {
                c.lr = crate::optim::LrSchedule::paper_default(lr0, smb_iters);
            })
        })
        .collect();
    specs.push(RunSpec::new(FAM, "sgd32", t, |c| {
        c.smd.enabled = true;
        c.smd.p = 1.0 / 3.0;
    }));
    let recs = ctx.run_many(specs)?;
    let mut rows = Vec::new();
    let mut best_smb = (0.0f64, 0.0f64);
    for (&lr0, r) in lr0s.iter().zip(recs.iter()) {
        println!("SMB lr0={lr0:.2}: acc {:>6.2}%  (J {:.2})", r.acc * 100.0, r.joules);
        if r.acc > best_smb.1 {
            best_smb = (lr0, r.acc);
        }
        rows.push(row(vec![
            ("method", Json::str("smb")),
            ("lr0", Json::num(lr0)),
            ("acc", Json::num(r.acc)),
        ]));
    }
    let smd = recs.last().unwrap();
    println!(
        "SMD p=1/3:  acc {:>6.2}%  (J {:.2})   best SMB (lr0={:.2}) {:.2}%  Δ {:+.2}%",
        smd.acc * 100.0,
        smd.joules,
        best_smb.0,
        best_smb.1 * 100.0,
        (smd.acc - best_smb.1) * 100.0
    );
    rows.push(row(vec![
        ("method", Json::str("smd")),
        ("acc", Json::num(smd.acc)),
    ]));
    ctx.save_json("fig3b", &row(vec![("rows", Json::Arr(rows))]))
}

// ==========================================================================
// Table 1 — SMD on other datasets and backbones (energy ratio 0.67)
// ==========================================================================

fn tab1(ctx: &ExpCtx) -> Result<()> {
    println!("Table 1: SMD vs SMB at energy ratio 0.67");
    println!("paper: C10/ResNet-110 92.75->93.05, C100/ResNet-74 71.11->71.37\n");
    let workloads =
        [(FAM_MID, "CIFAR10-syn/resnet20"), (FAM_C100, "CIFAR100-syn/resnet20")];
    // One (SMB, SMD) pair per workload, all independent: fan out.
    let mut specs = Vec::new();
    for (fam, _) in workloads {
        specs.push(RunSpec::new(fam, "sgd32", ctx.iters * 2 / 3, |_| {}));
        specs.push(RunSpec::new(fam, "sgd32", ctx.iters, |c| {
            c.smd.enabled = true;
            c.smd.p = 1.0 / 3.0;
        }));
    }
    let recs = ctx.run_many(specs)?;
    let mut rows = Vec::new();
    for (i, (_, label)) in workloads.iter().enumerate() {
        let smb = &recs[2 * i];
        let smd = &recs[2 * i + 1];
        println!(
            "{label:<24} SMB {:>6.2}%   SMD {:>6.2}%   Δ {:+.2}%",
            smb.acc * 100.0,
            smd.acc * 100.0,
            (smd.acc - smb.acc) * 100.0
        );
        rows.push(row(vec![
            ("workload", Json::str(*label)),
            ("smb_acc", Json::num(smb.acc)),
            ("smd_acc", Json::num(smd.acc)),
        ]));
    }
    ctx.save_json("tab1", &row(vec![("rows", Json::Arr(rows))]))
}

// ==========================================================================
// Fig. 4 — SLU vs SD (and SLU+SMD) accuracy vs energy ratio
// ==========================================================================

// Stays serial: each SD run is calibrated to the gate activity its SLU
// counterpart *measured*, so the pairs have a data dependency.
fn fig4(ctx: &ExpCtx) -> Result<()> {
    println!("Fig 4: SLU vs SD vs SLU+SMD, accuracy vs energy ratio");
    println!("paper: SLU above SD at every matched energy; SLU+SMD pushes further\n");
    let t = ctx.iters;
    let base = ctx.run(FAM, "sgd32", t, |_| {})?;
    let num_gated = Manifest::load(
        &ctx.base_cfg(FAM, "slu", t).manifest_path(),
    )?
    .num_gated() as f64;
    let mut rows = Vec::new();
    for alpha in [0.3, 1.0, 3.0, 10.0] {
        let slu = ctx.run(FAM, "slu", t, |c| c.alpha = alpha)?;
        let skip = 1.0 - slu.mean_gate;
        // SD calibrated to the same drop ratio (the paper's fairness
        // rule): solve the linear-decay mean-survival formula for p_l.
        let sd = ctx.run(FAM, "sd", t, |c| {
            let m = slu.mean_gate;
            c.sd.p_l =
                (1.0 - (1.0 - m) * 2.0 * num_gated / (num_gated + 1.0)).clamp(0.0, 1.0);
        })?;
        let slu_smd = ctx.run(FAM, "slu", t, |c| {
            c.alpha = alpha;
            c.smd.enabled = true;
            c.smd.p = 0.5;
        })?;
        println!(
            "alpha {:>4.1} skip {:>4.1}%  SLU {:>6.2}% (E/E0 {:.2})  SD {:>6.2}% (E/E0 {:.2})  SLU+SMD {:>6.2}% (E/E0 {:.2})",
            alpha,
            skip * 100.0,
            slu.acc * 100.0,
            slu.joules / base.joules,
            sd.acc * 100.0,
            sd.joules / base.joules,
            slu_smd.acc * 100.0,
            slu_smd.joules / base.joules,
        );
        let pair = |r: &RunRecord| {
            row(vec![
                ("acc", Json::num(r.acc)),
                ("ratio", Json::num(r.joules / base.joules)),
            ])
        };
        rows.push(row(vec![
            ("alpha", Json::num(alpha)),
            ("skip", Json::num(skip)),
            ("slu", pair(&slu)),
            ("sd", pair(&sd)),
            ("slu_smd", pair(&slu_smd)),
        ]));
    }
    ctx.save_json(
        "fig4",
        &row(vec![
            ("baseline_acc", Json::num(base.acc)),
            ("rows", Json::Arr(rows)),
        ]),
    )
}

// ==========================================================================
// Table 2 — SGD-32 / 8-bit fixed / SignSGD / PSG
// ==========================================================================

fn tab2(ctx: &ExpCtx) -> Result<()> {
    println!("Table 2: precision ablation ({FAM})");
    println!("paper: 32b 93.52 | 8bit 93.24 (38.6% save) | SignSGD 92.54 | PSG 92.59 (63.3% save)\n");
    let t = ctx.iters;
    let methods = ["fixed8", "signsgd", "psg"];
    let mut specs = vec![RunSpec::new(FAM, "sgd32", t, |_| {})];
    specs.extend(methods.iter().map(|m| RunSpec::new(FAM, m, t, |_| {})));
    let recs = ctx.run_many(specs)?;
    let base = &recs[0];
    let mut rows = vec![row(vec![
        ("method", Json::str("sgd32")),
        ("acc", Json::num(base.acc)),
        ("saving", Json::num(0.0)),
    ])];
    for (m, r) in methods.iter().zip(recs[1..].iter()) {
        let saving = 1.0 - r.joules / base.joules;
        println!(
            "{m:<8} acc {:>6.2}%  energy saving {:>6.2}%  (psg predictor usage {})",
            r.acc * 100.0,
            saving * 100.0,
            r.psg_frac
                .map(|p| format!("{:.0}%", p * 100.0))
                .unwrap_or_else(|| "-".into())
        );
        rows.push(row(vec![
            ("method", Json::str(*m)),
            ("acc", Json::num(r.acc)),
            ("saving", Json::num(saving)),
        ]));
    }
    println!("sgd32    acc {:>6.2}%  energy saving   0.00%", base.acc * 100.0);
    ctx.save_json("tab2", &row(vec![("rows", Json::Arr(rows))]))
}

// ==========================================================================
// Table 3 — the full E2-Train at different skipping ratios / thresholds
// ==========================================================================

fn tab3(ctx: &ExpCtx) -> Result<()> {
    println!("Table 3: E2-Train (SMD+SLU+PSG) skipping/threshold sweep ({FAM})");
    println!("paper: skip 20/40/60% -> energy savings 84.6/88.7/92.8%, acc 92.1/91.8/91.4 (b=.05)\n");
    let t = ctx.iters;
    // Baseline + the 6 sweep points all fan out together.
    let combos: Vec<(f64, f64)> = [0.05, 0.1]
        .iter()
        .flat_map(|&beta| [0.5, 2.0, 8.0].iter().map(move |&alpha| (beta, alpha)))
        .collect();
    let mut specs = vec![RunSpec::new(FAM, "sgd32", t, |_| {})];
    specs.extend(combos.iter().map(|&(beta, alpha)| {
        RunSpec::new(FAM, "e2train", t, move |c| {
            c.alpha = alpha;
            c.beta = beta;
            c.smd.enabled = true;
        })
    }));
    let recs = ctx.run_many(specs)?;
    let base = &recs[0];
    let mut rows = Vec::new();
    {
        for (&(beta, alpha), r) in combos.iter().zip(recs[1..].iter()) {
            let skip = 1.0 - r.mean_gate;
            let esave = 1.0 - r.joules / base.joules;
            let csave = 1.0 - r.macs / base.macs;
            println!(
                "beta {beta:.2} alpha {alpha:>4.1}: skip {:>5.1}%  acc {:>6.2}%  comp-save {:>5.1}%  energy-save {:>5.1}%",
                skip * 100.0,
                r.acc * 100.0,
                csave * 100.0,
                esave * 100.0
            );
            rows.push(row(vec![
                ("beta", Json::num(beta)),
                ("alpha", Json::num(alpha)),
                ("skip", Json::num(skip)),
                ("acc", Json::num(r.acc)),
                ("comp_saving", Json::num(csave)),
                ("energy_saving", Json::num(esave)),
            ]));
        }
    }
    ctx.save_json("tab3", &row(vec![("rows", Json::Arr(rows))]))
}

// ==========================================================================
// Fig. 5 — convergence curves: accuracy vs cumulative energy
// ==========================================================================

fn fig5(ctx: &ExpCtx) -> Result<()> {
    println!("Fig 5: convergence (test acc vs energy), 5 methods ({FAM})");
    println!("paper: E2-Train converges at least as fast per joule\n");
    let t = ctx.iters;
    let eval_every = (t / 8).max(1);
    let variants = [
        ("SMB", "sgd32", false),
        ("SD", "sd", false),
        ("SLU", "slu", false),
        ("SLU+SMD", "slu", true),
        ("E2-Train", "e2train", true),
    ];
    // Five independent curves, one thread each.
    let specs = variants
        .iter()
        .map(|&(_, method, smd)| {
            RunSpec::new(FAM, method, t, move |c| {
                c.smd.enabled = smd;
                c.eval_every = eval_every;
            })
        })
        .collect();
    let recs = ctx.run_many(specs)?;
    let mut curves = Vec::new();
    for (&(label, _, _), r) in variants.iter().zip(recs.iter()) {
        let pts: Vec<(f64, f64)> = r
            .curve
            .iter()
            .filter_map(|p| p.1.map(|acc| (p.0, acc)))
            .collect();
        print!("{label:<9}");
        for (j, acc) in &pts {
            print!("  {j:.1}J:{:.1}%", acc * 100.0);
        }
        println!("  | final {:.2}%", r.acc * 100.0);
        curves.push(row(vec![
            ("label", Json::str(label)),
            (
                "points",
                Json::arr(pts.iter().map(|&(j, a)| {
                    Json::arr(vec![Json::num(j), Json::num(a)])
                })),
            ),
            ("final_acc", Json::num(r.acc)),
        ]));
    }
    ctx.save_json("fig5", &row(vec![("curves", Json::Arr(curves))]))
}

// ==========================================================================
// Table 4 — other backbones/datasets
// ==========================================================================

fn tab4(ctx: &ExpCtx) -> Result<()> {
    println!("Table 4: ResNet-110-class + MobileNetV2 on C10/C100 (scaled)");
    println!("paper: e.g. C10/ResNet-110 E2-Train saves 83.4% with -0.56% acc\n");
    let t = ctx.iters;
    let workloads = [
        (FAM_MID, "C10-syn resnet20"),
        (FAM_C100, "C100-syn resnet20"),
        (FAM_MBV2, "C10-syn mbv2"),
    ];
    let alphas = [1.0, 4.0];
    // 4 runs per workload (base, SD, E2T at two alphas), all independent.
    let mut specs = Vec::new();
    for (fam, _) in workloads {
        specs.push(RunSpec::new(fam, "sgd32", t, |_| {}));
        specs.push(RunSpec::new(fam, "sd", t, |c| c.sd.p_l = 0.5));
        for &alpha in &alphas {
            specs.push(RunSpec::new(fam, "e2train", t, move |c| {
                c.alpha = alpha;
                c.smd.enabled = true;
            }));
        }
    }
    let recs = ctx.run_many(specs)?;
    let per_fam = 2 + alphas.len();
    let mut rows = Vec::new();
    for (wi, (_, label)) in workloads.iter().enumerate() {
        let base = &recs[wi * per_fam];
        let sd = &recs[wi * per_fam + 1];
        println!(
            "{label:<18} SMB acc {:>6.2}%/{:>6.2}%  (J {:>8.2})",
            base.acc * 100.0,
            base.acc5 * 100.0,
            base.joules
        );
        println!(
            "{label:<18} SD  acc {:>6.2}%          save {:>5.1}%",
            sd.acc * 100.0,
            (1.0 - sd.joules / base.joules) * 100.0
        );
        rows.push(row(vec![
            ("workload", Json::str(*label)),
            ("method", Json::str("smb")),
            ("acc", Json::num(base.acc)),
            ("acc5", Json::num(base.acc5)),
        ]));
        rows.push(row(vec![
            ("workload", Json::str(*label)),
            ("method", Json::str("sd")),
            ("acc", Json::num(sd.acc)),
            ("energy_saving", Json::num(1.0 - sd.joules / base.joules)),
        ]));
        for (ai, &alpha) in alphas.iter().enumerate() {
            let r = &recs[wi * per_fam + 2 + ai];
            let esave = 1.0 - r.joules / base.joules;
            let csave = 1.0 - r.macs / base.macs;
            println!(
                "{label:<18} E2T(a={alpha:.0}) acc {:>6.2}%/{:>6.2}%  comp-save {:>5.1}%  energy-save {:>5.1}%",
                r.acc * 100.0,
                r.acc5 * 100.0,
                csave * 100.0,
                esave * 100.0
            );
            rows.push(row(vec![
                ("workload", Json::str(*label)),
                ("method", Json::str(format!("e2train-a{alpha}"))),
                ("acc", Json::num(r.acc)),
                ("acc5", Json::num(r.acc5)),
                ("comp_saving", Json::num(csave)),
                ("energy_saving", Json::num(esave)),
            ]));
        }
    }
    ctx.save_json("tab4", &row(vec![("rows", Json::Arr(rows))]))
}

// ==========================================================================
// Sec. 4.5 — adapting a pre-trained model
// ==========================================================================

fn finetune(ctx: &ExpCtx) -> Result<()> {
    println!("Sec 4.5: fine-tune on held-out half — head-only FT vs E2-Train FT");
    println!("paper: +0.30% (FC only) vs +1.37% (E2-Train), E2-Train 61.6% cheaper\n");
    let rec = ctx.finetune(FAM, ctx.iters)?;
    let f = |k: &str| rec.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    println!(
        "pretrained acc {:.2}% | headFT {:+.2}% (J {:.2}) | e2trainFT {:+.2}% (J {:.2}) | extra saving {:.1}%",
        f("pretrain_acc") * 100.0,
        f("headft_delta") * 100.0,
        f("headft_joules"),
        f("e2t_delta") * 100.0,
        f("e2t_joules"),
        f("saving_vs_headft") * 100.0,
    );
    ctx.save_json("finetune", &rec)
}

// ==========================================================================
// Energy report (calibration vs paper anchors)
// ==========================================================================

/// Analytic per-step energy for each method at full gate activity —
/// calibration against the paper's anchor savings without training.
pub fn energy_report(family: &str, artifacts: &Path) -> Result<()> {
    let dir = artifacts.join(family);
    let base_m = Manifest::load(&dir.join("sgd32.json"))?;
    let base_e = EnergyModel::from_manifest(&base_m);
    let e0 = base_e.train_step(&base_m.method, &[], None).total();
    println!("energy model calibration, family {family}");
    println!("paper anchors: fixed8 ~38.6% | psg ~63.3% | e2train(skip60)+smd ~92.8%\n");
    println!("{:<10} {:>12} {:>9}", "method", "J/step", "saving");
    for m in ["sgd32", "fixed8", "signsgd", "psg", "slu", "e2train"] {
        let path = dir.join(format!("{m}.json"));
        if !path.exists() {
            continue;
        }
        let man = Manifest::load(&path)?;
        let em = EnergyModel::from_manifest(&man);
        let e = em.train_step(&man.method, &[], Some(0.6)).total();
        println!(
            "{m:<10} {:>12.4} {:>8.1}%",
            e * 1e-12,
            (1.0 - e / e0) * 100.0
        );
    }
    // E2-Train with SLU skipping 20/40/60% + SMD halving the steps.
    let man = Manifest::load(&dir.join("e2train.json"))?;
    let em = EnergyModel::from_manifest(&man);
    let ng = man.num_gated();
    for skip in [0.2, 0.4, 0.6] {
        let fracs = vec![1.0 - skip; ng];
        let e = em.train_step(&man.method, &fracs, Some(0.6)).total();
        // SMD p=0.5: half the steps run at this cost, the rest are free.
        let saving = 1.0 - 0.5 * e / e0;
        println!(
            "e2train skip {:>2.0}% + SMD: per-run saving {:>5.1}%",
            skip * 100.0,
            saving * 100.0
        );
    }
    Ok(())
}
