//! Shared run helpers for the experiment harness, including the
//! parallel fan-out: independent method runs within one experiment
//! execute on worker threads, **one engine per worker**
//! ([`crate::runtime::EnginePool`]) sharing the base engine's
//! compiled-program cache, so each artifact compiles once no matter how
//! many runs use it.  Per-worker engines remove the old `Engine: Sync`
//! assumption that the real PJRT CPU client (raw client pointers) does
//! not satisfy — the same pool structure backs the serve worker pool.
//!
//! Determinism: every run's config carries its own seed (set before the
//! tweak closure runs), and all stochastic components derive from that
//! seed alone — `run_many` returns records in spec order and produces
//! bitwise the same results as running the specs serially.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::config::{DataCfg, RunCfg};
use crate::coordinator::Trainer;
use crate::runtime::{Engine, EnginePool};
use crate::util::Json;

/// Condensed outcome of one training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub acc: f64,
    pub acc5: f64,
    pub joules: f64,
    pub macs: f64,
    /// Mean gate activity across gated blocks (1.0 when ungated).
    pub mean_gate: f64,
    pub psg_frac: Option<f64>,
    pub steps_run: u64,
    pub steps_skipped: u64,
    pub wall_seconds: f64,
    /// (cumulative joules, Some(test acc)) trace for curve experiments.
    pub curve: Vec<(f64, Option<f64>)>,
}

/// One planned run for [`ExpCtx::run_many`]: (family, method, budget) +
/// a config tweak applied before launch.
pub struct RunSpec {
    pub family: String,
    pub method: String,
    pub iters: u64,
    tweak: Box<dyn Fn(&mut RunCfg) + Send + Sync>,
}

impl RunSpec {
    pub fn new(
        family: &str,
        method: &str,
        iters: u64,
        tweak: impl Fn(&mut RunCfg) + Send + Sync + 'static,
    ) -> Self {
        Self {
            family: family.to_string(),
            method: method.to_string(),
            iters,
            tweak: Box::new(tweak),
        }
    }
}

/// Experiment context: engine + paths + the iteration budget.
pub struct ExpCtx<'e> {
    engine: &'e Engine,
    artifacts: PathBuf,
    out: PathBuf,
    pub iters: u64,
    /// Synthetic dataset sizing (kept modest for the 1-core testbed).
    pub n_train: usize,
    pub n_test: usize,
    pub seed: u64,
}

/// The plain-data slice of an [`ExpCtx`] a fan-out worker needs.
/// Workers receive this + an **owned** engine instead of `&ExpCtx`
/// (which holds `&Engine`), so the fan-out requires only
/// `Engine: Send`, never `Engine: Sync` — the property the real PJRT
/// CPU client lacks.
#[derive(Clone)]
struct RunParams {
    artifacts: PathBuf,
    n_train: usize,
    n_test: usize,
    seed: u64,
}

fn base_cfg_from(p: &RunParams, family: &str, method: &str, iters: u64) -> RunCfg {
    let mut cfg = RunCfg::quick(family, method, iters);
    cfg.artifacts_dir = p.artifacts.clone();
    cfg.seed = p.seed;
    cfg.smd.enabled = false; // experiments opt in explicitly
    cfg
}

/// Finalize a tweaked config (the dataset's class count is read from
/// the manifest) and execute it on `engine`.
fn exec_cfg(p: &RunParams, mut cfg: RunCfg, engine: &Engine) -> Result<RunRecord> {
    let manifest = crate::runtime::Manifest::load(&cfg.manifest_path())?;
    cfg.data = DataCfg::Synthetic {
        classes: manifest.arch.num_classes,
        n_train: p.n_train,
        n_test: p.n_test,
        seed: p.seed,
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    let outcome = trainer.run(None)?;
    let m = outcome.metrics;
    let mean_gate = if m.mean_gate_fracs.is_empty() {
        1.0
    } else {
        m.mean_gate_fracs.iter().sum::<f64>() / m.mean_gate_fracs.len() as f64
    };
    Ok(RunRecord {
        acc: m.final_test_acc,
        acc5: m.final_test_acc_top5,
        joules: m.total_joules,
        macs: m.executed_macs,
        mean_gate,
        psg_frac: m.mean_psg_frac,
        steps_run: m.steps_run,
        steps_skipped: m.steps_skipped,
        wall_seconds: m.wall_seconds,
        curve: m.trace.iter().map(|p| (p.joules, p.test_acc)).collect(),
    })
}

fn exec_spec(p: &RunParams, spec: &RunSpec, engine: &Engine) -> Result<RunRecord> {
    let mut cfg = base_cfg_from(p, &spec.family, &spec.method, spec.iters);
    (spec.tweak)(&mut cfg);
    exec_cfg(p, cfg, engine)
}

impl<'e> ExpCtx<'e> {
    pub fn new(engine: &'e Engine, artifacts: &Path, out: &Path, iters: u64) -> Self {
        Self {
            engine,
            artifacts: artifacts.to_path_buf(),
            out: out.to_path_buf(),
            iters,
            n_train: 2048,
            n_test: 512,
            seed: 0,
        }
    }

    fn params(&self) -> RunParams {
        RunParams {
            artifacts: self.artifacts.clone(),
            n_train: self.n_train,
            n_test: self.n_test,
            seed: self.seed,
        }
    }

    pub fn base_cfg(&self, family: &str, method: &str, iters: u64) -> RunCfg {
        base_cfg_from(&self.params(), family, method, iters)
    }

    /// Run (family, method) for `iters`, after applying `tweak` to the
    /// config.
    pub fn run(
        &self,
        family: &str,
        method: &str,
        iters: u64,
        tweak: impl FnOnce(&mut RunCfg),
    ) -> Result<RunRecord> {
        let mut cfg = self.base_cfg(family, method, iters);
        tweak(&mut cfg);
        exec_cfg(&self.params(), cfg, self.engine)
    }

    /// Execute independent runs in parallel across worker threads,
    /// bounded by the machine's parallelism, each worker on its own
    /// engine forked from this context's (sharing its compile cache, so
    /// every artifact still compiles once).  A shared work queue (no
    /// inter-batch barrier) keeps every core busy until the queue
    /// drains, even when iteration budgets differ wildly (fig3a spans
    /// 0.5T..T).  Results come back in spec order and match a serial
    /// execution exactly.
    pub fn run_many(&self, specs: Vec<RunSpec>) -> Result<Vec<RunRecord>> {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let params = self.params();
        if specs.len() <= 1 {
            return specs
                .iter()
                .map(|s| exec_spec(&params, s, self.engine))
                .collect();
        }
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(specs.len());
        // Reference programs are backend-portable, so workers share the
        // base engine's compiled-program cache (racing cold compiles
        // are deduped by the engine's compile lock).  Compiled HLO is
        // bound to the client that compiled it under real PJRT — give
        // each worker an isolated engine there.  Resolved from the
        // first spec's artifact paths without compiling anything
        // (experiments don't mix backends within one fan-out).
        let probe_cfg = self.base_cfg(&specs[0].family, &specs[0].method, 1);
        let pool = match crate::runtime::Manifest::resolved_backend(
            &probe_cfg.manifest_path(),
        ) {
            crate::runtime::BackendKind::Reference => {
                EnginePool::from_base(self.engine, workers)?
            }
            crate::runtime::BackendKind::Pjrt => EnginePool::new_isolated(workers)?,
        };
        let next = AtomicUsize::new(0);
        let slots: Vec<std::sync::Mutex<Option<Result<RunRecord>>>> =
            specs.iter().map(|_| std::sync::Mutex::new(None)).collect();
        // Workers get an **owned** engine and the plain-data params —
        // nothing crossing the thread boundary needs `Engine: Sync`.
        std::thread::scope(|scope| {
            let next = &next;
            let slots = &slots;
            let specs = &specs;
            let params = &params;
            for engine in pool.into_engines() {
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || exec_spec(params, &specs[i], &engine),
                    ))
                    .unwrap_or_else(|_| Err(anyhow!("experiment worker panicked")));
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap()
                    .unwrap_or_else(|| Err(anyhow!("experiment run never executed")))
            })
            .collect()
    }

    /// The Sec. 4.5 protocol: pre-train on half the data, then fine-tune
    /// the other half two ways (head-only standard vs. full E2-Train).
    /// Inherently sequential — each stage consumes the previous state.
    pub fn finetune(&self, family: &str, iters: u64) -> Result<Json> {
        let cfg = self.base_cfg(family, "sgd32", iters);
        let manifest = crate::runtime::Manifest::load(&cfg.manifest_path())?;
        let classes = manifest.arch.num_classes;
        let hw = manifest.arch.image_size;
        let (full, test) = crate::data::synthetic::generate_split(
            classes, self.n_train, self.n_test, hw, self.seed,
        );
        let (half_a, half_b) = full.split(0.5);

        // Pre-train on half A.
        let mut pre_cfg = self.base_cfg(family, "sgd32", iters);
        pre_cfg.data = DataCfg::Synthetic {
            classes,
            n_train: 1,
            n_test: 1,
            seed: 0,
        };
        let mut pre = Trainer::new(self.engine, pre_cfg)?;
        pre.set_data(half_a.clone(), test.clone());
        let pre_out = pre.run(None)?;
        let pre_acc = pre_out.metrics.final_test_acc;

        // Option 1: fine-tune only the FC head (standard training).
        let ft_iters = iters / 2;
        let mut head_cfg = self.base_cfg(family, "headft", ft_iters);
        head_cfg.data = pre.cfg.data.clone();
        let mut head = Trainer::new(self.engine, head_cfg)?;
        head.set_data(half_b.clone(), test.clone());
        let head_out = head.run(Some(pre_out.state.clone()))?;

        // Option 2: fine-tune all layers with E2-Train.
        let mut e2_cfg = self.base_cfg(family, "e2train", ft_iters);
        e2_cfg.smd.enabled = true;
        e2_cfg.data = pre.cfg.data.clone();
        let mut e2 = Trainer::new(self.engine, e2_cfg)?;
        e2.set_data(half_b, test);
        let e2_out = e2.run(Some(pre_out.state))?;

        let hj = head_out.metrics.total_joules;
        let ej = e2_out.metrics.total_joules;
        Ok(Json::obj(vec![
            ("pretrain_acc", Json::num(pre_acc)),
            ("headft_acc", Json::num(head_out.metrics.final_test_acc)),
            (
                "headft_delta",
                Json::num(head_out.metrics.final_test_acc - pre_acc),
            ),
            ("headft_joules", Json::num(hj)),
            ("e2t_acc", Json::num(e2_out.metrics.final_test_acc)),
            ("e2t_delta", Json::num(e2_out.metrics.final_test_acc - pre_acc)),
            ("e2t_joules", Json::num(ej)),
            ("saving_vs_headft", Json::num(1.0 - ej / hj)),
        ]))
    }

    pub fn save_json(&self, name: &str, v: &Json) -> Result<()> {
        let path = self.out.join(format!("{name}.json"));
        std::fs::write(&path, v.to_string())?;
        println!("\nresults -> {}", path.display());
        Ok(())
    }
}
