//! The `shard` bench scenario: time raw training steps through the
//! data-parallel sharded path at several shard counts and record
//! steps/sec plus strong-scaling efficiency into `BENCH_shard.json`
//! (schema `bench_shard/v1`, see PERF.md).
//!
//! The sweep holds the total batch fixed (strong scaling): each row runs
//! the identical step on the identical fixed batch, splitting it across
//! more engines.  `single_device_sps` records the plain resident
//! `step_device` loop as the non-sharded baseline — the sharded path
//! pays for its determinism contract (per-sample gradient emission +
//! fixed-shape host reduction), and the whole point of the pipelined
//! reducer is to hide that tax behind shard compute.  The sweep
//! therefore runs every shard count twice — reducer overlap off
//! (inline fold, the pre-pipeline cost) and on (the default) — and each
//! row records `reduce_ms`, the measured per-step host-reduce wall, so
//! the report shows both how big the tax is and how much of it the
//! overlap recovers.  Efficiency is relative to each overlap group's
//! own first row (overlap changes the cost model, so cross-group
//! efficiency would compare different machines).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::data::{synthetic, AugmentCfg, Sampler};
use crate::obs::{self, Obs};
use crate::runtime::{
    BackendKind, Engine, ModelState, ShardedTrainer, StepHyper, TrainProgram,
};
use crate::util::Json;

/// Bench workload shape.
#[derive(Debug, Clone)]
pub struct ShardBenchCfg {
    /// Shard counts to sweep (1 first, so efficiency is relative to the
    /// one-shard sharded path).
    pub shard_counts: Vec<usize>,
    pub warmup_steps: usize,
    /// Timed steps per shard count.
    pub steps: usize,
    /// Micro-batches per step (gradient accumulation; bitwise inert, so
    /// the bench defaults to 2 to exercise the pipelined path).
    pub accum: usize,
    pub seed: u64,
    /// Provenance string recorded in the report (producer + profile).
    pub source: String,
}

impl Default for ShardBenchCfg {
    fn default() -> Self {
        Self {
            shard_counts: vec![1, 2, 4],
            warmup_steps: 3,
            steps: 40,
            accum: 2,
            seed: 0,
            source: "shard_bench".into(),
        }
    }
}

/// Run the sweep and return the `bench_shard/v1` report.
pub fn run_shard_bench(
    engine: &Engine,
    manifest_path: &Path,
    cfg: &ShardBenchCfg,
) -> Result<Json> {
    let prog = TrainProgram::load(engine, manifest_path)?;
    let classes = prog.manifest.arch.num_classes;
    let hw = prog.manifest.arch.image_size;
    let data = synthetic::generate(classes, 256, hw, cfg.seed);
    let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), cfg.seed);
    let (x, y) = sampler.next_batch(&data);
    let hp = StepHyper::lr(0.05);
    let steps = cfg.steps.max(1);

    // Non-sharded baseline: the resident step loop every row competes
    // against.
    let mut dev = prog.upload_state(ModelState::init(&prog.manifest, cfg.seed))?;
    for _ in 0..cfg.warmup_steps {
        prog.step_device(&mut dev, &x, &y, hp, None)?;
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        prog.step_device(&mut dev, &x, &y, hp, None)?;
    }
    let single_sps = steps as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    println!("shard_bench: single-device baseline  {single_sps:>8.1} steps/s");

    let accum = cfg.accum.max(1);
    let mut rows = Vec::new();
    // Overlap-off first so the report reads "tax, then recovery".
    for overlap in [false, true] {
        let mut first: Option<(usize, f64)> = None;
        for &s in &cfg.shard_counts {
            let s = s.max(1);
            let mut st = ShardedTrainer::new(
                engine,
                manifest_path,
                s,
                ModelState::init(&prog.manifest, cfg.seed),
            )?;
            st.set_accum(accum);
            st.set_overlap(overlap);
            for _ in 0..cfg.warmup_steps {
                st.step(&x, &y, hp)?;
            }
            // Fresh hub after warmup: reduce_ms covers timed steps only.
            let row_obs = Obs::new(false);
            st.set_obs(row_obs.clone());
            let t0 = Instant::now();
            for _ in 0..steps {
                st.step(&x, &y, hp)?;
            }
            let sps = steps as f64 / t0.elapsed().as_secs_f64().max(1e-9);
            // Host-reduce wall per step (all micro-batch folds), whether
            // it ran inline (overlap off) or on the reducer thread.
            let reduce_ms = row_obs
                .phase_histogram(obs::PHASE_SHARD_REDUCE)
                .map(|h| h.total() as f64 / steps as f64 / 1e6)
                .unwrap_or(0.0);
            let (s0, sps0) = *first.get_or_insert((s, sps));
            let speedup = sps / sps0;
            // Strong-scaling efficiency vs this overlap group's first
            // row: speedup divided by the shard-count growth; 1.0 =
            // perfect linear scaling.
            let efficiency = speedup * s0 as f64 / s as f64;
            println!(
                "shard_bench: {s} shard(s) overlap={overlap:<5}  {sps:>8.1} steps/s  reduce {reduce_ms:>7.3} ms/step  speedup {speedup:.2}x  efficiency {efficiency:.2}"
            );
            rows.push(Json::obj(vec![
                ("shards", Json::num(s as f64)),
                // Execution backend per row, so trajectories stay
                // attributable after the `cfg.backend` knob.
                ("exec_backend", Json::str("sharded")),
                ("overlap", Json::Bool(overlap)),
                ("accum", Json::num(accum as f64)),
                ("steps_per_sec", Json::num(sps)),
                ("reduce_ms", Json::num(reduce_ms)),
                ("speedup_vs_first", Json::num(speedup)),
                ("efficiency", Json::num(efficiency)),
            ]));
        }
    }

    Ok(Json::obj(vec![
        ("schema", Json::str("bench_shard/v1")),
        ("source", Json::str(&cfg.source)),
        ("family", Json::str(prog.family())),
        ("method", Json::str(prog.method())),
        (
            "backend",
            Json::str(match prog.backend() {
                BackendKind::Reference => "reference",
                BackendKind::Pjrt => "pjrt",
            }),
        ),
        ("batch", Json::num(prog.batch() as f64)),
        ("steps_timed", Json::num(steps as f64)),
        ("single_device_sps", Json::num(single_sps)),
        // The baseline row's execution backend (the resident step loop).
        ("single_device_backend", Json::str("resident")),
        ("rows", Json::Arr(rows)),
    ]))
}
