//! The training-energy model — the substitute for the paper's FPGA +
//! power-meter measurements (DESIGN.md §Substitutions).
//!
//! Energy of one training step = sum over blocks of
//!
//!   FWD:    macs * mac(Ba, Bw)     + SRAM traffic + DRAM traffic
//!   BWD-x:  macs * mac(Bg, Bw)     (activation-gradient pass)
//!   BWD-w:  macs * mac(Bg, Ba)     (weight-gradient pass), where PSG
//!           replaces the confident fraction p with the 4x10-bit MSB
//!           predictor MAC and the gradient word shrinks to 1 bit on the
//!           update path,
//!   UPD:    weight movement + elementwise update
//!
//! with SLU charging each gateable block by its measured per-batch active
//! fraction (+ the tiny RNN-gate overhead), and SMD simply not charging
//! skipped steps (the coordinator never runs them).
//!
//! All three of the paper's savings are *counting* effects (fewer steps,
//! fewer blocks, narrower words), so savings ratios transfer even though
//! absolute joules are a 45nm ASIC model rather than a Zynq-7000.

use crate::runtime::{Manifest, MethodInfo};

use super::table::OpEnergies;

/// Static per-block cost sheet derived from a manifest.
#[derive(Debug, Clone)]
pub struct BlockCost {
    pub name: String,
    pub gateable: bool,
    /// MACs per sample (manifest `flops`).
    pub macs: f64,
    /// Input activation elements per sample.
    pub act_elems: f64,
    /// Weight elements.
    pub weight_elems: f64,
}

/// Joule breakdown of a charge (all values in joules).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    pub fwd_mac: f64,
    pub bwd_mac: f64,
    pub sram: f64,
    pub dram: f64,
    pub update: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.fwd_mac + self.bwd_mac + self.sram + self.dram + self.update
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.fwd_mac += o.fwd_mac;
        self.bwd_mac += o.bwd_mac;
        self.sram += o.sram;
        self.dram += o.dram;
        self.update += o.update;
    }
}

/// Datapath widths of one training step.
#[derive(Debug, Clone, Copy)]
pub struct Bits {
    pub act: u32,
    pub weight: u32,
    pub grad: u32,
}

impl Bits {
    pub fn fp32() -> Self {
        Self { act: 32, weight: 32, grad: 32 }
    }

    pub fn from_method(m: &MethodInfo) -> Self {
        // qbits_act covers activations and weights (Sec. 4.4: "8-bit
        // precision for the activations/weights and 16-bit for the
        // gradients").
        Self {
            act: m.qbits_act.unwrap_or(32),
            weight: m.qbits_act.unwrap_or(32),
            grad: m.qbits_grad.unwrap_or(32),
        }
    }
}

/// SRAM accesses per MAC in a blocked/systolic schedule: one operand
/// fetch amortized by reuse + partial-sum traffic.  The constant is the
/// Eyeriss-class row-stationary estimate (~1 word per MAC).
const SRAM_WORDS_PER_MAC: f64 = 1.0;

/// DRAM reuse multiplier: each unique tensor word crosses DRAM ~twice
/// per pass (read + spill of intermediates) on a small-buffer device.
const DRAM_TRAFFIC_FACTOR: f64 = 2.0;

pub struct EnergyModel {
    pub ops: OpEnergies,
    pub blocks: Vec<BlockCost>,
    pub head_macs: f64,
    pub head_weight_elems: f64,
    pub gate_macs: f64,
    pub batch: f64,
}

impl EnergyModel {
    /// Build the cost sheet from a manifest (parameter shapes give weight
    /// element counts; block `flops` are per sample).
    pub fn from_manifest(m: &Manifest) -> Self {
        let shape_of = |pname: &str| -> f64 {
            m.train_inputs
                .iter()
                .find(|s| s.name == pname)
                .map(|s| s.elem_count() as f64)
                .unwrap_or(0.0)
        };
        let blocks = m
            .blocks
            .iter()
            .map(|b| BlockCost {
                name: b.name.clone(),
                gateable: b.gateable,
                macs: b.flops as f64,
                act_elems: (b.in_hw * b.in_hw * b.in_ch) as f64,
                weight_elems: b.params.iter().map(|p| shape_of(p)).sum(),
            })
            .collect();
        let head_weight_elems = shape_of("head.w") + shape_of("head.b");
        EnergyModel {
            ops: OpEnergies::default(),
            blocks,
            head_macs: m.head_flops as f64,
            head_weight_elems,
            gate_macs: m.gate_flops as f64,
            batch: m.arch.batch as f64,
        }
    }

    /// Energy of one block's training passes at `active` fraction
    /// (0..=1, the mean gate activation across the batch).
    fn block_step(
        &self,
        b: &BlockCost,
        bits: Bits,
        active: f64,
        psg: Option<(u32, u32, f64)>, // (bits_x, bits_gy, predicted frac)
        sign_update: bool,            // sign/psg: 1-bit gradient on the bus
        fwd_only: bool,               // frozen trunk (head-only fine-tuning)
    ) -> EnergyBreakdown {
        let macs = b.macs * self.batch * active;
        let mut e = EnergyBreakdown::default();

        // --- MAC energy ---------------------------------------------------
        e.fwd_mac = macs * self.ops.mac(bits.act, bits.weight);
        let bwd_x = macs * self.ops.mac(bits.grad, bits.weight);
        let bwd_w = match psg {
            None => macs * self.ops.mac(bits.grad, bits.act),
            Some((bx, bgy, p)) => {
                // Confident fraction runs only the narrow predictor; the
                // fallback fraction still needs the full-width contraction
                // (the predictor is embedded in it, Sec. 3.3).
                macs * (p * self.ops.mac(bx, bgy)
                    + (1.0 - p) * self.ops.mac(bits.grad, bits.act))
            }
        };
        e.bwd_mac = bwd_x + bwd_w;

        // --- SRAM traffic (per-MAC, width-scaled) --------------------------
        let fwd_width = bits.act.max(bits.weight);
        let bwd_width = bits.grad;
        e.sram = self.ops.sram(macs * SRAM_WORDS_PER_MAC, fwd_width)
            + self.ops.sram(2.0 * macs * SRAM_WORDS_PER_MAC, bwd_width);

        // --- DRAM traffic ---------------------------------------------------
        // activations cross per sample and per pass (fwd, bwd-x, bwd-w);
        // weights cross once per step per pass.
        let act_words = b.act_elems * self.batch * active * DRAM_TRAFFIC_FACTOR;
        let w_words = b.weight_elems * DRAM_TRAFFIC_FACTOR;
        e.dram = self.ops.dram(act_words, bits.act)
            + self.ops.dram(2.0 * act_words, bits.grad)
            + self.ops.dram(3.0 * w_words, bits.weight);

        // --- update: read w, read g, write w -------------------------------
        // sign/PSG updates put one bit per weight on the bus (Sec. 3.3).
        let gbits = if sign_update { 1 } else { bits.grad };
        e.update = self.ops.dram(b.weight_elems, gbits)
            + self.ops.dram(2.0 * b.weight_elems, 32)
            + self.ops.mac(32, 32) * b.weight_elems / 8.0;
        if fwd_only {
            // Frozen trunk: forward inference only — no gradient passes,
            // no gradient traffic, no update.
            e.bwd_mac = 0.0;
            e.update = 0.0;
            e.sram = self.ops.sram(macs * SRAM_WORDS_PER_MAC, fwd_width);
            e.dram = self.ops.dram(act_words, bits.act)
                + self.ops.dram(w_words, bits.weight);
        }
        e
    }

    /// Full train-step energy for a method.
    ///
    /// `gate_fracs`: measured per-gated-block active fractions for this
    /// step (empty = all blocks fully active).  `psg_frac`: measured
    /// fraction of weight-gradient entries resolved by the MSB predictor.
    pub fn train_step(
        &self,
        method: &MethodInfo,
        gate_fracs: &[f64],
        psg_frac: Option<f64>,
    ) -> EnergyBreakdown {
        let bits = Bits::from_method(method);
        let psg = if method.update == "psg" {
            Some((
                method.psg_bits_x,
                method.psg_bits_gy,
                psg_frac.unwrap_or(0.6),
            ))
        } else {
            None
        };
        let mut total = EnergyBreakdown::default();
        let mut gi = 0;
        for b in &self.blocks {
            let active = if b.gateable && !gate_fracs.is_empty() {
                let a = gate_fracs.get(gi).copied().unwrap_or(1.0);
                gi += 1;
                a
            } else {
                1.0
            };
            total.add(&self.block_step(
                b,
                bits,
                active,
                psg,
                method.update != "sgd",
                method.head_only,
            ));
        }
        // Head (dense) — never gated.
        total.add(&self.block_step(
            &BlockCost {
                name: "head".into(),
                gateable: false,
                macs: self.head_macs,
                act_elems: 0.0,
                weight_elems: self.head_weight_elems,
            },
            bits,
            1.0,
            psg,
            method.update != "sgd",
            false, // the head always trains (head-only FT trains *only* it)
        ));
        // RNN gate overhead (fp32, tiny — substantiates the 0.04% claim).
        if !gate_fracs.is_empty() {
            let gate_macs = self.gate_macs * self.batch;
            total.fwd_mac += gate_macs * self.ops.mac(32, 32);
            total.bwd_mac += 2.0 * gate_macs * self.ops.mac(32, 32);
        }
        total
    }

    /// Computational (MAC-count) cost of a step relative to a full dense
    /// fp32 step — the "Computational Savings" columns of Tables 3/4
    /// count MACs, not joules.
    pub fn step_macs(&self, gate_fracs: &[f64]) -> f64 {
        let mut total = 0.0;
        let mut gi = 0;
        for b in &self.blocks {
            let active = if b.gateable && !gate_fracs.is_empty() {
                let a = gate_fracs.get(gi).copied().unwrap_or(1.0);
                gi += 1;
                a
            } else {
                1.0
            };
            total += 3.0 * b.macs * self.batch * active;
        }
        total + 3.0 * self.head_macs * self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> EnergyModel {
        EnergyModel {
            ops: OpEnergies::default(),
            blocks: vec![
                BlockCost {
                    name: "stem".into(),
                    gateable: false,
                    macs: 1.0e6,
                    act_elems: 3072.0,
                    weight_elems: 432.0,
                },
                BlockCost {
                    name: "b1".into(),
                    gateable: true,
                    macs: 4.0e6,
                    act_elems: 4096.0,
                    weight_elems: 4608.0,
                },
                BlockCost {
                    name: "b2".into(),
                    gateable: true,
                    macs: 4.0e6,
                    act_elems: 4096.0,
                    weight_elems: 4608.0,
                },
            ],
            head_macs: 640.0,
            head_weight_elems: 650.0,
            gate_macs: 1000.0,
            batch: 32.0,
        }
    }

    fn m(update: &str, qa: Option<u32>, qg: Option<u32>, gating: &str) -> MethodInfo {
        MethodInfo {
            name: "t".into(),
            qbits_act: qa,
            qbits_grad: qg,
            update: update.into(),
            gating: gating.into(),
            alpha: 0.0,
            beta: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            psg_bits_x: 4,
            psg_bits_gy: 10,
            head_only: false,
        }
    }

    #[test]
    fn quantization_saves_energy() {
        let em = toy_model();
        let e32 = em.train_step(&m("sgd", None, None, "none"), &[], None).total();
        let e8 = em
            .train_step(&m("sgd", Some(8), Some(16), "none"), &[], None)
            .total();
        let saving = 1.0 - e8 / e32;
        assert!(saving > 0.3 && saving < 0.9, "saving {saving}");
    }

    #[test]
    fn psg_beats_plain_quantized() {
        let em = toy_model();
        let eq = em
            .train_step(&m("sgd", Some(8), Some(16), "none"), &[], None)
            .total();
        let ep = em
            .train_step(&m("psg", Some(8), Some(16), "none"), &[], Some(0.6))
            .total();
        assert!(ep < eq);
    }

    #[test]
    fn psg_energy_monotone_in_predicted_fraction() {
        let em = toy_model();
        let meth = m("psg", Some(8), Some(16), "none");
        let e_lo = em.train_step(&meth, &[], Some(0.2)).total();
        let e_hi = em.train_step(&meth, &[], Some(0.9)).total();
        assert!(e_hi < e_lo);
    }

    #[test]
    fn gating_scales_block_energy() {
        let em = toy_model();
        let meth = m("sgd", None, None, "learned");
        let full = em.train_step(&meth, &[1.0, 1.0], None).total();
        let half = em.train_step(&meth, &[0.5, 0.5], None).total();
        let none = em.train_step(&meth, &[0.0, 0.0], None).total();
        assert!(none < half && half < full);
        // stem + head + update are not gated, so energy doesn't hit zero.
        assert!(none > 0.05 * full);
    }

    #[test]
    fn gate_overhead_is_negligible() {
        let em = toy_model();
        let meth_g = m("sgd", None, None, "learned");
        let meth_n = m("sgd", None, None, "none");
        let with_gate = em.train_step(&meth_g, &[1.0, 1.0], None).total();
        let without = em.train_step(&meth_n, &[], None).total();
        assert!((with_gate - without) / without < 0.01);
    }

    #[test]
    fn computational_savings_counting() {
        let em = toy_model();
        let dense = em.step_macs(&[]);
        let skipped = em.step_macs(&[0.5, 0.5]);
        // 8/9 of MACs are gateable here; half-active -> 4/9 saved.
        let ratio = skipped / dense;
        assert!((ratio - (1.0 - 4.0 / 9.0)).abs() < 0.01, "ratio {ratio}");
    }
}
