//! Per-operation energy table (45nm CMOS, Horowitz ISSCC'14 — the
//! paper's own cost basis, ref [59]).
//!
//! Scaling rules follow Sec. 3.3: arithmetic energy is ~quadratic in
//! operand width (a b1 x b2 multiplier array scales with b1*b2), data
//! movement is linear in word width.  The paper quotes the resulting
//! anchor points — 8-bit mult saves 95%, 8-bit add 97%, 8-bit movement
//! 75% vs. 32-bit float — which the table reproduces.

/// Energies in picojoules for 32-bit baseline operations.
#[derive(Debug, Clone, Copy)]
pub struct OpEnergies {
    /// 32-bit float multiply.
    pub mult32: f64,
    /// 32-bit float add.
    pub add32: f64,
    /// SRAM access per 32-bit word (on-chip buffer, ~32KB class).
    pub sram32: f64,
    /// DRAM access per 32-bit word (off-chip).
    pub dram32: f64,
}

impl Default for OpEnergies {
    fn default() -> Self {
        // Horowitz ISSCC'14 45nm: FP32 mult 3.7pJ, FP32 add 0.9pJ,
        // 32KB SRAM 5pJ/word, DRAM 640pJ/word.
        Self { mult32: 3.7, add32: 0.9, sram32: 5.0, dram32: 640.0 }
    }
}

impl OpEnergies {
    /// One multiply-accumulate with operand widths (b1, b2) bits.
    /// Multiplier array area/energy ~ b1*b2; adder ~ max width.
    pub fn mac(&self, b1: u32, b2: u32) -> f64 {
        let m = self.mult32 * (b1 as f64 * b2 as f64) / (32.0 * 32.0);
        let a = self.add32 * (b1.max(b2) as f64) / 32.0;
        m + a
    }

    /// SRAM energy for moving `words` values of `bits` width.
    pub fn sram(&self, words: f64, bits: u32) -> f64 {
        self.sram32 * words * bits as f64 / 32.0
    }

    /// DRAM energy for moving `words` values of `bits` width.
    pub fn dram(&self, words: f64, bits: u32) -> f64 {
        self.dram32 * words * bits as f64 / 32.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_points() {
        let e = OpEnergies::default();
        // "8-bit multiplication saves ~95% vs 32-bit float" (Sec. 3.3)
        let mult_saving = 1.0 - (e.mult32 * 64.0 / 1024.0) / e.mult32;
        assert!((mult_saving - 0.9375).abs() < 1e-9);
        // movement is linear: 8-bit moves save 75%
        let move_saving = 1.0 - e.dram(1.0, 8) / e.dram(1.0, 32);
        assert!((move_saving - 0.75).abs() < 1e-9);
    }

    #[test]
    fn mac_monotone_in_bits() {
        let e = OpEnergies::default();
        let mut prev = 0.0;
        for b in [1u32, 4, 8, 16, 32] {
            let cur = e.mac(b, b);
            assert!(cur > prev);
            prev = cur;
        }
    }

    #[test]
    fn asymmetric_mac() {
        let e = OpEnergies::default();
        // 4x10 predictor MAC is far cheaper than the 8x16 full MAC
        // (multiplier 40/128 of the area; adder 10/16 of the width).
        assert!(e.mac(4, 10) < 0.5 * e.mac(8, 16));
    }
}
