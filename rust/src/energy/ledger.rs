//! Energy ledger: the per-run accumulator standing in for the paper's
//! power meter.  The coordinator charges every executed step (SMD-dropped
//! steps are never charged — that *is* the data-level saving) and the
//! harness reads totals/savings at the end.

use super::model::EnergyBreakdown;

#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    pub steps_charged: u64,
    pub steps_skipped: u64,
    pub breakdown: EnergyBreakdown,
    /// MACs actually executed (for "Computational Savings" columns).
    pub macs: f64,
    /// Energy trace: cumulative joules at each recorded point (used by
    /// the Fig. 5 convergence-vs-energy curves).
    pub trace: Vec<(u64, f64)>,
}

impl EnergyLedger {
    pub fn charge(&mut self, step: u64, e: &EnergyBreakdown, macs: f64) {
        self.steps_charged += 1;
        self.breakdown.add(e);
        self.macs += macs;
        self.trace.push((step, self.total_joules()));
    }

    pub fn skip(&mut self) {
        self.steps_skipped += 1;
    }

    pub fn total_joules(&self) -> f64 {
        self.breakdown.total() * 1e-12 // table is in picojoules
    }

    /// Energy saving vs. a reference ledger (e.g. the fp32 SMB baseline).
    pub fn saving_vs(&self, baseline: &EnergyLedger) -> f64 {
        1.0 - self.total_joules() / baseline.total_joules()
    }

    pub fn computational_saving_vs(&self, baseline: &EnergyLedger) -> f64 {
        1.0 - self.macs / baseline.macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one() -> EnergyBreakdown {
        EnergyBreakdown { fwd_mac: 1e9, bwd_mac: 2e9, sram: 5e8, dram: 5e8, update: 1e8 }
    }

    #[test]
    fn accumulates() {
        let mut l = EnergyLedger::default();
        l.charge(0, &one(), 100.0);
        l.charge(1, &one(), 100.0);
        l.skip();
        assert_eq!(l.steps_charged, 2);
        assert_eq!(l.steps_skipped, 1);
        assert!((l.total_joules() - 2.0 * one().total() * 1e-12).abs() < 1e-15);
        assert_eq!(l.macs, 200.0);
        assert_eq!(l.trace.len(), 2);
    }

    #[test]
    fn savings() {
        let mut a = EnergyLedger::default();
        let mut b = EnergyLedger::default();
        for i in 0..10 {
            b.charge(i, &one(), 10.0);
        }
        for i in 0..4 {
            a.charge(i, &one(), 10.0);
        }
        assert!((a.saving_vs(&b) - 0.6).abs() < 1e-12);
        assert!((a.computational_saving_vs(&b) - 0.6).abs() < 1e-12);
    }
}
