//! Energy accounting — the substitute for the paper's FPGA + power-meter
//! setup (Fig. 2).  See DESIGN.md §Substitutions for why savings *ratios*
//! transfer: SMD/SLU/PSG savings are counting effects (fewer steps, fewer
//! blocks, narrower datapaths), charged here with the Horowitz 45nm cost
//! table the paper itself cites.

pub mod ledger;
pub mod model;
pub mod table;

pub use ledger::EnergyLedger;
pub use model::{Bits, EnergyBreakdown, EnergyModel};
pub use table::OpEnergies;
