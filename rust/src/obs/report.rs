//! `e2train trace-report` — aggregate an `obs_trace/v1` JSONL file into
//! a per-phase table (count, total ms, mean, p50/p99, % of run).
//!
//! Aggregation prefers the raw `span` events (re-histogrammed here, so
//! the table reflects exactly what the trace carries); a phase whose
//! spans were capped out of the event log — or a trace stripped down to
//! its tail — falls back to that phase's authoritative `summary` row.
//! Counters and recovery events are appended verbatim.

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

use super::hist::Histogram;
use super::TRACE_SCHEMA;

/// One rendered table row.
#[derive(Debug, Clone)]
pub struct ReportRow {
    pub phase: String,
    pub count: u64,
    pub total_ms: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Share of the run's wall clock (0..100); phases overlap across
    /// threads, so the column need not sum to 100.
    pub pct_of_run: f64,
}

/// Aggregated view of one trace file.
#[derive(Debug, Clone, Default)]
pub struct TraceReport {
    /// (family, method, backend, shards, batch) from the meta row.
    pub key_line: String,
    pub wall_ms: f64,
    pub dropped_events: u64,
    /// Sorted by total ms, descending.
    pub rows: Vec<ReportRow>,
    pub counters: Vec<(String, u64)>,
    /// (site, attempt, backoff_ms) per supervised recovery.
    pub recoveries: Vec<(String, u64, u64)>,
    /// The planner's `plan` row (auto-backend runs), verbatim.
    pub plan: Option<Json>,
}

/// Parse + aggregate an `obs_trace/v1` JSONL document.
pub fn aggregate(text: &str) -> Result<TraceReport> {
    let mut meta: Option<Json> = None;
    let mut spans: Vec<(String, f64)> = Vec::new();
    let mut summaries: Vec<Json> = Vec::new();
    let mut counters = Vec::new();
    let mut recoveries = Vec::new();
    let mut plan: Option<Json> = None;

    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = parse(line).with_context(|| format!("trace line {}", i + 1))?;
        match v.at(&["kind"]).as_str() {
            Some("meta") => {
                let schema = v.at(&["schema"]).as_str().unwrap_or("?");
                if schema != TRACE_SCHEMA {
                    bail!("unsupported trace schema {schema:?} (want {TRACE_SCHEMA})");
                }
                meta = Some(v);
            }
            Some("span") => spans.push((
                v.at(&["phase"]).as_str().unwrap_or("?").to_string(),
                v.at(&["dur_ms"]).as_f64().unwrap_or(0.0),
            )),
            Some("summary") => summaries.push(v),
            Some("counter") => counters.push((
                v.at(&["name"]).as_str().unwrap_or("?").to_string(),
                v.at(&["value"]).as_u64().unwrap_or(0),
            )),
            Some("recovery") => recoveries.push((
                v.at(&["site"]).as_str().unwrap_or("?").to_string(),
                v.at(&["attempt"]).as_u64().unwrap_or(0),
                v.at(&["backoff_ms"]).as_u64().unwrap_or(0),
            )),
            Some("plan") => {
                let mut row = v.as_obj().cloned().unwrap_or_default();
                row.remove("kind");
                plan = Some(Json::Obj(row));
            }
            other => bail!("trace line {}: unknown kind {other:?}", i + 1),
        }
    }
    let meta = meta.ok_or_else(|| {
        anyhow::anyhow!("no meta row — not an {TRACE_SCHEMA} trace")
    })?;
    let wall_ms = meta.at(&["wall_ms"]).as_f64().unwrap_or(0.0);

    // Re-aggregate spans per phase through the same fixed-bucket
    // histogram the live collector uses.
    let mut by_phase: std::collections::BTreeMap<String, Histogram> =
        std::collections::BTreeMap::new();
    for (phase, dur_ms) in spans {
        by_phase
            .entry(phase)
            .or_default()
            .observe((dur_ms * 1e6).max(1.0) as u64);
    }
    let mut rows: Vec<ReportRow> = Vec::new();
    for (phase, h) in &by_phase {
        rows.push(ReportRow {
            phase: phase.clone(),
            count: h.count(),
            total_ms: h.total() as f64 / 1e6,
            mean_ms: h.mean() / 1e6,
            p50_ms: h.percentile(0.50) / 1e6,
            p99_ms: h.percentile(0.99) / 1e6,
            pct_of_run: 0.0,
        });
    }
    // Summary rows cover phases whose spans never made the event log
    // (capped, or a trace reduced to its summary tail).
    for s in &summaries {
        let phase = s.at(&["phase"]).as_str().unwrap_or("?");
        let count = s.at(&["count"]).as_u64().unwrap_or(0);
        let logged = by_phase.get(phase).map(|h| h.count()).unwrap_or(0);
        if logged >= count {
            continue;
        }
        rows.retain(|r| r.phase != phase);
        rows.push(ReportRow {
            phase: phase.to_string(),
            count,
            total_ms: s.at(&["total_ms"]).as_f64().unwrap_or(0.0),
            mean_ms: s.at(&["mean_ms"]).as_f64().unwrap_or(0.0),
            p50_ms: s.at(&["p50_ms"]).as_f64().unwrap_or(0.0),
            p99_ms: s.at(&["p99_ms"]).as_f64().unwrap_or(0.0),
            pct_of_run: 0.0,
        });
    }
    for r in &mut rows {
        r.pct_of_run = if wall_ms > 0.0 {
            100.0 * r.total_ms / wall_ms
        } else {
            0.0
        };
    }
    rows.sort_by(|a, b| {
        b.total_ms
            .partial_cmp(&a.total_ms)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.phase.cmp(&b.phase))
    });

    Ok(TraceReport {
        key_line: format!(
            "{}/{} backend={} shards={} batch={}",
            meta.at(&["family"]).as_str().unwrap_or("?"),
            meta.at(&["method"]).as_str().unwrap_or("?"),
            meta.at(&["backend"]).as_str().unwrap_or("?"),
            meta.at(&["shards"]).as_u64().unwrap_or(0),
            meta.at(&["batch"]).as_u64().unwrap_or(0),
        ),
        wall_ms,
        dropped_events: meta.at(&["dropped_events"]).as_u64().unwrap_or(0),
        rows,
        counters,
        recoveries,
        plan,
    })
}

impl TraceReport {
    /// Render the human-facing table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace: {}  wall {:.1}ms\n",
            self.key_line, self.wall_ms
        ));
        if self.dropped_events > 0 {
            out.push_str(&format!(
                "note: {} span event(s) past the {}-event cap were aggregated but not logged\n",
                self.dropped_events,
                super::MAX_EVENTS
            ));
        }
        out.push_str(&format!(
            "{:<22} {:>8} {:>12} {:>10} {:>10} {:>10} {:>7}\n",
            "phase", "count", "total ms", "mean ms", "p50 ms", "p99 ms", "% run"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<22} {:>8} {:>12.3} {:>10.4} {:>10.4} {:>10.4} {:>6.1}%\n",
                r.phase, r.count, r.total_ms, r.mean_ms, r.p50_ms, r.p99_ms, r.pct_of_run
            ));
        }
        if !self.counters.is_empty() {
            out.push_str("counters:\n");
            for (name, value) in &self.counters {
                out.push_str(&format!("  {name:<38} {value}\n"));
            }
        }
        if !self.recoveries.is_empty() {
            out.push_str("recoveries:\n");
            for (site, attempt, backoff_ms) in &self.recoveries {
                out.push_str(&format!(
                    "  attempt {attempt} at {site} (backoff {backoff_ms}ms)\n"
                ));
            }
        }
        if let Some(p) = &self.plan {
            out.push_str(&format!(
                "plan: backend={} shards={} prefetch_depth={} predicted {:.1} sps / actual {:.1} sps ({:+.1}%)\n",
                p.at(&["backend"]).as_str().unwrap_or("?"),
                p.at(&["shards"]).as_u64().unwrap_or(0),
                p.at(&["prefetch_depth"])
                    .as_u64()
                    .map(|d| d.to_string())
                    .unwrap_or_else(|| "off".into()),
                p.at(&["predicted_sps"]).as_f64().unwrap_or(0.0),
                p.at(&["actual_sps"]).as_f64().unwrap_or(0.0),
                p.at(&["sps_rel_err"]).as_f64().unwrap_or(0.0) * 100.0,
            ));
        }
        out
    }

    /// Machine-readable form (`e2train trace-report --json`): the same
    /// aggregation as the table, one JSON object.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("schema", Json::str("trace_report/v1")),
            ("key", Json::str(&self.key_line)),
            ("wall_ms", Json::num(self.wall_ms)),
            ("dropped_events", Json::num(self.dropped_events as f64)),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("phase", Json::str(&r.phase)),
                        ("count", Json::num(r.count as f64)),
                        ("total_ms", Json::num(r.total_ms)),
                        ("mean_ms", Json::num(r.mean_ms)),
                        ("p50_ms", Json::num(r.p50_ms)),
                        ("p99_ms", Json::num(r.p99_ms)),
                        ("pct_of_run", Json::num(r.pct_of_run)),
                    ])
                })),
            ),
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "recoveries",
                Json::arr(self.recoveries.iter().map(|(site, attempt, backoff_ms)| {
                    Json::obj(vec![
                        ("site", Json::str(site)),
                        ("attempt", Json::num(*attempt as f64)),
                        ("backoff_ms", Json::num(*backoff_ms as f64)),
                    ])
                })),
            ),
        ];
        if let Some(p) = &self.plan {
            pairs.push(("plan", p.clone()));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Obs, TraceKey, CTR_CKPT_SUBMITS, PHASE_AUGMENT, PHASE_STEP_EXEC};
    use std::time::Duration;

    fn sample_trace() -> String {
        let obs = Obs::new(true);
        obs.set_key(TraceKey {
            family: "refmlp-tiny".into(),
            method: "sgd32".into(),
            backend: "host".into(),
            shards: 0,
            batch: 8,
        });
        for i in 0..10 {
            obs.record(PHASE_STEP_EXEC, Duration::from_micros(200 + i));
            obs.record(PHASE_AUGMENT, Duration::from_micros(40));
        }
        obs.count(CTR_CKPT_SUBMITS, 3);
        obs.recovery("engine.train_step", 1, 10);
        obs.snapshot().unwrap().to_jsonl()
    }

    #[test]
    fn aggregates_spans_into_the_table() {
        let rep = aggregate(&sample_trace()).unwrap();
        assert!(rep.key_line.contains("refmlp-tiny/sgd32"));
        assert!(rep.key_line.contains("backend=host"));
        assert!(rep.wall_ms > 0.0);
        let step = rep.rows.iter().find(|r| r.phase == PHASE_STEP_EXEC).unwrap();
        assert_eq!(step.count, 10);
        assert!(step.total_ms >= 2.0, "total {}", step.total_ms);
        assert!(step.p99_ms >= step.p50_ms);
        // step-exec dominates augment, so it sorts first
        assert_eq!(rep.rows[0].phase, PHASE_STEP_EXEC);
        assert_eq!(rep.counters, vec![(CTR_CKPT_SUBMITS.to_string(), 3)]);
        assert_eq!(rep.recoveries.len(), 1);
        let text = rep.render();
        assert!(text.contains("step-exec"));
        assert!(text.contains("% run"));
        assert!(text.contains(CTR_CKPT_SUBMITS));
        assert!(text.contains("engine.train_step"));
    }

    #[test]
    fn summary_rows_back_fill_missing_spans() {
        // Keep only meta + summary lines (a trace reduced to its tail).
        let tail: String = sample_trace()
            .lines()
            .filter(|l| l.contains("\"meta\"") || l.contains("\"summary\""))
            .map(|l| format!("{l}\n"))
            .collect();
        let rep = aggregate(&tail).unwrap();
        let step = rep.rows.iter().find(|r| r.phase == PHASE_STEP_EXEC).unwrap();
        assert_eq!(step.count, 10);
        assert!(step.total_ms > 0.0);
    }

    #[test]
    fn json_output_mirrors_the_table_and_carries_the_plan() {
        let obs = Obs::new(true);
        obs.set_key(TraceKey {
            family: "refmlp-tiny".into(),
            method: "sgd32".into(),
            backend: "resident".into(),
            shards: 0,
            batch: 8,
        });
        obs.record(PHASE_STEP_EXEC, Duration::from_micros(300));
        obs.set_plan(crate::obs::catalog::PlanRecord {
            backend: "resident".into(),
            shards: 0,
            prefetch: true,
            prefetch_depth: Some(2),
            predicted_sps: 1200.0,
            actual_sps: 1000.0,
            sps_rel_err: 0.2,
            ..Default::default()
        });
        let rep = aggregate(&obs.snapshot().unwrap().to_jsonl()).unwrap();
        let plan = rep.plan.as_ref().expect("plan row survives aggregation");
        assert_eq!(plan.at(&["backend"]).as_str(), Some("resident"));
        assert_eq!(plan.at(&["prefetch_depth"]).as_f64(), Some(2.0));
        assert!(rep.render().contains("plan: backend=resident"));

        let j = rep.to_json();
        assert_eq!(j.at(&["schema"]).as_str(), Some("trace_report/v1"));
        assert_eq!(j.at(&["key"]).as_str(), Some(rep.key_line.as_str()));
        let rows = j.at(&["rows"]).as_arr().unwrap();
        assert_eq!(rows.len(), rep.rows.len());
        assert_eq!(rows[0].at(&["phase"]).as_str(), Some(PHASE_STEP_EXEC));
        assert_eq!(
            rows[0].at(&["count"]).as_u64(),
            Some(rep.rows[0].count)
        );
        assert_eq!(j.at(&["plan", "predicted_sps"]).as_f64(), Some(1200.0));
        // And it parses back (single-line machine format).
        let text = j.to_string();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);

        // A plan-less report omits the plan key entirely.
        let rep2 = aggregate(&sample_trace()).unwrap();
        assert!(rep2.plan.is_none());
        assert_eq!(rep2.to_json().at(&["plan"]), &Json::Null);
    }

    #[test]
    fn rejects_non_traces() {
        assert!(aggregate("").is_err());
        assert!(aggregate("{\"kind\":\"span\"}").is_err(), "no meta row");
        let bad_schema =
            "{\"kind\":\"meta\",\"schema\":\"obs_trace/v9\",\"wall_ms\":1}";
        let err = aggregate(bad_schema).unwrap_err();
        assert!(format!("{err:#}").contains("obs_trace/v9"));
    }
}
