//! The calibrated cost/energy catalog (`obs_catalog/v1`).
//!
//! One JSON file holds everything the system has measured about what an
//! execution layout costs: per (family, method, backend, shards, batch)
//! key, the full fixed-bucket histograms of `step-exec` and `augment`
//! span durations plus accumulated joules per charged step.  Entries
//! are built **only** from the observability plane — live runs fold in
//! their [`crate::obs::Obs`] phase histograms and energy-ledger totals,
//! and `e2train catalog --ingest` re-histograms the span rows of an
//! `obs_trace/v1` file — there is no parallel timing path.
//!
//! The planner (`coordinator::planner`) reads the catalog to predict
//! steps/sec and J/step for each candidate plan; every completed run
//! writes its measurements back, so the catalog recalibrates itself
//! run over run.  Histograms merge (associative + commutative, see
//! `obs::hist`), so catalogs from different machines/runs can be merged
//! with `e2train catalog --merge` without losing percentile fidelity.
//!
//! Serve costs live in the same file under the reserved backend name
//! `"serve"` with `batch` = micro-batch size and `step_ns` holding
//! `serve-infer` span durations.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{parse, Json};

use super::hist::Histogram;
use super::TRACE_SCHEMA;

/// Schema identifier pinned field-by-field by `tests/planner_matrix.rs`.
pub const CATALOG_SCHEMA: &str = "obs_catalog/v1";

/// Default catalog filename, written next to the `BENCH_*.json` reports
/// (the repo root in the shipped launchers).
pub const DEFAULT_CATALOG_FILE: &str = "OBS_CATALOG.json";

/// Reserved backend name for serve-side entries (`batch` = micro-batch).
pub const SERVE_BACKEND: &str = "serve";

/// The identity of one catalog entry.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CatalogKey {
    pub family: String,
    pub method: String,
    /// `StepBackend::name()` (`host` | `resident` | `sharded`) or
    /// [`SERVE_BACKEND`].
    pub backend: String,
    pub shards: usize,
    pub batch: usize,
}

impl CatalogKey {
    /// Stable string form used as the JSON map key (BTreeMap order ⇒
    /// deterministic file layout).
    pub fn id(&self) -> String {
        format!(
            "{}/{}/{}/s{}/b{}",
            self.family, self.method, self.backend, self.shards, self.batch
        )
    }
}

/// Accumulated measurements for one key.
#[derive(Debug, Clone)]
pub struct CatalogEntry {
    pub key: CatalogKey,
    /// Full runs folded in.
    pub runs: u64,
    /// Short calibration probes folded in (kept separate so a noisy
    /// 2-step probe is visibly different provenance from a 500-step run).
    pub probes: u64,
    /// `step-exec` span durations (ns).
    pub step_ns: Histogram,
    /// `augment` span durations (ns) — batch assembly cost, the other
    /// leg of the prefetch-overlap pipeline.
    pub augment_ns: Histogram,
    /// `shard-reduce` span durations (ns) — the host combine cost the
    /// sharded backend's reducer pipeline overlaps with shard compute.
    /// Empty for single-executor and serve entries, and in catalogs
    /// written before this field existed (parsed leniently).
    pub reduce_ns: Histogram,
    /// Total joules charged across folded-in runs …
    pub joules: f64,
    /// … over this many executed steps (J/step = joules / joule_steps).
    pub joule_steps: u64,
}

impl CatalogEntry {
    fn new(key: CatalogKey) -> Self {
        CatalogEntry {
            key,
            runs: 0,
            probes: 0,
            step_ns: Histogram::new(),
            augment_ns: Histogram::new(),
            reduce_ns: Histogram::new(),
            joules: 0.0,
            joule_steps: 0,
        }
    }

    /// Mean step-exec nanoseconds (`None` until something was measured).
    pub fn step_mean_ns(&self) -> Option<f64> {
        (self.step_ns.count() > 0).then(|| self.step_ns.mean())
    }

    /// Mean augment nanoseconds (`None` until something was measured).
    pub fn augment_mean_ns(&self) -> Option<f64> {
        (self.augment_ns.count() > 0).then(|| self.augment_ns.mean())
    }

    /// Mean shard-reduce nanoseconds (`None` until a sharded run or
    /// trace measured one — the planner then credits the reduce as
    /// overlapped with shard compute instead of serial after it).
    pub fn reduce_mean_ns(&self) -> Option<f64> {
        (self.reduce_ns.count() > 0).then(|| self.reduce_ns.mean())
    }

    /// Joules per executed step (`None` until energy was charged — the
    /// analytic energy model is layout-invariant, so callers may fall
    /// back to a sibling entry that differs only in backend/shards).
    pub fn j_per_step(&self) -> Option<f64> {
        (self.joule_steps > 0).then(|| self.joules / self.joule_steps as f64)
    }

    fn merge(&mut self, other: &CatalogEntry) {
        self.runs += other.runs;
        self.probes += other.probes;
        self.step_ns.merge(&other.step_ns);
        self.augment_ns.merge(&other.augment_ns);
        self.reduce_ns.merge(&other.reduce_ns);
        self.joules += other.joules;
        self.joule_steps += other.joule_steps;
    }

    fn hist_json(h: &Histogram) -> Json {
        Json::obj(vec![
            (
                "buckets",
                Json::arr(h.bucket_counts().into_iter().map(|(i, c)| {
                    Json::arr([Json::num(i as f64), Json::num(c as f64)])
                })),
            ),
            ("total", Json::num(h.total() as f64)),
            ("max", Json::num(h.max() as f64)),
        ])
    }

    fn hist_from_json(v: &Json, what: &str) -> Result<Histogram> {
        let buckets = v
            .at(&["buckets"])
            .as_arr()
            .ok_or_else(|| anyhow!("{what}: missing buckets array"))?
            .iter()
            .map(|pair| {
                let p = pair
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| anyhow!("{what}: bucket is not an [index, count] pair"))?;
                let idx = p[0]
                    .as_f64()
                    .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                    .ok_or_else(|| anyhow!("{what}: non-integer bucket index"))?;
                let count = p[1]
                    .as_f64()
                    .filter(|v| *v > 0.0 && v.fract() == 0.0)
                    .ok_or_else(|| anyhow!("{what}: non-integer bucket count"))?;
                Ok((idx as usize, count as u64))
            })
            .collect::<Result<Vec<_>>>()?;
        let total = v
            .at(&["total"])
            .as_f64()
            .ok_or_else(|| anyhow!("{what}: missing total"))? as u64;
        let max = v
            .at(&["max"])
            .as_f64()
            .ok_or_else(|| anyhow!("{what}: missing max"))? as u64;
        Histogram::from_parts(&buckets, total, max)
            .ok_or_else(|| anyhow!("{what}: bucket index out of range"))
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("family", Json::str(&self.key.family)),
            ("method", Json::str(&self.key.method)),
            ("backend", Json::str(&self.key.backend)),
            ("shards", Json::num(self.key.shards as f64)),
            ("batch", Json::num(self.key.batch as f64)),
            ("runs", Json::num(self.runs as f64)),
            ("probes", Json::num(self.probes as f64)),
            ("step_ns", Self::hist_json(&self.step_ns)),
            ("augment_ns", Self::hist_json(&self.augment_ns)),
            ("reduce_ns", Self::hist_json(&self.reduce_ns)),
            ("joules", Json::num(self.joules)),
            ("joule_steps", Json::num(self.joule_steps as f64)),
        ])
    }

    fn from_json(id: &str, v: &Json) -> Result<CatalogEntry> {
        let req_str = |k: &str| {
            v.at(&[k])
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("entry {id}: missing string field {k:?}"))
        };
        let req_num = |k: &str| {
            v.at(&[k])
                .as_f64()
                .filter(|n| *n >= 0.0 && n.is_finite())
                .ok_or_else(|| anyhow!("entry {id}: missing/invalid number field {k:?}"))
        };
        let key = CatalogKey {
            family: req_str("family")?,
            method: req_str("method")?,
            backend: req_str("backend")?,
            shards: req_num("shards")? as usize,
            batch: req_num("batch")? as usize,
        };
        if key.id() != id {
            bail!("entry {id}: key fields disagree with map key ({})", key.id());
        }
        Ok(CatalogEntry {
            key,
            runs: req_num("runs")? as u64,
            probes: req_num("probes")? as u64,
            step_ns: Self::hist_from_json(v.at(&["step_ns"]), "step_ns")
                .with_context(|| format!("entry {id}"))?,
            augment_ns: Self::hist_from_json(v.at(&["augment_ns"]), "augment_ns")
                .with_context(|| format!("entry {id}"))?,
            // Lenient: absent in pre-reduce catalogs ⇒ empty histogram
            // (still `obs_catalog/v1` — adding a measurement stream is
            // not a schema break; present-but-corrupt is still fatal).
            reduce_ns: match v.at(&["reduce_ns"]) {
                Json::Null => Histogram::new(),
                rv => Self::hist_from_json(rv, "reduce_ns")
                    .with_context(|| format!("entry {id}"))?,
            },
            joules: req_num("joules")?,
            joule_steps: req_num("joule_steps")? as u64,
        })
    }
}

/// One measurement batch to fold into the catalog (a completed run, a
/// calibration probe, or a serve bench level).
#[derive(Debug, Clone, Default)]
pub struct Observation {
    /// `step-exec` (or `serve-infer`) durations, ns.
    pub step_ns: Histogram,
    /// `augment` durations, ns (empty for serve entries).
    pub augment_ns: Histogram,
    /// `shard-reduce` durations, ns (empty off the sharded backend).
    pub reduce_ns: Histogram,
    pub joules: f64,
    pub joule_steps: u64,
    /// True for short calibration probes.
    pub probe: bool,
}

/// The persisted catalog: a deterministic map of entries.
#[derive(Debug, Clone, Default)]
pub struct Catalog {
    entries: BTreeMap<String, CatalogEntry>,
}

impl Catalog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn get(&self, key: &CatalogKey) -> Option<&CatalogEntry> {
        self.entries.get(&key.id())
    }

    pub fn entries(&self) -> impl Iterator<Item = &CatalogEntry> {
        self.entries.values()
    }

    /// The layout-invariant J/step fallback: energy is charged by the
    /// analytic model per executed step, so any entry sharing (family,
    /// method, batch) predicts J/step for a layout never run before.
    pub fn j_per_step_any_layout(&self, family: &str, method: &str, batch: usize) -> Option<f64> {
        self.entries
            .values()
            .find(|e| {
                e.key.family == family
                    && e.key.method == method
                    && e.key.batch == batch
                    && e.joule_steps > 0
            })
            .and_then(CatalogEntry::j_per_step)
    }

    /// Fold one measurement batch into `key`'s entry.
    pub fn observe(&mut self, key: CatalogKey, obs: &Observation) {
        let e = self
            .entries
            .entry(key.id())
            .or_insert_with(|| CatalogEntry::new(key));
        if obs.probe {
            e.probes += 1;
        } else {
            e.runs += 1;
        }
        e.step_ns.merge(&obs.step_ns);
        e.augment_ns.merge(&obs.augment_ns);
        e.reduce_ns.merge(&obs.reduce_ns);
        e.joules += obs.joules;
        e.joule_steps += obs.joule_steps;
    }

    /// Fold another catalog in (entry-wise histogram merge).
    pub fn merge(&mut self, other: &Catalog) {
        for (id, entry) in &other.entries {
            match self.entries.get_mut(id) {
                Some(e) => e.merge(entry),
                None => {
                    self.entries.insert(id.clone(), entry.clone());
                }
            }
        }
    }

    /// Re-histogram the span rows of an `obs_trace/v1` JSONL document
    /// into this catalog under the trace's own (family, method, backend,
    /// shards, batch) key.  Span-less traces are rejected — a summary
    /// row's mean can't honestly reconstruct a distribution, and the
    /// trace carries no energy ledger, so `joules` stays untouched.
    pub fn ingest_trace(&mut self, text: &str) -> Result<()> {
        let mut key: Option<CatalogKey> = None;
        let mut obs = Observation::default();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = parse(line).with_context(|| format!("trace line {}", i + 1))?;
            match v.at(&["kind"]).as_str() {
                Some("meta") => {
                    let schema = v.at(&["schema"]).as_str().unwrap_or("?");
                    if schema != TRACE_SCHEMA {
                        bail!("unsupported trace schema {schema:?} (want {TRACE_SCHEMA})");
                    }
                    key = Some(CatalogKey {
                        family: v.at(&["family"]).as_str().unwrap_or("?").into(),
                        method: v.at(&["method"]).as_str().unwrap_or("?").into(),
                        backend: v.at(&["backend"]).as_str().unwrap_or("?").into(),
                        shards: v.at(&["shards"]).as_usize().unwrap_or(0),
                        batch: v.at(&["batch"]).as_usize().unwrap_or(0),
                    });
                }
                Some("span") => {
                    let ns = (v.at(&["dur_ms"]).as_f64().unwrap_or(0.0) * 1e6).max(1.0) as u64;
                    match v.at(&["phase"]).as_str() {
                        Some(super::PHASE_STEP_EXEC) | Some(super::PHASE_SERVE_INFER) => {
                            obs.step_ns.observe(ns)
                        }
                        Some(super::PHASE_AUGMENT) => obs.augment_ns.observe(ns),
                        Some(super::PHASE_SHARD_REDUCE) => obs.reduce_ns.observe(ns),
                        _ => {}
                    }
                }
                _ => {} // other row kinds carry no catalog-relevant cost
            }
        }
        let key = key.ok_or_else(|| anyhow!("no meta row — not an {TRACE_SCHEMA} trace"))?;
        if obs.step_ns.count() == 0 {
            bail!(
                "trace has no step-exec/serve-infer span rows to ingest \
                 (record the run with --trace-out)"
            );
        }
        self.observe(key, &obs);
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(CATALOG_SCHEMA)),
            (
                "entries",
                Json::Obj(
                    self.entries
                        .iter()
                        .map(|(id, e)| (id.clone(), e.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Catalog> {
        let schema = v
            .at(&["schema"])
            .as_str()
            .ok_or_else(|| anyhow!("not a catalog: missing schema field"))?;
        if schema != CATALOG_SCHEMA {
            bail!("unsupported catalog schema {schema:?} (want {CATALOG_SCHEMA})");
        }
        let raw = v
            .at(&["entries"])
            .as_obj()
            .ok_or_else(|| anyhow!("catalog: missing entries object"))?;
        let mut entries = BTreeMap::new();
        for (id, ev) in raw {
            entries.insert(id.clone(), CatalogEntry::from_json(id, ev)?);
        }
        Ok(Catalog { entries })
    }

    /// Parse a catalog file.  A missing file is an error here — callers
    /// that treat "no catalog yet" as empty use [`Catalog::load_or_empty`].
    pub fn load(path: &Path) -> Result<Catalog> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading catalog {}", path.display()))?;
        let v = parse(&text).with_context(|| format!("parsing catalog {}", path.display()))?;
        Self::from_json(&v).with_context(|| format!("catalog {}", path.display()))
    }

    /// A missing file is an empty catalog (first run bootstraps it); a
    /// present-but-corrupt file is still a hard error — silently
    /// resetting a corrupt catalog would erase every calibration.
    pub fn load_or_empty(path: &Path) -> Result<Catalog> {
        if path.exists() {
            Self::load(path)
        } else {
            Ok(Catalog::new())
        }
    }

    /// Atomic-ish save: write sibling temp, rename over.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_string())
            .with_context(|| format!("writing catalog {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing catalog {}", path.display()))?;
        Ok(())
    }

    /// Human-facing listing for `e2train catalog`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>5} {:>6} {:>12} {:>12} {:>12}\n",
            "key", "runs", "probes", "step ms", "augment ms", "J/step"
        ));
        for e in self.entries.values() {
            let fmt_opt = |v: Option<f64>, scale: f64| match v {
                Some(x) => format!("{:.4}", x / scale),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "{:<44} {:>5} {:>6} {:>12} {:>12} {:>12}\n",
                e.key.id(),
                e.runs,
                e.probes,
                fmt_opt(e.step_mean_ns(), 1e6),
                fmt_opt(e.augment_mean_ns(), 1e6),
                fmt_opt(e.j_per_step(), 1.0),
            ));
        }
        out
    }
}

/// The plan the planner chose for one run, with predicted-vs-actual
/// accounting filled in at end of run.  Carried in [`crate::metrics::RunMetrics`]
/// and emitted as the `plan` row of the run trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanRecord {
    /// Chosen backend name (`host` | `resident` | `sharded`).
    pub backend: String,
    /// Chosen shard count (0 for single-executor backends).
    pub shards: usize,
    /// Whether the plan pipelines batch assembly.
    pub prefetch: bool,
    /// Pinned prefetch channel depth (None when prefetch is off).
    pub prefetch_depth: Option<usize>,
    /// True when a calibration probe ran because catalog keys were
    /// missing — the plan is then measurement-seeded, not pure lookup.
    pub probed: bool,
    /// Planner's predicted training throughput (steps/sec).
    pub predicted_sps: f64,
    /// Planner's predicted energy per executed step (0.0 = unknown:
    /// no energy had ever been charged for this workload).
    pub predicted_j_per_step: f64,
    /// Measured throughput over this run's step-exec spans.
    pub actual_sps: f64,
    /// Measured ledger joules per executed step.
    pub actual_j_per_step: f64,
    /// (predicted − actual) / actual for steps/sec (0.0 until actuals).
    pub sps_rel_err: f64,
    /// (predicted − actual) / actual for J/step.
    pub j_rel_err: f64,
}

impl PlanRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::str(&self.backend)),
            ("shards", Json::num(self.shards as f64)),
            ("prefetch", Json::Bool(self.prefetch)),
            (
                "prefetch_depth",
                match self.prefetch_depth {
                    Some(d) => Json::num(d as f64),
                    None => Json::Null,
                },
            ),
            ("probed", Json::Bool(self.probed)),
            ("predicted_sps", Json::num(self.predicted_sps)),
            ("predicted_j_per_step", Json::num(self.predicted_j_per_step)),
            ("actual_sps", Json::num(self.actual_sps)),
            ("actual_j_per_step", Json::num(self.actual_j_per_step)),
            ("sps_rel_err", Json::num(self.sps_rel_err)),
            ("j_rel_err", Json::num(self.j_rel_err)),
        ])
    }

    /// Fill the actuals and relative errors from end-of-run measurements.
    pub fn record_actuals(&mut self, actual_sps: f64, actual_j_per_step: f64) {
        self.actual_sps = actual_sps;
        self.actual_j_per_step = actual_j_per_step;
        let rel = |pred: f64, act: f64| if act > 0.0 { (pred - act) / act } else { 0.0 };
        self.sps_rel_err = rel(self.predicted_sps, actual_sps);
        self.j_rel_err = if self.predicted_j_per_step > 0.0 {
            rel(self.predicted_j_per_step, actual_j_per_step)
        } else {
            0.0
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Obs, TraceKey, PHASE_AUGMENT, PHASE_STEP_EXEC};
    use std::time::Duration;

    fn key(backend: &str, shards: usize) -> CatalogKey {
        CatalogKey {
            family: "refmlp-tiny".into(),
            method: "sgd32".into(),
            backend: backend.into(),
            shards,
            batch: 8,
        }
    }

    fn obs_with(step_us: &[u64], aug_us: &[u64], joules: f64, steps: u64) -> Observation {
        let mut o = Observation { joules, joule_steps: steps, ..Default::default() };
        for &v in step_us {
            o.step_ns.observe(v * 1000);
        }
        for &v in aug_us {
            o.augment_ns.observe(v * 1000);
        }
        o
    }

    #[test]
    fn observe_merge_and_roundtrip() {
        let mut cat = Catalog::new();
        cat.observe(key("host", 0), &obs_with(&[200, 220, 240], &[40, 42], 0.6, 3));
        cat.observe(key("sharded", 2), &obs_with(&[150, 160], &[40], 0.4, 2));
        assert_eq!(cat.len(), 2);
        let e = cat.get(&key("host", 0)).unwrap();
        assert_eq!(e.runs, 1);
        assert_eq!(e.step_ns.count(), 3);
        assert!((e.j_per_step().unwrap() - 0.2).abs() < 1e-12);
        // Same key folds in, different provenance counted separately.
        let mut probe = obs_with(&[210], &[], 0.0, 0);
        probe.probe = true;
        cat.observe(key("host", 0), &probe);
        let e = cat.get(&key("host", 0)).unwrap();
        assert_eq!((e.runs, e.probes), (1, 1));
        assert_eq!(e.step_ns.count(), 4);

        // JSON round-trip is exact (histograms included).
        let back = Catalog::from_json(&cat.to_json()).unwrap();
        assert_eq!(back.len(), cat.len());
        for (a, b) in back.entries().zip(cat.entries()) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.step_ns.count(), b.step_ns.count());
            assert_eq!(a.step_ns.percentile(0.99), b.step_ns.percentile(0.99));
            assert_eq!(a.augment_ns.total(), b.augment_ns.total());
            assert_eq!((a.runs, a.probes), (b.runs, b.probes));
            assert_eq!(a.joule_steps, b.joule_steps);
        }

        // Catalog-level merge = entry-wise histogram merge.
        let mut other = Catalog::new();
        other.observe(key("host", 0), &obs_with(&[500], &[90], 0.25, 1));
        other.observe(key("resident", 0), &obs_with(&[180], &[40], 0.2, 1));
        let mut merged = cat.clone();
        merged.merge(&other);
        assert_eq!(merged.len(), 3);
        let e = merged.get(&key("host", 0)).unwrap();
        assert_eq!(e.step_ns.count(), 5);
        assert_eq!(e.runs, 2);
        assert!((e.joules - 0.85).abs() < 1e-12);

        // Layout-invariant energy fallback finds a sibling entry.
        let j = merged.j_per_step_any_layout("refmlp-tiny", "sgd32", 8);
        assert!(j.is_some());
        assert_eq!(merged.j_per_step_any_layout("nope", "sgd32", 8), None);

        let text = merged.render();
        assert!(text.contains("refmlp-tiny/sgd32/host/s0/b8"));
        assert!(text.contains("J/step"));
    }

    #[test]
    fn save_load_and_reject_corruption() {
        let tmp = crate::util::tmp::TempDir::new().unwrap();
        let path = tmp.path().join("OBS_CATALOG.json");
        // Missing file: load_or_empty bootstraps, load errors.
        assert!(Catalog::load_or_empty(&path).unwrap().is_empty());
        assert!(Catalog::load(&path).is_err());

        let mut cat = Catalog::new();
        cat.observe(key("host", 0), &obs_with(&[200], &[40], 0.1, 1));
        cat.save(&path).unwrap();
        let back = Catalog::load(&path).unwrap();
        assert_eq!(back.len(), 1);

        // Corrupt file: hard error, never silently reset.
        std::fs::write(&path, "{not json").unwrap();
        assert!(Catalog::load_or_empty(&path).is_err());
        // Wrong schema: named in the error.
        std::fs::write(&path, "{\"schema\":\"obs_catalog/v9\",\"entries\":{}}").unwrap();
        let err = format!("{:#}", Catalog::load_or_empty(&path).unwrap_err());
        assert!(err.contains("obs_catalog/v9"), "{err}");
        // Out-of-range bucket index inside an entry: rejected.
        let mut bad = cat.to_json().to_string();
        bad = bad.replace("\"buckets\":[[", "\"buckets\":[[9999,1],[");
        std::fs::write(&path, bad).unwrap();
        assert!(Catalog::load(&path).is_err());
        // Map key disagreeing with entry fields: rejected.
        let mut v = cat.to_json().as_obj().unwrap().clone();
        let entries = v.get("entries").unwrap().as_obj().unwrap().clone();
        let (_, entry) = entries.iter().next().unwrap();
        let mut renamed = BTreeMap::new();
        renamed.insert("wrong/key/host/s0/b8".to_string(), entry.clone());
        v.insert("entries".into(), Json::Obj(renamed));
        std::fs::write(&path, Json::Obj(v).to_string()).unwrap();
        assert!(Catalog::load(&path).is_err());
    }

    #[test]
    fn ingests_trace_span_rows() {
        let obs = Obs::new(true);
        obs.set_key(TraceKey {
            family: "refmlp-tiny".into(),
            method: "sgd32".into(),
            backend: "host".into(),
            shards: 0,
            batch: 8,
        });
        for i in 0..10 {
            obs.record(PHASE_STEP_EXEC, Duration::from_micros(200 + i));
            obs.record(PHASE_AUGMENT, Duration::from_micros(40));
        }
        let text = obs.snapshot().unwrap().to_jsonl();
        let mut cat = Catalog::new();
        cat.ingest_trace(&text).unwrap();
        let e = cat.get(&key("host", 0)).unwrap();
        assert_eq!(e.runs, 1);
        assert_eq!(e.step_ns.count(), 10);
        assert_eq!(e.augment_ns.count(), 10);
        assert!(e.step_mean_ns().unwrap() >= 200_000.0);
        assert_eq!(e.j_per_step(), None, "traces carry no energy ledger");

        // A summary-only trace (spans capped/stripped) is rejected —
        // means can't honestly reconstruct a distribution.
        let tail: String = text
            .lines()
            .filter(|l| l.contains("\"meta\"") || l.contains("\"summary\""))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(cat.ingest_trace(&tail).is_err());
        // Not a trace at all.
        assert!(cat.ingest_trace("{\"kind\":\"span\"}").is_err());
    }

    #[test]
    fn plan_record_actuals_and_json() {
        let mut p = PlanRecord {
            backend: "sharded".into(),
            shards: 2,
            prefetch: true,
            prefetch_depth: Some(3),
            probed: false,
            predicted_sps: 1000.0,
            predicted_j_per_step: 0.2,
            ..Default::default()
        };
        p.record_actuals(800.0, 0.25);
        assert!((p.sps_rel_err - 0.25).abs() < 1e-12);
        assert!((p.j_rel_err + 0.2).abs() < 1e-12);
        let j = p.to_json();
        assert_eq!(j.at(&["backend"]).as_str(), Some("sharded"));
        assert_eq!(j.at(&["prefetch_depth"]).as_f64(), Some(3.0));
        assert_eq!(j.at(&["actual_sps"]).as_f64(), Some(800.0));
        // Unknown predicted energy pins rel err at 0, not -1.
        let mut q = PlanRecord { predicted_sps: 10.0, ..Default::default() };
        q.record_actuals(10.0, 0.5);
        assert_eq!(q.j_rel_err, 0.0);
        assert_eq!(q.to_json().at(&["prefetch_depth"]), &Json::Null);
    }
}
