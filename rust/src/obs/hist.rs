//! Fixed-bucket log-scale latency histogram.
//!
//! Durations land in power-of-two octaves subdivided into 4 linear
//! sub-buckets, so a bucket's upper bound overestimates a sample by at
//! most 25% — accurate enough for p50/p99 while the whole histogram is
//! a fixed 252-slot array regardless of how many samples it absorbs.
//! That bound is why the serve stats collector can drop its unbounded
//! latency ring (`serve::stats`): observing a sample is O(1), memory is
//! constant, and percentiles never require a sort.
//!
//! Everything is plain data: no clocks, no threads, no allocation after
//! the first observation.  [`Histogram::merge`] is associative and
//! commutative, so per-thread histograms combine deterministically in
//! any order.

/// Number of buckets: values 0..8 exact, then 4 sub-buckets per octave
/// up to the full `u64` range.
pub const NUM_BUCKETS: usize = 252;

/// A fixed-size log-scale histogram over `u64` samples (nanoseconds by
/// convention, but unit-agnostic).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    /// Lazily sized to [`NUM_BUCKETS`] on first observation.
    counts: Vec<u64>,
    count: u64,
    total: u64,
    max: u64,
}

/// Bucket index for a sample: exact below 8, then
/// `8 + 4*(msb-3) + sub` where `sub` is the sample's two bits below the
/// most significant one.
fn bucket_index(v: u64) -> usize {
    if v < 8 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // >= 3
    let sub = ((v >> (msb - 2)) & 3) as usize;
    (8 + 4 * (msb - 3) + sub).min(NUM_BUCKETS - 1)
}

/// Largest sample a bucket can hold — the value reported for any
/// percentile that lands in it (clamped to the observed max).
fn bucket_upper(idx: usize) -> u64 {
    if idx < 8 {
        return idx as u64;
    }
    let msb = (idx - 8) / 4 + 3;
    let sub = ((idx - 8) % 4) as u64;
    // Subtract before adding: for the top bucket (msb 63, sub 3) the
    // naive `(1<<msb) + ((sub+1)<<(msb-2)) - 1` overflows u64 mid-way;
    // this order peaks at exactly u64::MAX.
    (1u64 << msb) - 1 + ((sub + 1) << (msb - 2))
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn observe(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; NUM_BUCKETS];
        }
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.total = self.total.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Samples observed.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all samples (saturating).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Exact largest sample.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile estimate for `p` in [0, 1]: the upper
    /// bound of the bucket holding the rank-th sample (≤ 25% above the
    /// true value), clamped to the exact observed max.  0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((self.count as f64 * p).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper(idx).min(self.max) as f64;
            }
        }
        self.max as f64
    }

    /// Fold another histogram in (associative + commutative, so
    /// per-thread histograms combine deterministically in any order).
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; NUM_BUCKETS];
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.total = self.total.saturating_add(other.total);
        self.max = self.max.max(other.max);
    }

    /// Non-empty buckets as `(index, count)` pairs in index order — the
    /// sparse wire form the `obs_catalog/v1` file persists.
    pub fn bucket_counts(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Rebuild a histogram from its persisted sparse form.  The sample
    /// count is derived from the bucket sum (it is not independently
    /// trusted); an out-of-range bucket index rejects the whole thing —
    /// a corrupt catalog must fail loudly, not shift percentiles.
    pub fn from_parts(buckets: &[(usize, u64)], total: u64, max: u64) -> Option<Histogram> {
        let mut h = Histogram::new();
        if buckets.is_empty() {
            return Some(h);
        }
        h.counts = vec![0; NUM_BUCKETS];
        for &(idx, c) in buckets {
            if idx >= NUM_BUCKETS {
                return None;
            }
            h.counts[idx] = h.counts[idx].checked_add(c)?;
            h.count = h.count.checked_add(c)?;
        }
        h.total = total;
        h.max = max;
        Some(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_tight_and_monotone() {
        // Exact below 8; ≤ 25% overestimate everywhere else.
        for v in [0u64, 1, 7, 8, 9, 100, 1_000, 65_536, 1_000_000, u64::MAX / 2] {
            let up = bucket_upper(bucket_index(v));
            assert!(up >= v, "upper {up} < value {v}");
            assert!(up <= v + v / 4 + 1, "upper {up} too loose for {v}");
        }
        // Bucket uppers strictly increase (no overlap, no gap inversion).
        for i in 1..NUM_BUCKETS {
            assert!(bucket_upper(i) > bucket_upper(i - 1), "bucket {i}");
        }
        // The top bucket's bound is exactly u64::MAX — the naive
        // arithmetic order overflowed here.
        assert_eq!(bucket_upper(bucket_index(u64::MAX)), u64::MAX);
        assert_eq!(bucket_upper(NUM_BUCKETS - 1), u64::MAX);
        // Adjacent values never map to earlier buckets.
        let mut prev = 0;
        for v in 0..100_000u64 {
            let b = bucket_index(v);
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn percentiles_bracket_the_data() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.observe(v * 1000); // 1µs..1ms in µs steps
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        assert!(p50 >= 500_000.0 && p50 <= 625_001.0, "p50 = {p50}");
        assert!(p99 >= 990_000.0 && p99 <= 1_000_000.0, "p99 = {p99}");
        assert!(p99 >= p50);
        assert_eq!(h.max(), 1_000_000);
        assert!((h.mean() - 500_500.0).abs() < 1e-6);
        // Empty histogram reports zeros, not NaNs.
        let e = Histogram::new();
        assert_eq!(e.percentile(0.99), 0.0);
        assert_eq!(e.mean(), 0.0);
        // A top-bucket sample (e.g. a corrupt duration re-histogrammed
        // by trace-report) must not overflow percentile().
        let mut big = Histogram::new();
        big.observe(u64::MAX);
        assert_eq!(big.percentile(0.99), u64::MAX as f64);
    }

    #[test]
    fn merge_matches_combined_observation() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for v in 0..500u64 {
            a.observe(v * 7);
            all.observe(v * 7);
        }
        for v in 0..300u64 {
            b.observe(v * 13 + 5);
            all.observe(v * 13 + 5);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.total(), all.total());
        assert_eq!(a.max(), all.max());
        for p in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.percentile(p), all.percentile(p), "p = {p}");
        }
        // Merging into an empty histogram copies.
        let mut e = Histogram::new();
        e.merge(&all);
        assert_eq!(e.count(), all.count());
        assert_eq!(e.percentile(0.5), all.percentile(0.5));
    }

    /// Cross-run calibration merges histograms whose ranges don't
    /// overlap at all (e.g. a fast host run folded into a slow sharded
    /// one).  Pin the quantile contract on the merged result: every
    /// percentile still falls inside the ≤25% bucket-overestimate band
    /// of the true pooled quantile, the median lands *between* the two
    /// clusters' ranges, and p100 is the exact pooled max.
    #[test]
    fn merged_disjoint_ranges_keep_the_quantile_contract() {
        let mut fast = Histogram::new();
        let mut slow = Histogram::new();
        for v in 0..1000u64 {
            fast.observe(1_000 + v); // ~1µs cluster
            slow.observe(1_000_000 + v * 100); // ~1ms cluster
        }
        let mut merged = fast.clone();
        merged.merge(&slow);
        assert_eq!(merged.count(), 2000);
        assert_eq!(merged.max(), slow.max());
        // p25 resolves inside the fast cluster, p75 inside the slow one.
        let p25 = merged.percentile(0.25);
        assert!((1_000.0..=2_500.0).contains(&p25), "p25 = {p25}");
        let p75 = merged.percentile(0.75);
        assert!((1_000_000.0..=1_375_000.0).contains(&p75), "p75 = {p75}");
        // The median is the bucket holding sample #1000 — the last fast
        // sample — so it must report from the fast cluster's top bucket,
        // never leak into the empty gap or the slow cluster.
        let p50 = merged.percentile(0.50);
        assert!((1_999.0..=2_500.0).contains(&p50), "p50 = {p50}");
        // p100 is exact (clamped to observed max, not a bucket bound).
        assert_eq!(merged.percentile(1.0), slow.max() as f64);
        // Merge order doesn't matter (commutative).
        let mut rev = slow.clone();
        rev.merge(&fast);
        for p in [0.0, 0.25, 0.5, 0.75, 0.99, 1.0] {
            assert_eq!(rev.percentile(p), merged.percentile(p), "p = {p}");
        }
    }

    /// The sparse persisted form round-trips exactly, and corrupt parts
    /// are rejected rather than absorbed.
    #[test]
    fn sparse_parts_round_trip_and_reject_corruption() {
        let mut h = Histogram::new();
        for v in [0u64, 7, 8, 950, 65_000, 1_000_000, u64::MAX / 3] {
            h.observe(v);
        }
        let parts = h.bucket_counts();
        assert!(!parts.is_empty());
        assert!(parts.windows(2).all(|w| w[0].0 < w[1].0), "sorted by index");
        let back = Histogram::from_parts(&parts, h.total(), h.max()).unwrap();
        assert_eq!(back.count(), h.count());
        assert_eq!(back.total(), h.total());
        assert_eq!(back.max(), h.max());
        for p in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(back.percentile(p), h.percentile(p), "p = {p}");
        }
        // Empty round-trip.
        let e = Histogram::from_parts(&[], 0, 0).unwrap();
        assert_eq!(e.count(), 0);
        assert_eq!(e.percentile(0.5), 0.0);
        // Out-of-range bucket index → rejected.
        assert!(Histogram::from_parts(&[(NUM_BUCKETS, 1)], 1, 1).is_none());
        // Counts that overflow u64 on summation → rejected.
        assert!(Histogram::from_parts(&[(0, u64::MAX), (1, 1)], 0, 0).is_none());
    }
}
