//! `obs` — the observability plane: phase-labeled span timers, named
//! monotonic counters, fixed-bucket latency histograms, and a
//! deterministic end-of-run trace (`obs_trace/v1`).
//!
//! Every layer of the stack answers "where did this run's wall-time
//! go?" through one [`Obs`] handle: the trainer times `augment` /
//! `prefetch-stall` / `step-exec`, the sharded backend times per-shard
//! execution plus the `shard-reduce` / `reduce-tree` / `optim-apply` /
//! `pipeline-stall` host phases, the checkpoint registry times
//! `checkpoint-encode` / `registry-publish`, and the serve pipeline
//! times `serve-batch-assembly` / `serve-infer`.
//! Spans record under the *recording thread's* label (worker threads
//! are already named — `e2train-prefetch`, `e2train-ckpt-writer`,
//! `e2train-serve-batcher`, `e2train-reducer` — and shard legs label themselves
//! `shard-{i}`), and per-thread aggregates merge into per-phase
//! summaries by sorted `BTreeMap` iteration, so the summary is
//! deterministic no matter how threads interleaved.
//!
//! **The inertness contract.**  Telemetry must be provably inert: a run
//! with tracing on is bitwise identical — metrics trace, energy ledger,
//! final state — to the same run with tracing off
//! (`tests/obs_invariance.rs` pins this across the backend matrix).
//! The contract holds by construction: recording only reads clocks and
//! mutates `obs`-private state, never an RNG or a tensor; timestamps
//! live only in this plane and are excluded from the determinism
//! fingerprint (`config::RunCfg::determinism_json`) and the checkpoint
//! payload.  `Obs` is a cheap cloneable handle around an
//! `Option<Arc<ObsHub>>` — [`Obs::off`] makes every call a no-op, the
//! fault-plan threading pattern (`util::fault`) applied to telemetry.
//!
//! Aggregates are always collected (they feed `RunMetrics` and
//! `BENCH_runtime.json`); the per-span *event log* is recorded only
//! when a JSONL trace was requested (`cfg.trace_out` /
//! `e2train train --trace-out`), capped at [`MAX_EVENTS`] with an
//! explicit dropped-event count — never a silent truncation.  Trace
//! rows are keyed by (family, method, backend, shards, batch) so the
//! planned cost/energy catalog (ROADMAP) can ingest them directly.

pub mod catalog;
pub mod hist;
pub mod report;

pub use hist::Histogram;

use catalog::PlanRecord;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::Json;

/// Trace schema identifier (first JSONL line of every trace).
pub const TRACE_SCHEMA: &str = "obs_trace/v1";

/// Per-span event-log cap; past it spans still aggregate but the event
/// is counted into `dropped_events` instead of logged.
pub const MAX_EVENTS: usize = 65_536;

// Phase labels.  One constant per instrumented phase so the trace, the
// summary, `BENCH_runtime.json` and the tests all agree on spelling.
/// Batch assembly (sampler + augmentation), sync path or prefetch worker.
pub const PHASE_AUGMENT: &str = "augment";
/// Consumer-side wait on the prefetch channel (pipeline bubble).
pub const PHASE_PREFETCH_STALL: &str = "prefetch-stall";
/// One `StepBackend::train_step` as the trainer sees it.
pub const PHASE_STEP_EXEC: &str = "step-exec";
/// One shard leg's forward/backward (recorded per shard thread).
pub const PHASE_SHARD_EXEC: &str = "shard-exec";
/// Fixed-order host all-reduce of per-shard outputs.
pub const PHASE_SHARD_REDUCE: &str = "shard-reduce";
/// The fixed-shape tree fold of gradient contributions inside one
/// shard-reduce job (`runtime::reduce::fold_tree`).
pub const PHASE_REDUCE_TREE: &str = "reduce-tree";
/// Main-thread wait on the reduce pipeline: blocking a micro-batch
/// hand-off on the full 2-slot ring, plus the end-of-step commit drain.
pub const PHASE_PIPELINE_STALL: &str = "pipeline-stall";
/// `optim::update::apply_update` + master write-back + rebroadcast.
pub const PHASE_OPTIM_APPLY: &str = "optim-apply";
/// Streaming `ckpt/v1` encode to the registry temp file.
pub const PHASE_CKPT_ENCODE: &str = "checkpoint-encode";
/// Whole registry publish (encode + rename + manifest + retention).
pub const PHASE_REGISTRY_PUBLISH: &str = "registry-publish";
/// Serve batcher: first staged sample -> micro-batch flush.
pub const PHASE_SERVE_ASSEMBLY: &str = "serve-batch-assembly";
/// Serve worker: one `eval_batch_snapshot` execution.
pub const PHASE_SERVE_INFER: &str = "serve-infer";
/// One checkpoint's evacuation to the remote store (chunked upload +
/// verify + promote + remote manifest publish), recorded on the
/// replicator thread.
pub const PHASE_REPLICATE_UPLOAD: &str = "replicate-upload";

// Counter names (monotonic u64).
/// Batches the prefetch worker finished assembling.
pub const CTR_PREFETCH_PRODUCED: &str = "prefetch.batches-produced";
/// Consumer arrivals that found the prefetch channel empty.
pub const CTR_PREFETCH_STALLS: &str = "prefetch.stalls";
/// Sum of ready-batch counts sampled at each consumer arrival …
pub const CTR_PREFETCH_OCC_SUM: &str = "prefetch.occupancy-sum";
/// … over this many samples (mean occupancy = sum / samples).
pub const CTR_PREFETCH_OCC_SAMPLES: &str = "prefetch.occupancy-samples";
/// Nanoseconds `CheckpointWriter::submit` blocked on the depth-1
/// channel while the previous write was still in flight.
pub const CTR_CKPT_BACKPRESSURE_WAIT_NS: &str = "ckpt.backpressure-wait-ns";
/// Checkpoints submitted to the background writer.
pub const CTR_CKPT_SUBMITS: &str = "ckpt.submits";
/// Accumulated per-step spread between the slowest and fastest shard
/// leg (ns) — the straggler cost the fixed-order reduce waits out.
pub const CTR_SHARD_IMBALANCE_NS: &str = "shard.imbalance-ns";
/// Sum of request-queue depths sampled at each batcher pop …
pub const CTR_SERVE_QUEUE_DEPTH_SUM: &str = "serve.queue-depth-sum";
/// … over this many samples.
pub const CTR_SERVE_QUEUE_DEPTH_SAMPLES: &str = "serve.queue-depth-samples";
/// Real (non-padding) rows across executed serve micro-batches …
pub const CTR_SERVE_BATCH_REAL: &str = "serve.batch-rows-real";
/// … out of this many total rows (fill ratio = real / total).
pub const CTR_SERVE_BATCH_SLOTS: &str = "serve.batch-rows-total";
/// Payload bytes the replicator landed on the remote store (staged
/// appends that verified and promoted; excludes discarded prefixes).
pub const CTR_REPLICA_BYTES: &str = "replica.bytes";
/// Upload resumptions: a new replication attempt found verified staged
/// bytes from an interrupted transfer and continued from that offset.
pub const CTR_REPLICA_RETRIES: &str = "replica.retries";
/// Source checkpoints that vanished (retention prune) before the
/// replicator could read them — skipped, never an error.
pub const CTR_REPLICA_SKIPPED_VANISHED: &str = "replica.skipped-vanished";

/// The catalog key a trace row is attributed to.
#[derive(Debug, Clone, Default)]
pub struct TraceKey {
    pub family: String,
    pub method: String,
    pub backend: String,
    pub shards: usize,
    pub batch: usize,
}

/// One logged span occurrence (event log only; aggregates live in the
/// per-thread histograms).
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub phase: String,
    pub thread: String,
    /// Global record order (under the hub lock, so gap-free).
    pub seq: u64,
    /// Milliseconds since the hub was created.
    pub t_ms: f64,
    pub dur_ms: f64,
}

/// One supervised-recovery occurrence (`coordinator::supervisor`),
/// always kept — recoveries are rare and load-bearing.
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// Fault site that triggered the attempt (`util::fault` site name,
    /// or `"unknown"` for non-injected failures).
    pub site: String,
    /// 1-based failed-attempt ordinal.
    pub attempt: u64,
    pub backoff_ms: u64,
    pub t_ms: f64,
}

#[derive(Default)]
struct Inner {
    /// (thread label, phase) -> samples.  Two-level key so the merge
    /// order is fixed by `BTreeMap` iteration, not thread scheduling.
    phases: BTreeMap<(String, String), Histogram>,
    counters: BTreeMap<String, u64>,
    events: Vec<SpanEvent>,
    dropped_events: u64,
    recoveries: Vec<RecoveryEvent>,
    seq: u64,
    key: TraceKey,
    /// Chosen execution plan + predicted-vs-actual accounting (auto
    /// backend runs only; `None` keeps the trace byte-identical to
    /// pre-planner output).
    plan: Option<PlanRecord>,
}

/// The shared collection point behind an [`Obs`] handle.
pub struct ObsHub {
    record_events: bool,
    t0: Instant,
    inner: Mutex<Inner>,
}

impl ObsHub {
    /// A record must never be lost to a poisoned mutex — spans are
    /// recorded inside `catch_unwind` scopes (serve workers), and a
    /// panic between lock and drop leaves only fully-written state.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn record(&self, thread: &str, phase: &str, dur: Duration) {
        // A span floors at 1ns so "the phase ran" is always
        // distinguishable from "the phase never ran" (totals > 0), even
        // under a coarse clock.
        let dur_ns = (dur.as_nanos().min(u64::MAX as u128) as u64).max(1);
        let t_ms = self.t0.elapsed().as_secs_f64() * 1e3;
        let mut g = self.lock();
        g.phases
            .entry((thread.to_string(), phase.to_string()))
            .or_default()
            .observe(dur_ns);
        if self.record_events {
            if g.events.len() < MAX_EVENTS {
                let seq = g.seq;
                g.seq += 1;
                g.events.push(SpanEvent {
                    phase: phase.to_string(),
                    thread: thread.to_string(),
                    seq,
                    t_ms,
                    dur_ms: dur_ns as f64 / 1e6,
                });
            } else {
                g.dropped_events += 1;
            }
        }
    }
}

/// Cheap cloneable telemetry handle, threaded explicitly (no process
/// globals) through trainer, backends, prefetcher, registry, writer and
/// serve — exactly like `Arc<FaultPlan>`.  [`Obs::off`] (the `Default`)
/// turns every call into a no-op.
#[derive(Clone, Default)]
pub struct Obs {
    hub: Option<Arc<ObsHub>>,
}

/// RAII span: created by [`Obs::span`], records its phase duration on
/// drop under the dropping thread's label.
pub struct SpanGuard {
    hub: Option<Arc<ObsHub>>,
    phase: &'static str,
    t0: Instant,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(hub) = self.hub.take() {
            hub.record(&current_thread_label(), self.phase, self.t0.elapsed());
        }
    }
}

fn current_thread_label() -> String {
    std::thread::current().name().unwrap_or("main").to_string()
}

impl Obs {
    /// The inert handle: every call is a no-op.
    pub fn off() -> Self {
        Obs { hub: None }
    }

    /// A live hub.  Aggregates are always collected; `record_events`
    /// additionally keeps the per-span event log for a JSONL trace.
    pub fn new(record_events: bool) -> Self {
        Obs {
            hub: Some(Arc::new(ObsHub {
                record_events,
                t0: Instant::now(),
                inner: Mutex::new(Inner::default()),
            })),
        }
    }

    /// False for [`Obs::off`] handles.
    pub fn is_on(&self) -> bool {
        self.hub.is_some()
    }

    /// Attribute everything collected so far (and after) to this
    /// catalog key — called once the backend is resolved.
    pub fn set_key(&self, key: TraceKey) {
        if let Some(h) = &self.hub {
            h.lock().key = key;
        }
    }

    /// Open a phase span; the duration records when the guard drops,
    /// under the dropping thread's label.
    pub fn span(&self, phase: &'static str) -> SpanGuard {
        SpanGuard { hub: self.hub.clone(), phase, t0: Instant::now() }
    }

    /// Record an externally-timed duration under the calling thread.
    pub fn record(&self, phase: &str, dur: Duration) {
        if let Some(h) = &self.hub {
            h.record(&current_thread_label(), phase, dur);
        }
    }

    /// Record an externally-timed duration under an explicit thread
    /// label (shard legs label themselves `shard-{i}` regardless of
    /// which scoped thread ran them).
    pub fn record_on(&self, thread: &str, phase: &str, dur: Duration) {
        if let Some(h) = &self.hub {
            h.record(thread, phase, dur);
        }
    }

    /// Bump a named monotonic counter.
    pub fn count(&self, name: &str, delta: u64) {
        if let Some(h) = &self.hub {
            let mut g = h.lock();
            *g.counters.entry(name.to_string()).or_insert(0) += delta;
        }
    }

    /// The merged cross-thread histogram for one phase (the same merge
    /// [`Obs::snapshot`] performs, but returning the raw histogram so
    /// the cost catalog can fold it in without a parallel timing path).
    /// `None` for an off handle or a phase never recorded.
    pub fn phase_histogram(&self, phase: &str) -> Option<Histogram> {
        let h = self.hub.as_ref()?;
        let g = h.lock();
        let mut merged = Histogram::new();
        // BTreeMap iteration ⇒ fixed (thread, phase) merge order.
        for ((_, p), hist) in g.phases.iter() {
            if p == phase {
                merged.merge(hist);
            }
        }
        (merged.count() > 0).then_some(merged)
    }

    /// Attach the planner's chosen plan (with predicted-vs-actual
    /// accounting) to this run's trace.
    pub fn set_plan(&self, plan: PlanRecord) {
        if let Some(h) = &self.hub {
            h.lock().plan = Some(plan);
        }
    }

    /// Record one supervised-recovery attempt as a structured event.
    pub fn recovery(&self, site: &str, attempt: u64, backoff_ms: u64) {
        if let Some(h) = &self.hub {
            let t_ms = h.t0.elapsed().as_secs_f64() * 1e3;
            h.lock().recoveries.push(RecoveryEvent {
                site: site.to_string(),
                attempt,
                backoff_ms,
                t_ms,
            });
        }
    }

    /// Merge everything collected so far into a [`RunTrace`] without
    /// clearing the hub (a supervised run snapshots after its final
    /// attempt and keeps accumulating across restarts).  `None` for an
    /// [`Obs::off`] handle.
    pub fn snapshot(&self) -> Option<RunTrace> {
        let h = self.hub.as_ref()?;
        let wall_ms = h.t0.elapsed().as_secs_f64() * 1e3;
        let g = h.lock();
        // Per-phase merge across thread labels: BTreeMap iteration is
        // sorted by (thread, phase), so the merge order — and therefore
        // the summary — is deterministic for identical recorded data.
        let mut by_phase: BTreeMap<String, Histogram> = BTreeMap::new();
        for ((_, phase), hist) in g.phases.iter() {
            by_phase.entry(phase.clone()).or_default().merge(hist);
        }
        let phases = by_phase
            .into_iter()
            .map(|(phase, h)| PhaseSummary {
                count: h.count(),
                total_ms: h.total() as f64 / 1e6,
                mean_ms: h.mean() / 1e6,
                p50_ms: h.percentile(0.50) / 1e6,
                p99_ms: h.percentile(0.99) / 1e6,
                max_ms: h.max() as f64 / 1e6,
                phase,
            })
            .collect();
        let counters = g.counters.iter().map(|(k, v)| (k.clone(), *v)).collect();
        Some(RunTrace {
            key: g.key.clone(),
            wall_ms,
            summary: ObsSummary { phases, counters },
            events: g.events.clone(),
            recoveries: g.recoveries.clone(),
            dropped_events: g.dropped_events,
            plan: g.plan.clone(),
        })
    }
}

/// Per-phase aggregate row, the catalog-facing shape.
#[derive(Debug, Clone)]
pub struct PhaseSummary {
    pub phase: String,
    pub count: u64,
    pub total_ms: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl PhaseSummary {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("total_ms", Json::num(self.total_ms)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("max_ms", Json::num(self.max_ms)),
        ])
    }
}

/// The end-of-run summary folded into `RunMetrics` (and from there into
/// run-metrics JSON and `BENCH_runtime.json`).
#[derive(Debug, Clone, Default)]
pub struct ObsSummary {
    /// Sorted by phase name.
    pub phases: Vec<PhaseSummary>,
    /// Sorted by counter name.
    pub counters: Vec<(String, u64)>,
}

impl ObsSummary {
    /// Total wall-ms spent in `phase` (0.0 when never recorded).
    pub fn phase_total_ms(&self, phase: &str) -> f64 {
        self.phases
            .iter()
            .find(|p| p.phase == phase)
            .map(|p| p.total_ms)
            .unwrap_or(0.0)
    }

    /// Final value of a named counter (0 when never bumped).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "phases",
                Json::Obj(
                    self.phases
                        .iter()
                        .map(|p| (p.phase.clone(), p.to_json()))
                        .collect(),
                ),
            ),
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Everything one run recorded, ready to serialize as `obs_trace/v1`.
#[derive(Debug, Clone)]
pub struct RunTrace {
    pub key: TraceKey,
    /// Wall milliseconds from hub creation to snapshot.
    pub wall_ms: f64,
    pub summary: ObsSummary,
    /// Per-span event log (empty unless events were recorded).
    pub events: Vec<SpanEvent>,
    pub recoveries: Vec<RecoveryEvent>,
    /// Spans past [`MAX_EVENTS`] that aggregated but were not logged.
    pub dropped_events: u64,
    /// Chosen execution plan with predicted-vs-actual accounting
    /// (planned runs only).
    pub plan: Option<PlanRecord>,
}

impl RunTrace {
    /// Serialize as `obs_trace/v1` JSONL: one `meta` line, an optional
    /// `plan` line, then `span` events in record order, `recovery`
    /// events, final `counter` values, and one `summary` line per phase.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let mut line = |j: Json| {
            out.push_str(&j.to_string());
            out.push('\n');
        };
        line(Json::obj(vec![
            ("kind", Json::str("meta")),
            ("schema", Json::str(TRACE_SCHEMA)),
            ("family", Json::str(&self.key.family)),
            ("method", Json::str(&self.key.method)),
            ("backend", Json::str(&self.key.backend)),
            ("shards", Json::num(self.key.shards as f64)),
            ("batch", Json::num(self.key.batch as f64)),
            ("wall_ms", Json::num(self.wall_ms)),
            ("dropped_events", Json::num(self.dropped_events as f64)),
        ]));
        if let Some(p) = &self.plan {
            let mut row = p.to_json().as_obj().cloned().unwrap_or_default();
            row.insert("kind".into(), Json::str("plan"));
            line(Json::Obj(row));
        }
        for e in &self.events {
            line(Json::obj(vec![
                ("kind", Json::str("span")),
                ("phase", Json::str(&e.phase)),
                ("thread", Json::str(&e.thread)),
                ("seq", Json::num(e.seq as f64)),
                ("t_ms", Json::num(e.t_ms)),
                ("dur_ms", Json::num(e.dur_ms)),
            ]));
        }
        for r in &self.recoveries {
            line(Json::obj(vec![
                ("kind", Json::str("recovery")),
                ("site", Json::str(&r.site)),
                ("attempt", Json::num(r.attempt as f64)),
                ("backoff_ms", Json::num(r.backoff_ms as f64)),
                ("t_ms", Json::num(r.t_ms)),
            ]));
        }
        for (name, value) in &self.summary.counters {
            line(Json::obj(vec![
                ("kind", Json::str("counter")),
                ("name", Json::str(name)),
                ("value", Json::num(*value as f64)),
            ]));
        }
        for p in &self.summary.phases {
            line(Json::obj(vec![
                ("kind", Json::str("summary")),
                ("phase", Json::str(&p.phase)),
                ("count", Json::num(p.count as f64)),
                ("total_ms", Json::num(p.total_ms)),
                ("mean_ms", Json::num(p.mean_ms)),
                ("p50_ms", Json::num(p.p50_ms)),
                ("p99_ms", Json::num(p.p99_ms)),
                ("max_ms", Json::num(p.max_ms)),
            ]));
        }
        out
    }

    /// Write the JSONL trace to `path`.
    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_jsonl())
            .with_context(|| format!("writing obs trace {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_handle_is_a_total_noop() {
        let obs = Obs::off();
        assert!(!obs.is_on());
        drop(obs.span(PHASE_STEP_EXEC));
        obs.record(PHASE_AUGMENT, Duration::from_millis(1));
        obs.count(CTR_PREFETCH_STALLS, 3);
        obs.recovery("engine.train_step", 1, 10);
        assert!(obs.snapshot().is_none());
    }

    #[test]
    fn spans_aggregate_across_threads_and_merge_by_phase() {
        let obs = Obs::new(false);
        drop(obs.span(PHASE_STEP_EXEC));
        obs.record_on("shard-0", PHASE_SHARD_EXEC, Duration::from_micros(100));
        obs.record_on("shard-1", PHASE_SHARD_EXEC, Duration::from_micros(300));
        obs.count(CTR_SHARD_IMBALANCE_NS, 200_000);
        let t = obs.snapshot().unwrap();
        // per-phase merge: both shard labels fold into one phase row
        let shard = t
            .summary
            .phases
            .iter()
            .find(|p| p.phase == PHASE_SHARD_EXEC)
            .expect("shard-exec row");
        assert_eq!(shard.count, 2);
        assert!(shard.total_ms >= 0.4 - 1e-9, "total {}", shard.total_ms);
        assert!(t.summary.phase_total_ms(PHASE_STEP_EXEC) > 0.0);
        assert_eq!(t.summary.counter(CTR_SHARD_IMBALANCE_NS), 200_000);
        assert_eq!(t.summary.counter("no.such.counter"), 0);
        // phases arrive sorted by name
        let names: Vec<&str> =
            t.summary.phases.iter().map(|p| p.phase.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        // events were not recorded (aggregate-only hub)
        assert!(t.events.is_empty());
        assert_eq!(t.dropped_events, 0);
    }

    #[test]
    fn event_log_records_in_order_and_caps_explicitly() {
        let obs = Obs::new(true);
        obs.record(PHASE_AUGMENT, Duration::from_micros(10));
        obs.record(PHASE_STEP_EXEC, Duration::from_micros(20));
        let t = obs.snapshot().unwrap();
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].seq, 0);
        assert_eq!(t.events[1].seq, 1);
        assert_eq!(t.events[0].phase, PHASE_AUGMENT);
        assert!(t.events[1].t_ms >= t.events[0].t_ms);

        // Cap: aggregates keep counting, drops are counted not silent.
        let obs = Obs::new(true);
        for _ in 0..(MAX_EVENTS + 5) {
            obs.record(PHASE_AUGMENT, Duration::from_nanos(50));
        }
        let t = obs.snapshot().unwrap();
        assert_eq!(t.events.len(), MAX_EVENTS);
        assert_eq!(t.dropped_events, 5);
        let aug = t.summary.phases.iter().find(|p| p.phase == PHASE_AUGMENT);
        assert_eq!(aug.unwrap().count, (MAX_EVENTS + 5) as u64);
    }

    #[test]
    fn jsonl_round_trips_through_the_parser() {
        let obs = Obs::new(true);
        obs.set_key(TraceKey {
            family: "refmlp-tiny".into(),
            method: "e2train".into(),
            backend: "sharded".into(),
            shards: 2,
            batch: 8,
        });
        obs.record(PHASE_STEP_EXEC, Duration::from_micros(250));
        obs.count(CTR_CKPT_SUBMITS, 1);
        obs.recovery("shard.engine", 1, 20);
        let trace = obs.snapshot().unwrap();
        let text = trace.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 4, "meta + span + counter + summary");
        let meta = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(meta.at(&["kind"]).as_str(), Some("meta"));
        assert_eq!(meta.at(&["schema"]).as_str(), Some(TRACE_SCHEMA));
        assert_eq!(meta.at(&["family"]).as_str(), Some("refmlp-tiny"));
        assert_eq!(meta.at(&["shards"]).as_f64(), Some(2.0));
        for l in &lines[1..] {
            let v = crate::util::json::parse(l).unwrap();
            let kind = v.at(&["kind"]).as_str().unwrap();
            assert!(
                ["span", "counter", "recovery", "summary"].contains(&kind),
                "unexpected kind {kind}"
            );
        }
        // the recovery row is structured, not a log line
        let rec = lines
            .iter()
            .map(|l| crate::util::json::parse(l).unwrap())
            .find(|v| v.at(&["kind"]).as_str() == Some("recovery"))
            .expect("recovery row");
        assert_eq!(rec.at(&["site"]).as_str(), Some("shard.engine"));
        assert_eq!(rec.at(&["attempt"]).as_f64(), Some(1.0));
        assert_eq!(rec.at(&["backoff_ms"]).as_f64(), Some(20.0));
    }
}
