//! Data-parallel sharded training with a deterministic host-side
//! all-reduce.
//!
//! [`ShardedTrainer`] splits every training batch into S contiguous
//! row slices and runs a **grad-emitting program variant**
//! (`<method>.grad.ref.json`, `runtime::reference::RefKind::Grad`) on S
//! engines drawn from an [`super::pool::EnginePool`], each shard holding
//! a resident replica of the grad-input state in a
//! [`super::device::DeviceState`].  The shard outputs are *per-sample*
//! gradient / activation / metric contributions; the host combines them
//! with a **fixed-order all-reduce** (global sample order — shard slices
//! are contiguous and ordered) and applies the optimizer update to a
//! host-side master state, then rebroadcasts the changed tensors to
//! every replica.
//!
//! ## Why per-sample contributions
//!
//! Floating-point addition is not associative, so per-shard *partial
//! sums* can never bitwise-match the single-device step's sequential
//! accumulation for every shard count and split.  Per-sample terms can:
//! the train step accumulates `acc[e] += term(bi, e)` for `bi` ascending
//! from an all-`+0.0` accumulator, and the host reduction performs the
//! exact same additions in the exact same order.  Entries the train step
//! *skips* (its `x == 0` / `hact == 0` fast paths) arrive here as
//! explicit `0.0` adds — bitwise harmless, because an accumulator that
//! starts at `+0.0` can never become `-0.0` under round-to-nearest
//! (`x + y == -0.0` requires both operands `-0.0`), and `v + 0.0 == v`
//! for every other value.
//!
//! The update itself (weight decay, PSG telemetry, momentum SGD, learned
//! gates, the running-mean state) is the one shared
//! [`crate::optim::update::apply_update`] — the same function the
//! reference train step calls — so for a fixed seed the sharded loop is
//! **bitwise identical** to the single-device resident path for any
//! shard count: the same determinism contract
//! `tests/resident_equivalence.rs` pins for resident-vs-host, extended
//! by `tests/shard_equivalence.rs` to S ∈ {1, 2, 3} and by
//! `tests/backend_matrix.rs` to the full backend matrix.
//!
//! ## Pipelined micro-batch reduce (killing the determinism tax)
//!
//! The host reduction used to run inline after the fan-out joined —
//! an O(batch × params) sequential tail on every step (PERF.md
//! "determinism tax").  It is now overlapped and parallelized without
//! touching the contract:
//!
//! * **Micro-batch pipelining** — `set_accum(A)` splits each logical
//!   batch into A contiguous micro-batches.  Shard outputs for
//!   micro-batch *k* are handed to a dedicated **reducer thread**
//!   (2-slot ring: one job queued, one being folded) while the shards
//!   run micro-batch *k+1*'s forward/grad.  Weights are constant for
//!   the whole logical step (the one `apply_update` happens after the
//!   pipeline drains), so overlap cannot observe a half-updated
//!   master.  Overlap across *logical* steps is deliberately excluded:
//!   batch k+1's forward depends on batch k's update, so cross-step
//!   overlap would compute on stale weights and break bitwise
//!   equality.  The pipeline fully drains inside [`ShardedTrainer::step`]
//!   (commit before apply), so every `StepBackend` boundary —
//!   `sync_master`, `rebroadcast`, `probe_step`,
//!   `export_for_checkpoint` — trivially sees no in-flight state.
//! * **Fixed-shape reduction tree** — each job is folded with
//!   [`super::reduce::fold_tree`]: a static binary tree over the
//!   gradient *element* axis (shape a pure function of the element
//!   count, never of timing).  Every element still accumulates its
//!   per-sample terms in ascending global sample order, so the tree is
//!   bitwise identical to the sequential fold by construction — see
//!   `runtime::reduce` for why the sample axis cannot be treed.
//! * **Gradient accumulation** — because micro-batches are reduced in
//!   send order into one accumulator and per-sample terms are already
//!   scaled by the *global* batch size, `accum` is a pure layout knob:
//!   any A produces bitwise the same step as A = 1, and
//!   [`crate::optim::update::apply_update`] runs exactly once per
//!   logical step.
//!
//! `set_overlap(false)` folds jobs inline on the caller thread (same
//! tree, no reducer thread) — the bench's overlap on/off comparison.
//!
//! Real-PJRT note: this path requires the reference backend's grad
//! programs.  On real devices the same structure maps to on-device
//! collectives (all-reduce of gradient buffers); that is the seeded
//! follow-up in ROADMAP.md — the shard/replica/rebroadcast substrate
//! here is what it will reuse.

use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::sampler::{shard_ranges, slice_batch};
use crate::obs::{self, Obs};
use crate::optim::update::{apply_update, GateIn, ParamIn, RunMeanIn, UpdateCfg};
use crate::util::fault::{self, FaultPlan, InjectedFault};

use super::device::{DeviceState, DeviceValue, ValueRef};
use super::engine::{BackendKind, Engine, Program};
use super::manifest::Manifest;
use super::pool::EnginePool;
use super::program::{ModelState, StepHyper, StepMetrics};
use super::reduce::fold_tree;
use super::tensor::HostTensor;

/// One non-gate trainable param: master-state indices of the param and
/// its momentum, plus whether weight decay applies (the reference train
/// step decays weight matrices, not biases — i.e. tensors of rank >= 2).
struct DataParam {
    idx: usize,
    mom_idx: usize,
    decay: bool,
    elems: usize,
}

/// One shard: an engine, its loaded grad program, and a resident
/// replica of the grad-program state inputs (params + persistent state,
/// in manifest order).
struct Shard {
    #[allow(dead_code)]
    engine: Engine,
    grad: Arc<Program>,
    replica: DeviceState,
}

/// The reduce shape of one logical step, handed to the reducer thread
/// at `Begin`: per-data-param element counts plus the hidden width when
/// the method tracks a running mean.  A pure function of the workload —
/// the tree built from it never depends on timing.
#[derive(Clone)]
struct ReducePlan {
    elems: Vec<usize>,
    h: Option<usize>,
}

/// The running reduction state of one logical step: gradient
/// accumulators (one per data param), sequential metric sums, and the
/// hidden-activation column sums.  Folding is defined once here and
/// shared verbatim by the reducer thread (overlap on) and the inline
/// path (overlap off), so both produce identical bits and identical
/// error messages.
struct StepAccum {
    grads: Vec<Vec<f32>>,
    loss_sum: f32,
    correct_sum: f32,
    col_sums: Option<Vec<f32>>,
}

impl StepAccum {
    fn new(plan: &ReducePlan) -> Self {
        StepAccum {
            grads: plan.elems.iter().map(|&e| vec![0f32; e]).collect(),
            loss_sum: 0.0,
            correct_sum: 0.0,
            col_sums: plan.h.map(|h| vec![0f32; h]),
        }
    }

    /// Fold one micro-batch's shard outputs in.  Per gradient element
    /// the additions happen in ascending global sample order (jobs
    /// arrive in micro-batch order, shard slices are contiguous and
    /// ordered, and [`fold_tree`] preserves per-element order), so any
    /// sequence of folds is bitwise identical to one sequential pass
    /// over the whole batch.
    fn fold(&mut self, outs: &[Vec<HostTensor>], obs: &Obs) -> Result<()> {
        let pp = self.grads.len();
        for out in outs {
            if out.len() != pp + 3 {
                bail!(
                    "grad program returned {} outputs, expected {} (per-param \
                     grads + hact + loss + correct)",
                    out.len(),
                    pp + 3
                );
            }
        }

        let t_reduce = Instant::now();
        // ---- fixed-shape tree reduce of gradient contributions -------
        let t_tree = Instant::now();
        for (pi, acc) in self.grads.iter_mut().enumerate() {
            let mut views: Vec<&[f32]> = Vec::with_capacity(outs.len());
            for out in outs {
                let v = out[pi].as_f32()?;
                let rows = out[pi].shape.first().copied().unwrap_or(0);
                if v.len() != rows * acc.len() {
                    bail!("shard grad output {pi} has the wrong size");
                }
                views.push(v);
            }
            fold_tree(acc, &views);
        }
        obs.record(obs::PHASE_REDUCE_TREE, t_tree.elapsed());
        // ---- metric reduction (same order; integer-valued `correct`
        // sums are exact, `loss` keeps the sequential order) -----------
        for out in outs {
            for &v in out[pp + 1].as_f32()? {
                self.loss_sum += v;
            }
            for &v in out[pp + 2].as_f32()? {
                self.correct_sum += v;
            }
        }
        // ---- hidden-activation column sums, global row order ---------
        // (the run_mean EMA's numerator; per column, additions happen in
        // ascending global sample order — shard slices are contiguous
        // and ordered, so this is the train step's own accumulation.)
        if let Some(cs) = &mut self.col_sums {
            let h = cs.len();
            let mut views: Vec<&[f32]> = Vec::with_capacity(outs.len());
            for out in outs {
                let ha = out[pp].as_f32()?;
                let rows = out[pp].shape.first().copied().unwrap_or(0);
                if ha.len() != rows * h {
                    bail!("shard hact output has the wrong size");
                }
                views.push(ha);
            }
            fold_tree(cs, &views);
        }
        obs.record(obs::PHASE_SHARD_REDUCE, t_reduce.elapsed());
        Ok(())
    }
}

/// Reducer-thread protocol.  `Begin` resets the thread for a new
/// logical step (also discarding any state a failed previous step left
/// behind); `Job` carries one micro-batch's shard outputs; `Commit`
/// drains the pipeline and returns the finished accumulator (or the
/// first fold error) through the reply channel.
enum Msg {
    Begin(ReducePlan),
    Job(Vec<Vec<HostTensor>>),
    Commit(mpsc::Sender<Result<StepAccum>>),
}

/// Handle to the dedicated reducer thread.  Dropping it closes the
/// channel (the thread exits at the next `recv`) and joins.
struct Reducer {
    tx: Option<SyncSender<Msg>>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for Reducer {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The reducer thread's main loop.  A fold error is parked and
/// surfaced at `Commit` — later queued jobs are skipped, never folded
/// into a poisoned accumulator.
fn reducer_main(rx: Receiver<Msg>, obs: Obs) {
    let mut accum: Option<StepAccum> = None;
    let mut pending_err: Option<anyhow::Error> = None;
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Begin(plan) => {
                pending_err = None;
                accum = Some(StepAccum::new(&plan));
            }
            Msg::Job(outs) => {
                if pending_err.is_some() {
                    continue;
                }
                match &mut accum {
                    Some(acc) => {
                        if let Err(e) = acc.fold(&outs, &obs) {
                            pending_err = Some(e);
                        }
                    }
                    None => pending_err = Some(anyhow!("reduce job before begin")),
                }
            }
            Msg::Commit(reply) => {
                let res = match pending_err.take() {
                    Some(e) => Err(e),
                    None => accum
                        .take()
                        .ok_or_else(|| anyhow!("reduce commit before begin")),
                };
                let _ = reply.send(res);
            }
        }
    }
}

/// Data-parallel sharded training step over an engine pool.
///
/// Checkpoint/resume integration (`crate::checkpoint`): durable
/// checkpoints snapshot [`ShardedTrainer::state`] — the host-side
/// master — so a sharded run checkpoints without draining or syncing
/// replicas; on resume the constructor seeds every replica from the
/// restored master (the same rebroadcast a post-update refresh does),
/// and the continuation stays bitwise identical for any shard count,
/// including a shard count different from the checkpointing run's
/// (tests/resume_equivalence.rs).
pub struct ShardedTrainer {
    shards: Vec<Shard>,
    /// Host-side authoritative state (full train-state order); SWA /
    /// publisher / checkpoint sync reads from here — "shard 0" of the
    /// design, without a device round-trip.
    master: ModelState,
    /// Master-state index of each grad-program state input, in input
    /// order (params then persistent state).
    grad_state_idx: Vec<usize>,
    data_params: Vec<DataParam>,
    /// (gate.w, mom.gate.w) master indices when gating is learned.
    gate: Option<(usize, usize)>,
    run_mean_idx: Option<usize>,
    momentum: f32,
    weight_decay: f32,
    update: String,
    backend: BackendKind,
    /// A private fork of the construction-time base engine, kept so a
    /// failed shard can be re-forked in place (sharing the same program
    /// cache) without the caller's engine handle.
    base: Engine,
    grad_path: PathBuf,
    faults: Option<Arc<FaultPlan>>,
    /// Observability handle (per-shard exec timing, reduce/apply spans,
    /// the imbalance counter).  `Obs::off()` unless the trainer attached
    /// a live hub — always inert either way (tests/obs_invariance.rs).
    obs: Obs,
    /// In-place shard recoveries performed so far (telemetry/tests).
    recoveries: u64,
    /// Micro-batches per logical step (gradient accumulation); a pure
    /// layout knob — any value is bitwise identical to 1.
    accum: usize,
    /// Pipeline micro-batch reduces onto the reducer thread (default).
    /// Off folds inline on the caller thread — same tree, same bits.
    overlap: bool,
    /// Lazily-spawned dedicated reducer thread (overlap on only).
    reducer: Option<Reducer>,
}

/// In-step failure budget: a step tolerates this many shard/fork
/// failures (each answered by an in-place re-fork) before giving up and
/// surfacing the error to the supervisor's checkpoint-restore path.
const MAX_STEP_FAILURES: u32 = 3;

impl ShardedTrainer {
    /// Build `shards` engines (forked from `base`, sharing its compiled
    /// program cache) around `init`, loading the manifest's grad
    /// program on each.
    pub fn new(
        base: &Engine,
        manifest_path: &Path,
        shards: usize,
        init: ModelState,
    ) -> Result<Self> {
        let manifest = Manifest::load(manifest_path)?;
        if manifest.method.gating == "mask" {
            bail!(
                "sharded training does not support mask-gated (stochastic \
                 depth) methods"
            );
        }
        let grad_path = Manifest::grad_program_path(manifest_path);
        if !grad_path.exists() {
            bail!(
                "{} has no grad-emitting program ({}): sharded training \
                 currently requires a reference family — the real-PJRT \
                 collective all-reduce is the seeded follow-up in ROADMAP.md",
                manifest_path.display(),
                grad_path.display()
            );
        }

        let mut grad_state_idx = Vec::new();
        let mut data_params = Vec::new();
        let mut gate = None;
        let mut run_mean_idx = None;
        for spec in &manifest.train_inputs {
            if !matches!(spec.role.as_str(), "param" | "state") {
                continue;
            }
            let idx = init
                .index_of(&spec.name)
                .ok_or_else(|| anyhow!("state tensor {} missing from init", spec.name))?;
            grad_state_idx.push(idx);
            if spec.role == "param" {
                let mom_idx = init
                    .index_of(&format!("mom.{}", spec.name))
                    .ok_or_else(|| anyhow!("param {} has no momentum slot", spec.name))?;
                if spec.name.starts_with("gate.") {
                    gate = Some((idx, mom_idx));
                } else {
                    data_params.push(DataParam {
                        idx,
                        mom_idx,
                        decay: init.values[idx].shape.len() >= 2,
                        elems: init.values[idx].elem_count(),
                    });
                }
            } else if spec.name == "run_mean" {
                run_mean_idx = Some(idx);
            } else {
                bail!(
                    "sharded training does not understand persistent state '{}'",
                    spec.name
                );
            }
        }
        if manifest.method.gating == "learned" && gate.is_none() {
            bail!("learned gating but no gate.* param in the state");
        }
        if manifest.method.gating != "learned" {
            gate = None;
        }

        // Reference grad programs are backend-portable, so forked
        // engines share the base cache and the artifact compiles once
        // no matter how many shards load it.
        let pool = EnginePool::from_base(base, shards.max(1))?;
        let mut slots = Vec::new();
        let mut backend = BackendKind::Reference;
        for engine in pool.into_engines() {
            let grad = engine.load(&grad_path)?;
            backend = grad.backend();
            let replica = Self::replica(&init, &grad_state_idx, backend)?;
            slots.push(Shard { engine, grad, replica });
        }

        Ok(Self {
            shards: slots,
            master: init,
            grad_state_idx,
            data_params,
            gate,
            run_mean_idx,
            momentum: manifest.method.momentum as f32,
            weight_decay: manifest.method.weight_decay as f32,
            update: manifest.method.update.clone(),
            backend,
            base: base.fork()?,
            grad_path,
            faults: None,
            obs: Obs::off(),
            recoveries: 0,
            accum: 1,
            overlap: true,
            reducer: None,
        })
    }

    /// Arm fault-injection sites on the shard fan-out (`shard.engine`)
    /// and the recovery fork (`pool.fork`).
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// Attach an observability handle (forwarded by
    /// [`super::exec::ShardedBackend::set_obs`]).  Any running reducer
    /// thread is dropped so the next step respawns it with the new
    /// handle.
    pub fn set_obs(&mut self, obs: Obs) {
        self.obs = obs;
        self.reducer = None;
    }

    /// Micro-batches per logical step (gradient accumulation, clamped
    /// to >= 1).  Bitwise identical to 1 for any value — pinned by
    /// `tests/reduce_matrix.rs` and a proptest — so this is purely a
    /// memory/pipelining layout knob.
    pub fn set_accum(&mut self, accum: usize) {
        self.accum = accum.max(1);
    }

    pub fn accum(&self) -> usize {
        self.accum
    }

    /// Toggle the reducer-thread pipeline (on by default).  Off reduces
    /// inline after each fan-out — the overlap-off baseline the shard
    /// bench compares against.
    pub fn set_overlap(&mut self, on: bool) {
        self.overlap = on;
        if !on {
            self.reducer = None;
        }
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// In-place shard recoveries performed so far.
    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    fn replica(
        master: &ModelState,
        idx: &[usize],
        backend: BackendKind,
    ) -> Result<DeviceState> {
        let values: Vec<HostTensor> =
            idx.iter().map(|&i| master.values[i].clone()).collect();
        let names: Vec<String> =
            idx.iter().map(|&i| master.names[i].clone()).collect();
        DeviceState::upload(backend, ModelState::new(values, names))
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// The authoritative host-side state (SWA snapshots, publishing,
    /// eval, checkpoints read from here).
    pub fn state(&self) -> &ModelState {
        &self.master
    }

    /// Consume into the final host state (end of run).
    pub fn into_state(self) -> ModelState {
        self.master
    }

    /// One data-parallel optimizer step: split the batch into `accum`
    /// micro-batches, fan each out over the shards, pipeline the
    /// fixed-order reduce onto the reducer thread (overlap on) or fold
    /// inline (overlap off), then apply the one optimizer update and
    /// rebroadcast.  The pipeline fully drains before the apply, so
    /// callers never observe in-flight state.
    ///
    /// A shard that fails mid-fan-out is recovered **in place**: its
    /// engine is re-forked from the construction-time base, the grad
    /// program reloaded, and the replica rebuilt from the host master —
    /// then the failed micro-batch retries.  This is bitwise invisible
    /// because a failed fan-out's outputs are never sent to the reducer
    /// (no stale slot to invalidate), earlier micro-batches already
    /// queued stay valid (the master is constant until [`Self::apply`]),
    /// and a rebuilt replica carries exactly the master tensors a
    /// rebroadcast would have pushed.
    pub fn step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
    ) -> Result<StepMetrics> {
        let b = x.shape.first().copied().unwrap_or(0);
        if b == 0 {
            bail!("empty batch");
        }
        let n_scalar = HostTensor::scalar_f32(b as f32);
        let plan = ReducePlan {
            elems: self.data_params.iter().map(|p| p.elems).collect(),
            h: self
                .run_mean_idx
                .map(|ri| self.master.values[ri].elem_count()),
        };
        // Contiguous ascending micro-batches: concatenating their shard
        // slices in send order replays the whole batch in global sample
        // order, so any accum value folds bitwise like accum = 1.
        let micro = shard_ranges(b, self.accum);
        let mut failures = 0u32;

        let acc = if self.overlap {
            let tx = self.ensure_reducer()?;
            let dead = || anyhow!("reducer thread died");
            tx.send(Msg::Begin(plan)).map_err(|_| dead())?;
            for r in &micro {
                let outs =
                    self.run_micro_batch(x, y, r.clone(), &n_scalar, &mut failures)?;
                // Backpressure: blocks only while the 2-slot ring is
                // full, i.e. the reducer is still folding micro-batch
                // k-1 — the stall the overlap is supposed to hide.
                let t0 = Instant::now();
                tx.send(Msg::Job(outs)).map_err(|_| dead())?;
                self.obs.record(obs::PHASE_PIPELINE_STALL, t0.elapsed());
            }
            // Drain: the apply below must see the finished accumulator.
            let (rtx, rrx) = mpsc::channel();
            let t0 = Instant::now();
            tx.send(Msg::Commit(rtx)).map_err(|_| dead())?;
            let acc = rrx.recv().map_err(|_| dead())??;
            self.obs.record(obs::PHASE_PIPELINE_STALL, t0.elapsed());
            acc
        } else {
            let mut acc = StepAccum::new(&plan);
            for r in &micro {
                let outs =
                    self.run_micro_batch(x, y, r.clone(), &n_scalar, &mut failures)?;
                acc.fold(&outs, &self.obs)?;
            }
            acc
        };
        self.apply(b, acc, hp)
    }

    /// Spawn (once) the dedicated reducer thread and hand back a cloned
    /// sender into its 2-slot ring.
    fn ensure_reducer(&mut self) -> Result<SyncSender<Msg>> {
        if self.reducer.is_none() {
            let (tx, rx) = mpsc::sync_channel::<Msg>(1);
            let obs = self.obs.clone();
            let handle = std::thread::Builder::new()
                .name("e2train-reducer".into())
                .spawn(move || reducer_main(rx, obs))
                .context("spawning the reducer thread")?;
            self.reducer = Some(Reducer { tx: Some(tx), handle: Some(handle) });
        }
        Ok(self.reducer.as_ref().unwrap().tx.as_ref().unwrap().clone())
    }

    /// Slice one micro-batch's rows across the shards and fan out,
    /// recovering failed shards in place within the step's shared
    /// failure budget.
    fn run_micro_batch(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        range: Range<usize>,
        n_scalar: &HostTensor,
        failures: &mut u32,
    ) -> Result<Vec<Vec<HostTensor>>> {
        let slices = shard_ranges(range.len(), self.shards.len())
            .into_iter()
            .map(|r| slice_batch(x, y, range.start + r.start..range.start + r.end))
            .collect::<Result<Vec<_>>>()?;
        loop {
            let (i, e) = match self.fan_out(&slices, n_scalar) {
                Ok(outs) => return Ok(outs),
                Err(at) => at,
            };
            *failures += 1;
            if *failures > MAX_STEP_FAILURES {
                return Err(e.context(format!(
                    "shard {i} still failing after {} in-place recoveries",
                    *failures - 1
                )));
            }
            eprintln!(
                "[shard] shard {i} failed ({e:#}); re-forking its engine and \
                 retrying the micro-batch"
            );
            loop {
                match self.recover_shard(i) {
                    Ok(()) => break,
                    Err(re) => {
                        *failures += 1;
                        if *failures > MAX_STEP_FAILURES {
                            return Err(re.context(format!(
                                "recovering shard {i} after a fan-out failure"
                            )));
                        }
                        eprintln!(
                            "[shard] recovering shard {i} failed ({re:#}); \
                             retrying the fork"
                        );
                    }
                }
            }
            self.recoveries += 1;
        }
    }

    /// Fan the slices out over the shards; on failure, report *which*
    /// shard died so [`Self::recover_shard`] can rebuild exactly it.
    fn fan_out(
        &self,
        slices: &[(HostTensor, HostTensor)],
        n_scalar: &HostTensor,
    ) -> std::result::Result<Vec<Vec<HostTensor>>, (usize, anyhow::Error)> {
        // The `shard.engine` site kills one fan-out leg: the victim is
        // picked by the shot's firing sequence, so repeated injections
        // walk the shards deterministically.
        let victim = self
            .faults
            .as_ref()
            .and_then(|p| p.hit(fault::SITE_SHARD_ENGINE))
            .map(|shot| (shot.seq as usize) % slices.len().max(1));
        let inject = |i: usize| -> Result<()> {
            if victim == Some(i) {
                return Err(anyhow::Error::new(InjectedFault::new(
                    fault::SITE_SHARD_ENGINE,
                )));
            }
            Ok(())
        };

        let mut results: Vec<Option<(Result<Vec<HostTensor>>, Duration)>> =
            slices.iter().map(|_| None).collect();
        if slices.len() == 1 {
            let t0 = Instant::now();
            let r = inject(0).and_then(|()| {
                run_shard(&self.shards[0], &slices[0].0, &slices[0].1, n_scalar)
            });
            results[0] = Some((r, t0.elapsed()));
        } else {
            std::thread::scope(|scope| {
                for (i, ((shard, (xs, ys)), slot)) in self
                    .shards
                    .iter()
                    .zip(slices.iter())
                    .zip(results.iter_mut())
                    .enumerate()
                {
                    let inject = &inject;
                    scope.spawn(move || {
                        let t0 = Instant::now();
                        let r =
                            inject(i).and_then(|()| run_shard(shard, xs, ys, n_scalar));
                        *slot = Some((r, t0.elapsed()));
                    });
                }
            });
        }
        let n = results.len();
        let mut outs = Vec::with_capacity(n);
        let (mut min_dur, mut max_dur) = (Duration::MAX, Duration::ZERO);
        for (i, r) in results.into_iter().enumerate() {
            let (res, dur) = r.unwrap_or_else(|| {
                (Err(anyhow!("shard worker never ran")), Duration::ZERO)
            });
            self.obs
                .record_on(&format!("shard-{i}"), obs::PHASE_SHARD_EXEC, dur);
            min_dur = min_dur.min(dur);
            max_dur = max_dur.max(dur);
            match res {
                Ok(o) => outs.push(o),
                Err(e) => return Err((i, e)),
            }
        }
        if n > 1 {
            // Straggler gap this step: slowest minus fastest shard leg.
            // Floored at 1ns (like span records) so the counter also
            // proves the multi-shard fan-out path ran at all.
            self.obs.count(
                obs::CTR_SHARD_IMBALANCE_NS,
                (max_dur.saturating_sub(min_dur).as_nanos() as u64).max(1),
            );
        }
        Ok(outs)
    }

    /// Rebuild shard `i` from scratch: re-fork its engine from the base
    /// (through the injectable [`EnginePool::fork_one`]), reload the
    /// grad program, and seed a fresh replica from the host master.
    fn recover_shard(&mut self, i: usize) -> Result<()> {
        let engine = EnginePool::fork_one(&self.base, self.faults.as_deref())
            .context("re-forking a replacement shard engine")?;
        let grad = engine.load(&self.grad_path)?;
        let replica = Self::replica(&self.master, &self.grad_state_idx, grad.backend())?;
        self.shards[i] = Shard { engine, grad, replica };
        Ok(())
    }

    /// Time one sharded step without perturbing the run: the master
    /// state is restored and replicas rebroadcast afterwards, so the
    /// probe is invisible to metrics and determinism (the prefetch
    /// depth auto-tuner's denominator).
    pub fn probe_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
    ) -> Result<f64> {
        let saved = self.master.clone();
        let t0 = Instant::now();
        self.step(x, y, hp)?;
        let dt = t0.elapsed().as_secs_f64();
        self.master = saved;
        self.rebroadcast()?;
        Ok(dt)
    }

    /// Hand one drained [`StepAccum`] (the fixed-order all-reduce of
    /// every micro-batch, global sample order) to the one shared
    /// [`apply_update`] — no update math lives here.
    fn apply(&mut self, b: usize, acc: StepAccum, hp: StepHyper) -> Result<StepMetrics> {
        let StepAccum { grads, loss_sum, correct_sum, col_sums } = acc;
        let t_apply = Instant::now();
        // ---- the one shared optimizer update -------------------------
        let ucfg = UpdateCfg {
            lr: hp.lr,
            alpha: hp.alpha,
            beta: hp.beta,
            momentum: self.momentum,
            weight_decay: self.weight_decay,
            psg: self.update == "psg",
            batch: b as f32,
        };
        let out = {
            let params = self
                .data_params
                .iter()
                .zip(grads)
                .map(|(p, g)| {
                    Ok(ParamIn {
                        w: self.master.values[p.idx].as_f32()?,
                        mom: self.master.values[p.mom_idx].as_f32()?,
                        grad: g,
                        decay: p.decay,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let gate = match self.gate {
                Some((gi, gmi)) => Some(GateIn {
                    w: self.master.values[gi].as_f32()?,
                    mom: self.master.values[gmi].as_f32()?,
                }),
                None => None,
            };
            let run_mean = match (self.run_mean_idx, col_sums) {
                (Some(ri), Some(cs)) => Some(RunMeanIn {
                    current: self.master.values[ri].as_f32()?,
                    col_sums: cs,
                }),
                _ => None,
            };
            apply_update(&ucfg, params, gate, run_mean)
        };

        // ---- write the update back into the master state -------------
        for (p, (nw, nm)) in self.data_params.iter().zip(out.params) {
            self.master.values[p.idx].as_f32_mut()?.copy_from_slice(&nw);
            self.master.values[p.mom_idx]
                .as_f32_mut()?
                .copy_from_slice(&nm);
        }
        let mut gate_fracs: Vec<f64> = Vec::new();
        if let (Some((gi, gmi)), Some(g)) = (self.gate, out.gate) {
            self.master.values[gi].as_f32_mut()?.copy_from_slice(&g.w);
            self.master.values[gmi].as_f32_mut()?.copy_from_slice(&g.mom);
            gate_fracs = g.fracs.iter().map(|&v| v as f64).collect();
        }
        if let (Some(ri), Some(nm)) = (self.run_mean_idx, out.run_mean) {
            self.master.values[ri].as_f32_mut()?.copy_from_slice(&nm);
        }

        self.rebroadcast()?;
        self.obs.record(obs::PHASE_OPTIM_APPLY, t_apply.elapsed());

        Ok(StepMetrics {
            loss: (loss_sum / b as f32) as f64,
            correct: correct_sum as f64,
            gate_fracs,
            psg_frac: out.psg_frac.map(|v| v as f64),
        })
    }

    /// Refresh every replica's grad-input tensors from the master state
    /// (params + persistent state; momenta never leave the host).
    /// Public because [`super::exec::ShardedBackend`] exposes it through
    /// the `StepBackend` trait; the on-device-collective follow-up
    /// (ROADMAP) replaces its body without touching callers.
    pub fn rebroadcast(&mut self) -> Result<()> {
        for shard in &mut self.shards {
            for (ri, &mi) in self.grad_state_idx.iter().enumerate() {
                shard
                    .replica
                    .refresh_from_host(ri, self.master.values[mi].clone())?;
            }
        }
        Ok(())
    }
}

/// Execute one shard's grad program: resident replica state + the
/// shard's (x, y) slice + the global batch size scalar.
fn run_shard(
    shard: &Shard,
    xs: &HostTensor,
    ys: &HostTensor,
    n: &HostTensor,
) -> Result<Vec<HostTensor>> {
    let mut inputs: Vec<ValueRef> =
        Vec::with_capacity(shard.replica.values.len() + 3);
    for v in &shard.replica.values {
        inputs.push(ValueRef::Dev(v));
    }
    inputs.push(ValueRef::Host(xs));
    inputs.push(ValueRef::Host(ys));
    inputs.push(ValueRef::Host(n));
    shard
        .grad
        .execute_refs(&inputs)?
        .into_iter()
        .map(DeviceValue::into_host)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, AugmentCfg, Sampler};
    use crate::runtime::{write_reference_family, RefFamilySpec, TrainProgram};
    use crate::util::tmp::TempDir;

    /// The core bitwise contract at step granularity: S shards == the
    /// single-device resident step, metrics and state, including a
    /// non-divisible 8-row/3-shard split.
    #[test]
    fn sharded_step_is_bitwise_identical_to_step_device() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        for method in ["sgd32", "e2train"] {
            let manifest = fam.join(format!("{method}.json"));
            let prog = TrainProgram::load(&engine, &manifest).unwrap();
            let data = synthetic::generate(10, 64, 8, 1);
            let hp = StepHyper { lr: 0.03, alpha: 1.5, beta: 0.05 };
            let init = ModelState::init(&prog.manifest, 9);
            for shards in [1usize, 2, 3] {
                let mut sampler =
                    Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
                let mut dev = prog.upload_state(init.clone()).unwrap();
                let mut sharded =
                    ShardedTrainer::new(&engine, &manifest, shards, init.clone())
                        .unwrap();
                assert_eq!(sharded.num_shards(), shards);
                for step in 0..5 {
                    let (x, y) = sampler.next_batch(&data);
                    let a = prog.step_device(&mut dev, &x, &y, hp, None).unwrap();
                    let b = sharded.step(&x, &y, hp).unwrap();
                    assert_eq!(a.loss, b.loss, "{method} S={shards} step {step}");
                    assert_eq!(a.correct, b.correct, "{method} S={shards}");
                    assert_eq!(a.gate_fracs, b.gate_fracs, "{method} S={shards}");
                    assert_eq!(a.psg_frac, b.psg_frac, "{method} S={shards}");
                }
                let single = dev.into_host().unwrap();
                single.assert_bitwise_eq(sharded.state());
            }
        }
    }

    /// A probe step must leave the trainer exactly where it was: the
    /// next real step matches a run that never probed.
    #[test]
    fn probe_step_is_invisible() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("sgd32.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let data = synthetic::generate(10, 32, 8, 2);
        let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 3);
        let (x, y) = sampler.next_batch(&data);
        let hp = StepHyper::lr(0.05);
        let init = ModelState::init(&prog.manifest, 1);

        let mut plain =
            ShardedTrainer::new(&engine, &manifest, 2, init.clone()).unwrap();
        let mut probed = ShardedTrainer::new(&engine, &manifest, 2, init).unwrap();
        let dt = probed.probe_step(&x, &y, hp).unwrap();
        assert!(dt > 0.0);
        plain.state().assert_bitwise_eq(probed.state());

        let a = plain.step(&x, &y, hp).unwrap();
        let b = probed.step(&x, &y, hp).unwrap();
        assert_eq!(a.loss, b.loss);
        plain.state().assert_bitwise_eq(probed.state());
    }

    /// More shards than batch rows: only the non-empty slices execute,
    /// and the result is still bitwise identical.
    #[test]
    fn more_shards_than_rows_still_bitwise() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("sgd32.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let data = synthetic::generate(10, 32, 8, 0);
        let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 7);
        let (x, y) = sampler.next_batch(&data);
        let hp = StepHyper::lr(0.1);
        let init = ModelState::init(&prog.manifest, 2);

        let mut dev = prog.upload_state(init.clone()).unwrap();
        let mut sharded =
            ShardedTrainer::new(&engine, &manifest, 16, init).unwrap();
        let a = prog.step_device(&mut dev, &x, &y, hp, None).unwrap();
        let b = sharded.step(&x, &y, hp).unwrap();
        assert_eq!(a.loss, b.loss);
        dev.into_host().unwrap().assert_bitwise_eq(sharded.state());
    }

    /// In-place shard recovery is bitwise invisible: a run whose shard
    /// engines are killed (and whose first recovery fork also fails)
    /// ends identical to a run that never faulted.
    #[test]
    fn shard_failure_recovers_in_place_bitwise() {
        use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};

        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("e2train.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let data = synthetic::generate(10, 64, 8, 4);
        let hp = StepHyper { lr: 0.03, alpha: 1.5, beta: 0.05 };
        let init = ModelState::init(&prog.manifest, 9);

        let site = |s: &str, at: u64| FaultSiteCfg {
            site: s.into(),
            at,
            times: 1,
            after_bytes: None,
        };
        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![
                    site(fault::SITE_SHARD_ENGINE, 2),
                    site(fault::SITE_POOL_FORK, 1),
                ],
                ..Default::default()
            },
            9,
        )
        .unwrap();

        let mut plain =
            ShardedTrainer::new(&engine, &manifest, 3, init.clone()).unwrap();
        let mut faulted =
            ShardedTrainer::new(&engine, &manifest, 3, init).unwrap();
        faulted.set_faults(plan.clone());

        let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
        let mut sampler2 = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
        for step in 0..5 {
            let (x, y) = sampler.next_batch(&data);
            let (x2, y2) = sampler2.next_batch(&data);
            let a = plain.step(&x, &y, hp).unwrap();
            let b = faulted.step(&x2, &y2, hp).unwrap();
            assert_eq!(a.loss, b.loss, "step {step}");
            assert_eq!(a.correct, b.correct, "step {step}");
        }
        plain.state().assert_bitwise_eq(faulted.state());
        assert_eq!(plan.fired(fault::SITE_SHARD_ENGINE), 1, "shard fault never fired");
        assert_eq!(plan.fired(fault::SITE_POOL_FORK), 1, "fork fault never fired");
        assert_eq!(faulted.recoveries(), 1);
        assert_eq!(plain.recoveries(), 0);
    }

    /// A shard fault that keeps firing past the in-step budget surfaces
    /// a clean typed error instead of hanging or panicking.
    #[test]
    fn unrecoverable_shard_failure_fails_fast() {
        use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};

        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("sgd32.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let data = synthetic::generate(10, 32, 8, 2);
        let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 3);
        let (x, y) = sampler.next_batch(&data);
        let init = ModelState::init(&prog.manifest, 1);

        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_SHARD_ENGINE.into(),
                    at: 1,
                    times: 100,
                    after_bytes: None,
                }],
                ..Default::default()
            },
            0,
        )
        .unwrap();
        let mut t = ShardedTrainer::new(&engine, &manifest, 2, init).unwrap();
        t.set_faults(plan);
        let err = t.step(&x, &y, StepHyper::lr(0.05)).unwrap_err();
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");
        assert!(format!("{err:#}").contains("in-place recoveries"));
    }

    /// Gradient accumulation is a pure layout knob: any accum value
    /// (including accum > batch) stays bitwise identical to the
    /// single-device step, metrics and state.
    #[test]
    fn accum_is_bitwise_identical_to_single_pass() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        for method in ["sgd32", "e2train"] {
            let manifest = fam.join(format!("{method}.json"));
            let prog = TrainProgram::load(&engine, &manifest).unwrap();
            let data = synthetic::generate(10, 64, 8, 1);
            let hp = StepHyper { lr: 0.03, alpha: 1.5, beta: 0.05 };
            let init = ModelState::init(&prog.manifest, 9);
            for accum in [2usize, 3, 16] {
                let mut sampler =
                    Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
                let mut dev = prog.upload_state(init.clone()).unwrap();
                let mut sharded =
                    ShardedTrainer::new(&engine, &manifest, 2, init.clone())
                        .unwrap();
                sharded.set_accum(accum);
                assert_eq!(sharded.accum(), accum);
                for step in 0..4 {
                    let (x, y) = sampler.next_batch(&data);
                    let a = prog.step_device(&mut dev, &x, &y, hp, None).unwrap();
                    let b = sharded.step(&x, &y, hp).unwrap();
                    assert_eq!(a.loss, b.loss, "{method} A={accum} step {step}");
                    assert_eq!(a.correct, b.correct, "{method} A={accum}");
                    assert_eq!(a.gate_fracs, b.gate_fracs, "{method} A={accum}");
                    assert_eq!(a.psg_frac, b.psg_frac, "{method} A={accum}");
                }
                let single = dev.into_host().unwrap();
                single.assert_bitwise_eq(sharded.state());
            }
        }
    }

    /// The reducer-thread pipeline is bitwise invisible: overlap off
    /// (inline fold) and overlap on (default) agree step by step.
    #[test]
    fn overlap_off_matches_overlap_on_bitwise() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("e2train.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let data = synthetic::generate(10, 64, 8, 3);
        let hp = StepHyper { lr: 0.03, alpha: 1.5, beta: 0.05 };
        let init = ModelState::init(&prog.manifest, 4);

        let mut piped =
            ShardedTrainer::new(&engine, &manifest, 3, init.clone()).unwrap();
        let mut inline = ShardedTrainer::new(&engine, &manifest, 3, init).unwrap();
        assert!(piped.overlap(), "pipelining must be the default");
        inline.set_overlap(false);
        piped.set_accum(2);
        inline.set_accum(2);

        let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
        let mut sampler2 = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
        for step in 0..4 {
            let (x, y) = sampler.next_batch(&data);
            let (x2, y2) = sampler2.next_batch(&data);
            let a = piped.step(&x, &y, hp).unwrap();
            let b = inline.step(&x2, &y2, hp).unwrap();
            assert_eq!(a.loss, b.loss, "step {step}");
            assert_eq!(a.correct, b.correct, "step {step}");
        }
        piped.state().assert_bitwise_eq(inline.state());
    }

    /// A shard death mid-pipeline (accum > 1, overlap on) recovers in
    /// place bitwise: the failed micro-batch's outputs never reach the
    /// reducer, earlier queued micro-batches stay valid, and only the
    /// failed micro-batch retries.
    #[test]
    fn shard_failure_mid_pipeline_recovers_bitwise() {
        use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};

        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("e2train.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let data = synthetic::generate(10, 64, 8, 4);
        let hp = StepHyper { lr: 0.03, alpha: 1.5, beta: 0.05 };
        let init = ModelState::init(&prog.manifest, 9);

        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_SHARD_ENGINE.into(),
                    at: 3,
                    times: 1,
                    after_bytes: None,
                }],
                ..Default::default()
            },
            9,
        )
        .unwrap();

        let mut plain =
            ShardedTrainer::new(&engine, &manifest, 2, init.clone()).unwrap();
        let mut faulted = ShardedTrainer::new(&engine, &manifest, 2, init).unwrap();
        plain.set_accum(2);
        faulted.set_accum(2);
        faulted.set_faults(plan.clone());

        let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
        let mut sampler2 = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
        for step in 0..5 {
            let (x, y) = sampler.next_batch(&data);
            let (x2, y2) = sampler2.next_batch(&data);
            let a = plain.step(&x, &y, hp).unwrap();
            let b = faulted.step(&x2, &y2, hp).unwrap();
            assert_eq!(a.loss, b.loss, "step {step}");
            assert_eq!(a.correct, b.correct, "step {step}");
        }
        plain.state().assert_bitwise_eq(faulted.state());
        assert_eq!(plan.fired(fault::SITE_SHARD_ENGINE), 1, "fault never fired");
        assert_eq!(faulted.recoveries(), 1);
    }

    /// A manifest without a grad program (every PJRT family today) must
    /// fail fast with a message naming the missing piece.
    #[test]
    fn missing_grad_program_is_rejected() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        std::fs::remove_file(fam.join("sgd32.grad.ref.json")).unwrap();
        let engine = Engine::cpu().unwrap();
        let prog = TrainProgram::load(&engine, &fam.join("sgd32.json")).unwrap();
        let init = ModelState::init(&prog.manifest, 0);
        let err = ShardedTrainer::new(&engine, &fam.join("sgd32.json"), 2, init)
            .unwrap_err();
        assert!(format!("{err:#}").contains("grad-emitting"));
    }
}
