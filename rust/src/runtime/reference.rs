//! Reference backend: a pure-rust train/eval program that speaks the
//! exact artifact contract (manifest-ordered inputs -> state outputs +
//! metrics) without needing a PJRT runtime.
//!
//! Motivation: the coordinator, the resident-state path, the prefetch
//! pipeline and the experiment fan-out are all *orchestration* — none of
//! them care what the executable computes, only that it is deterministic
//! and honors the I/O contract.  The reference program (a two-layer MLP
//! with momentum SGD, optional learned gates and PSG telemetry) makes
//! every orchestration path executable and benchmarkable on machines
//! where the real `xla` crate / AOT artifacts are unavailable, and it is
//! the ground truth for the host-path vs resident-path equivalence tests.
//!
//! A reference artifact family is a directory of `<method>.json`
//! manifests (the same schema aot.py emits) whose programs are
//! `<method>.train.ref.json` / `<method>.eval.ref.json` files instead of
//! HLO text; [`write_reference_family`] generates one.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::optim::update::{apply_update, GateIn, ParamIn, RunMeanIn, UpdateCfg};
use crate::util::json::{parse, Json};

use super::tensor::{HostTensor, TensorData};

/// One input/output slot of a reference program (manifest IoSpec shape).
#[derive(Debug, Clone)]
pub struct RefIo {
    pub name: String,
    pub role: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefKind {
    Train,
    Eval,
    /// Gradient-emitting variant of the train step for the sharded
    /// data-parallel path (`runtime::shard`): computes **per-sample**
    /// gradient / activation / metric contributions for a slice of the
    /// batch, without applying any update.  Each per-sample row is
    /// bitwise the term the full-batch train step accumulates for the
    /// same sample (the softmax rows are normalized by the *global*
    /// batch size, passed as the scalar input `n`), so a host-side
    /// reduction in global sample order reproduces the single-device
    /// step exactly.
    Grad,
}

/// A loaded reference program: interpretable train or eval step.
#[derive(Debug, Clone)]
pub struct RefProgram {
    pub kind: RefKind,
    pub inputs: Vec<RefIo>,
    pub outputs: Vec<RefIo>,
    gating: String,
    update: String,
    momentum: f32,
    weight_decay: f32,
}

impl RefProgram {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading reference program {}", path.display()))?;
        Self::from_text(&text)
            .with_context(|| format!("parsing reference program {}", path.display()))
    }

    pub fn from_text(text: &str) -> Result<Self> {
        let v = parse(text)?;
        let kind = match v.req_str("kind")? {
            "train" => RefKind::Train,
            "eval" => RefKind::Eval,
            "grad" => RefKind::Grad,
            other => bail!("unknown reference program kind {other}"),
        };
        let ios = |key: &str| -> Result<Vec<RefIo>> {
            v.req_arr(key)?
                .iter()
                .map(|io| {
                    Ok(RefIo {
                        name: io.req_str("name")?.to_string(),
                        role: io.req_str("role")?.to_string(),
                        shape: io
                            .req_arr("shape")?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                        dtype: io.req_str("dtype")?.to_string(),
                    })
                })
                .collect()
        };
        Ok(Self {
            kind,
            inputs: ios("inputs")?,
            outputs: ios("outputs")?,
            gating: v.req_str("gating")?.to_string(),
            update: v.req_str("update")?.to_string(),
            momentum: v.req_f64("momentum")? as f32,
            weight_decay: v.req_f64("weight_decay")? as f32,
        })
    }

    fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|io| io.name == name)
            .ok_or_else(|| anyhow!("reference program has no input '{name}'"))
    }

    /// Interpret the program on positional inputs (manifest order).
    /// Pure, deterministic, fixed summation order — identical inputs give
    /// bitwise-identical outputs, which the host/resident equivalence
    /// tests rely on.
    pub fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "reference program expects {} inputs, got {}",
                self.inputs.len(),
                inputs.len()
            );
        }
        match self.kind {
            RefKind::Train => self.run_train(inputs),
            RefKind::Eval => self.run_eval(inputs),
            RefKind::Grad => self.run_grad(inputs),
        }
    }

    fn f32_in<'a>(&self, inputs: &[&'a HostTensor], name: &str) -> Result<&'a HostTensor> {
        Ok(inputs[self.input_index(name)?])
    }

    fn scalar_in(&self, inputs: &[&HostTensor], name: &str) -> Result<f32> {
        let t = self.f32_in(inputs, name)?;
        Ok(t.as_f32()?[0])
    }

    fn run_train(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let w1t = self.f32_in(inputs, "w1")?;
        let b1t = self.f32_in(inputs, "b1")?;
        let w2t = self.f32_in(inputs, "w2")?;
        let b2t = self.f32_in(inputs, "b2")?;
        let (d, h) = (w1t.shape[0], w1t.shape[1]);
        let c = w2t.shape[1];
        let (w1, b1, w2, b2) =
            (w1t.as_f32()?, b1t.as_f32()?, w2t.as_f32()?, b2t.as_f32()?);

        let xt = self.f32_in(inputs, "x")?;
        let bsz = xt.shape[0];
        let xv = xt.as_f32()?;
        if xv.len() != bsz * d {
            bail!("x has {} elems, expected {}x{}", xv.len(), bsz, d);
        }
        let yt = inputs[self.input_index("y")?];
        let yv = match &yt.data {
            TensorData::I32(v) => v,
            _ => bail!("y must be i32"),
        };
        let lr = self.scalar_in(inputs, "lr")?;
        let mu = self.momentum;
        let wd = self.weight_decay;

        let fwd = forward(xv, w1, b1, w2, b2, bsz, d, h, c);

        // ---- loss + train metrics ------------------------------------
        let (loss_sum, correct, _correct5) = softmax_metrics(&fwd.z, yv, bsz, c);
        let loss = loss_sum / bsz as f32;

        // ---- backward -------------------------------------------------
        let mut dz = vec![0f32; bsz * c];
        for bi in 0..bsz {
            let zr = &fwd.z[bi * c..(bi + 1) * c];
            let m = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f32;
            for &v in zr {
                denom += (v - m).exp();
            }
            let dr = &mut dz[bi * c..(bi + 1) * c];
            for ci in 0..c {
                dr[ci] = (zr[ci] - m).exp() / denom;
            }
            let y = yv[bi];
            if y >= 0 && (y as usize) < c {
                dr[y as usize] -= 1.0;
            }
            for v in dr.iter_mut() {
                *v /= bsz as f32;
            }
        }

        let mut dw2 = vec![0f32; h * c];
        let mut db2 = vec![0f32; c];
        for bi in 0..bsz {
            let hr = &fwd.hact[bi * h..(bi + 1) * h];
            let dr = &dz[bi * c..(bi + 1) * c];
            for ci in 0..c {
                db2[ci] += dr[ci];
            }
            for j in 0..h {
                let hv = hr[j];
                if hv == 0.0 {
                    continue;
                }
                let row = &mut dw2[j * c..(j + 1) * c];
                for ci in 0..c {
                    row[ci] += hv * dr[ci];
                }
            }
        }

        let mut dh = vec![0f32; bsz * h];
        for bi in 0..bsz {
            let dr = &dz[bi * c..(bi + 1) * c];
            let pr = &fwd.h_pre[bi * h..(bi + 1) * h];
            let dhr = &mut dh[bi * h..(bi + 1) * h];
            for j in 0..h {
                if pr[j] <= 0.0 {
                    continue;
                }
                let row = &w2[j * c..(j + 1) * c];
                let mut s = 0f32;
                for ci in 0..c {
                    s += dr[ci] * row[ci];
                }
                dhr[j] = s;
            }
        }

        let mut dw1 = vec![0f32; d * h];
        let mut db1 = vec![0f32; h];
        for bi in 0..bsz {
            let xr = &xv[bi * d..(bi + 1) * d];
            let dhr = &dh[bi * h..(bi + 1) * h];
            for j in 0..h {
                db1[j] += dhr[j];
            }
            for di in 0..d {
                let x = xr[di];
                if x == 0.0 {
                    continue;
                }
                let row = &mut dw1[di * h..(di + 1) * h];
                for j in 0..h {
                    row[j] += x * dhr[j];
                }
            }
        }

        // ---- hidden-activation column sums (run_mean numerator) ------
        let mut col_sums = vec![0f32; h];
        for row in fwd.hact.chunks_exact(h) {
            for (acc, v) in col_sums.iter_mut().zip(row) {
                *acc += *v;
            }
        }

        // ---- the one shared optimizer update -------------------------
        // wd -> PSG telemetry -> momentum SGD -> gates -> run_mean all
        // live in `optim::update::apply_update`; this interpreter only
        // produces raw gradients and packages the results.
        let mut ucfg = UpdateCfg {
            lr,
            alpha: 0.0,
            beta: 0.0,
            momentum: mu,
            weight_decay: wd,
            psg: self.update == "psg",
            batch: bsz as f32,
        };
        if ucfg.psg {
            ucfg.beta = self.scalar_in(inputs, "beta")?;
        }
        let gate = if self.gating == "learned" {
            ucfg.alpha = self.scalar_in(inputs, "alpha")?;
            Some(GateIn {
                w: self.f32_in(inputs, "gate.w")?.as_f32()?,
                mom: self.f32_in(inputs, "mom.gate.w")?.as_f32()?,
            })
        } else {
            None
        };
        let params = vec![
            ParamIn {
                w: w1,
                mom: self.f32_in(inputs, "mom.w1")?.as_f32()?,
                grad: dw1,
                decay: true,
            },
            ParamIn {
                w: b1,
                mom: self.f32_in(inputs, "mom.b1")?.as_f32()?,
                grad: db1,
                decay: false,
            },
            ParamIn {
                w: w2,
                mom: self.f32_in(inputs, "mom.w2")?.as_f32()?,
                grad: dw2,
                decay: true,
            },
            ParamIn {
                w: b2,
                mom: self.f32_in(inputs, "mom.b2")?.as_f32()?,
                grad: db2,
                decay: false,
            },
        ];
        let run_mean = RunMeanIn {
            current: self.f32_in(inputs, "run_mean")?.as_f32()?,
            col_sums,
        };
        let up = apply_update(&ucfg, params, gate, Some(run_mean));

        // ---- assemble outputs in spec order --------------------------
        let mut pit = up.params.into_iter();
        let (nw1, nm1) = pit.next().expect("w1 update");
        let (nb1, nmb1) = pit.next().expect("b1 update");
        let (nw2, nm2) = pit.next().expect("w2 update");
        let (nb2, nmb2) = pit.next().expect("b2 update");
        let mut computed: HashMap<&str, HostTensor> = HashMap::new();
        computed.insert("w1", HostTensor::f32(vec![d, h], nw1));
        computed.insert("b1", HostTensor::f32(vec![h], nb1));
        computed.insert("w2", HostTensor::f32(vec![h, c], nw2));
        computed.insert("b2", HostTensor::f32(vec![c], nb2));
        computed.insert("mom.w1", HostTensor::f32(vec![d, h], nm1));
        computed.insert("mom.b1", HostTensor::f32(vec![h], nmb1));
        computed.insert("mom.w2", HostTensor::f32(vec![h, c], nm2));
        computed.insert("mom.b2", HostTensor::f32(vec![c], nmb2));
        computed.insert(
            "run_mean",
            HostTensor::f32(vec![h], up.run_mean.expect("run_mean update")),
        );
        computed.insert("loss", HostTensor::scalar_f32(loss));
        computed.insert("correct", HostTensor::scalar_f32(correct));
        if let Some(g) = up.gate {
            let n = g.fracs.len();
            computed.insert("gate.w", HostTensor::f32(vec![n], g.w));
            computed.insert("mom.gate.w", HostTensor::f32(vec![n], g.mom));
            computed.insert("gate_fracs", HostTensor::f32(vec![n], g.fracs));
        }
        if let Some(p) = up.psg_frac {
            computed.insert("psg_frac", HostTensor::scalar_f32(p));
        }

        self.outputs
            .iter()
            .map(|io| {
                computed
                    .remove(io.name.as_str())
                    .ok_or_else(|| anyhow!("reference train step cannot produce '{}'", io.name))
            })
            .collect()
    }

    /// The sharded-training shard step: per-sample gradient products,
    /// hidden activations and metric contributions for a batch slice.
    ///
    /// Every arithmetic expression here mirrors [`Self::run_train`]
    /// term-for-term; entries the train step's accumulation skips
    /// (`x == 0` / `hact == 0` fast paths) stay exactly `0.0`, and the
    /// softmax rows divide by the global batch size `n`, so summing the
    /// per-sample tensors in global sample order is bitwise identical
    /// to the train step's own accumulation (see `runtime::shard` for
    /// the reduction side and the sign-of-zero argument).
    fn run_grad(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let w1t = self.f32_in(inputs, "w1")?;
        let b1t = self.f32_in(inputs, "b1")?;
        let w2t = self.f32_in(inputs, "w2")?;
        let b2t = self.f32_in(inputs, "b2")?;
        let (d, h) = (w1t.shape[0], w1t.shape[1]);
        let c = w2t.shape[1];
        let (w1, b1, w2, b2) =
            (w1t.as_f32()?, b1t.as_f32()?, w2t.as_f32()?, b2t.as_f32()?);

        let xt = self.f32_in(inputs, "x")?;
        let bsz = xt.shape[0];
        if bsz == 0 {
            bail!("grad program got an empty batch slice");
        }
        let xv = xt.as_f32()?;
        if xv.len() != bsz * d {
            bail!("x has {} elems, expected {}x{}", xv.len(), bsz, d);
        }
        let yt = inputs[self.input_index("y")?];
        let yv = match &yt.data {
            TensorData::I32(v) => v,
            _ => bail!("y must be i32"),
        };
        let n = self.scalar_in(inputs, "n")?;
        if !(n >= 1.0) {
            bail!("grad program needs the global batch size n >= 1, got {n}");
        }

        let fwd = forward(xv, w1, b1, w2, b2, bsz, d, h, c);

        // Per-sample softmax gradient rows (run_train's dz, normalized
        // by the GLOBAL batch size).
        let mut dz = vec![0f32; bsz * c];
        for bi in 0..bsz {
            let zr = &fwd.z[bi * c..(bi + 1) * c];
            let m = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f32;
            for &v in zr {
                denom += (v - m).exp();
            }
            let dr = &mut dz[bi * c..(bi + 1) * c];
            for ci in 0..c {
                dr[ci] = (zr[ci] - m).exp() / denom;
            }
            let y = yv[bi];
            if y >= 0 && (y as usize) < c {
                dr[y as usize] -= 1.0;
            }
            for v in dr.iter_mut() {
                *v /= n;
            }
        }

        // Hidden-layer backprop, identical to run_train.
        let mut dh = vec![0f32; bsz * h];
        for bi in 0..bsz {
            let dr = &dz[bi * c..(bi + 1) * c];
            let pr = &fwd.h_pre[bi * h..(bi + 1) * h];
            let dhr = &mut dh[bi * h..(bi + 1) * h];
            for j in 0..h {
                if pr[j] <= 0.0 {
                    continue;
                }
                let row = &w2[j * c..(j + 1) * c];
                let mut s = 0f32;
                for ci in 0..c {
                    s += dr[ci] * row[ci];
                }
                dhr[j] = s;
            }
        }

        // Per-sample gradient products, laid out [b, param shape] —
        // the exact terms run_train's `+=` loops accumulate.
        let mut gw1 = vec![0f32; bsz * d * h];
        let mut gb1 = vec![0f32; bsz * h];
        let mut gw2 = vec![0f32; bsz * h * c];
        let mut gb2 = vec![0f32; bsz * c];
        for bi in 0..bsz {
            let dr = &dz[bi * c..(bi + 1) * c];
            gb2[bi * c..(bi + 1) * c].copy_from_slice(dr);
            let hr = &fwd.hact[bi * h..(bi + 1) * h];
            for j in 0..h {
                let hv = hr[j];
                if hv == 0.0 {
                    continue;
                }
                let row = &mut gw2[(bi * h + j) * c..(bi * h + j + 1) * c];
                for ci in 0..c {
                    row[ci] = hv * dr[ci];
                }
            }
            let dhr = &dh[bi * h..(bi + 1) * h];
            gb1[bi * h..(bi + 1) * h].copy_from_slice(dhr);
            let xr = &xv[bi * d..(bi + 1) * d];
            for di in 0..d {
                let x = xr[di];
                if x == 0.0 {
                    continue;
                }
                let row = &mut gw1[(bi * d + di) * h..(bi * d + di + 1) * h];
                for j in 0..h {
                    row[j] = x * dhr[j];
                }
            }
        }

        // Per-sample metric contributions (0 for padded/invalid labels,
        // matching softmax_metrics' skip).
        let mut loss = vec![0f32; bsz];
        let mut correct = vec![0f32; bsz];
        for bi in 0..bsz {
            let y = yv[bi];
            if y < 0 || y as usize >= c {
                continue;
            }
            let y = y as usize;
            let zr = &fwd.z[bi * c..(bi + 1) * c];
            loss[bi] = row_softmax_loss(zr, y);
            if row_rank(zr, y) == 0 {
                correct[bi] = 1.0;
            }
        }

        let mut computed: HashMap<&str, HostTensor> = HashMap::new();
        computed.insert("g.w1", HostTensor::f32(vec![bsz, d, h], gw1));
        computed.insert("g.b1", HostTensor::f32(vec![bsz, h], gb1));
        computed.insert("g.w2", HostTensor::f32(vec![bsz, h, c], gw2));
        computed.insert("g.b2", HostTensor::f32(vec![bsz, c], gb2));
        computed.insert("hact", HostTensor::f32(vec![bsz, h], fwd.hact));
        computed.insert("loss", HostTensor::f32(vec![bsz], loss));
        computed.insert("correct", HostTensor::f32(vec![bsz], correct));
        self.outputs
            .iter()
            .map(|io| {
                computed
                    .remove(io.name.as_str())
                    .ok_or_else(|| anyhow!("reference grad step cannot produce '{}'", io.name))
            })
            .collect()
    }

    fn run_eval(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let w1t = self.f32_in(inputs, "w1")?;
        let w2t = self.f32_in(inputs, "w2")?;
        let (d, h) = (w1t.shape[0], w1t.shape[1]);
        let c = w2t.shape[1];
        let (w1, w2) = (w1t.as_f32()?, w2t.as_f32()?);
        let b1 = self.f32_in(inputs, "b1")?.as_f32()?;
        let b2 = self.f32_in(inputs, "b2")?.as_f32()?;
        let xt = self.f32_in(inputs, "x")?;
        let bsz = xt.shape[0];
        let xv = xt.as_f32()?;
        let yt = inputs[self.input_index("y")?];
        let yv = match &yt.data {
            TensorData::I32(v) => v,
            _ => bail!("y must be i32"),
        };

        let fwd = forward(xv, w1, b1, w2, b2, bsz, d, h, c);
        let (loss_sum, correct, correct5) = softmax_metrics(&fwd.z, yv, bsz, c);

        // Batch-mean loss: rows with label < 0 (eval-tail padding)
        // contribute exactly zero, so `mean * batch` recovers the sum
        // over real samples — the contract evaluate_full relies on.
        let mut computed: HashMap<&str, HostTensor> = HashMap::new();
        computed.insert("loss", HostTensor::scalar_f32(loss_sum / bsz as f32));
        computed.insert("correct", HostTensor::scalar_f32(correct));
        computed.insert("correct5", HostTensor::scalar_f32(correct5));
        // Per-sample logits (role out_aux) when the program declares
        // them — the serving path routes individual rows back to their
        // requesters.  Rows are computed independently, so a sample's
        // logits don't depend on which batch it was coalesced into.
        if self.outputs.iter().any(|o| o.name == "logits") {
            computed.insert("logits", HostTensor::f32(vec![bsz, c], fwd.z));
        }
        self.outputs
            .iter()
            .map(|io| {
                computed
                    .remove(io.name.as_str())
                    .ok_or_else(|| anyhow!("reference eval cannot produce '{}'", io.name))
            })
            .collect()
    }
}

struct Forward {
    h_pre: Vec<f32>,
    hact: Vec<f32>,
    z: Vec<f32>,
}

fn forward(
    xv: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    bsz: usize,
    d: usize,
    h: usize,
    c: usize,
) -> Forward {
    let mut h_pre = vec![0f32; bsz * h];
    for bi in 0..bsz {
        let xr = &xv[bi * d..(bi + 1) * d];
        let hr = &mut h_pre[bi * h..(bi + 1) * h];
        hr.copy_from_slice(b1);
        for di in 0..d {
            let x = xr[di];
            if x == 0.0 {
                continue;
            }
            let row = &w1[di * h..(di + 1) * h];
            for j in 0..h {
                hr[j] += x * row[j];
            }
        }
    }
    let hact: Vec<f32> = h_pre.iter().map(|&v| v.max(0.0)).collect();
    let mut z = vec![0f32; bsz * c];
    for bi in 0..bsz {
        let hr = &hact[bi * h..(bi + 1) * h];
        let zr = &mut z[bi * c..(bi + 1) * c];
        zr.copy_from_slice(b2);
        for j in 0..h {
            let hv = hr[j];
            if hv == 0.0 {
                continue;
            }
            let row = &w2[j * c..(j + 1) * c];
            for ci in 0..c {
                zr[ci] += hv * row[ci];
            }
        }
    }
    Forward { h_pre, hact, z }
}

/// Softmax cross-entropy of one logits row against true class `y`.
/// Fixed evaluation order (max, then exp-sum in index order) — callers
/// relying on bitwise determinism (the serve equivalence tests) get the
/// exact float the batched metrics accumulate.
pub fn row_softmax_loss(zr: &[f32], y: usize) -> f32 {
    let m = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut denom = 0f32;
    for &v in zr {
        denom += (v - m).exp();
    }
    denom.ln() + m - zr[y]
}

/// Rank of the true class within a logits row (strict wins; ties broken
/// by index).  0 means top-1 hit.
pub fn row_rank(zr: &[f32], y: usize) -> usize {
    let zy = zr[y];
    zr.iter()
        .enumerate()
        .filter(|&(ci, &v)| v > zy || (v == zy && ci < y))
        .count()
}

/// Predicted class of a logits row: argmax with ties going to the lowest
/// index — the inverse of [`row_rank`]'s tie rule, so
/// `row_argmax(zr) == y  <=>  row_rank(zr, y) == 0`.
pub fn row_argmax(zr: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in zr.iter().enumerate().skip(1) {
        if v > zr[best] {
            best = i;
        }
    }
    best
}

/// (loss_sum, correct, correct5) over a logits batch.  Rows with a
/// negative label are padding: they contribute nothing to any metric
/// (mirroring `one_hot(-1) == 0` in the lowered artifacts).
fn softmax_metrics(z: &[f32], yv: &[i32], bsz: usize, c: usize) -> (f32, f32, f32) {
    let mut loss_sum = 0f32;
    let mut correct = 0f32;
    let mut correct5 = 0f32;
    for bi in 0..bsz {
        let y = yv[bi];
        if y < 0 || y as usize >= c {
            continue;
        }
        let y = y as usize;
        let zr = &z[bi * c..(bi + 1) * c];
        loss_sum += row_softmax_loss(zr, y);
        let rank = row_rank(zr, y);
        if rank == 0 {
            correct += 1.0;
        }
        if rank < 5 {
            correct5 += 1.0;
        }
    }
    (loss_sum, correct, correct5)
}

// ==========================================================================
// Fixture generation
// ==========================================================================

/// Sizing of a generated reference family.
#[derive(Debug, Clone)]
pub struct RefFamilySpec {
    pub family: String,
    pub hw: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub gated_blocks: usize,
}

impl RefFamilySpec {
    /// Small enough for debug-mode tests.
    pub fn tiny() -> Self {
        Self {
            family: "refmlp-tiny".into(),
            hw: 8,
            hidden: 32,
            classes: 10,
            batch: 8,
            eval_batch: 16,
            gated_blocks: 4,
        }
    }

    /// Large enough that state-transfer overhead is measurable against
    /// compute (bench_runtime's host-vs-resident comparison).
    pub fn bench() -> Self {
        Self {
            family: "refmlp-bench".into(),
            hw: 16,
            hidden: 192,
            classes: 10,
            batch: 16,
            eval_batch: 32,
            gated_blocks: 4,
        }
    }

    fn dim(&self) -> usize {
        self.hw * self.hw * 3
    }
}

fn io(name: &str, role: &str, shape: &[usize], dtype: &str, init: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("role", Json::str(role)),
        (
            "shape",
            Json::arr(shape.iter().map(|&s| Json::num(s as f64))),
        ),
        ("dtype", Json::str(dtype)),
        ("init", Json::str(init)),
    ])
}

/// Write a reference artifact family (methods `sgd32` and `e2train`)
/// under `dir/<family>/`: per-method manifest + train/eval reference
/// programs.  The layout matches aot.py's exactly, so `TrainProgram`,
/// `Trainer` and the experiment harness load it like any other family.
pub fn write_reference_family(dir: &Path, spec: &RefFamilySpec) -> Result<std::path::PathBuf> {
    let d = spec.dim();
    let h = spec.hidden;
    let c = spec.classes;
    let g = spec.gated_blocks;
    let fam_dir = dir.join(&spec.family);
    std::fs::create_dir_all(&fam_dir)?;

    for method in ["sgd32", "e2train"] {
        let gated = method == "e2train";
        let (update, gating) = if gated { ("psg", "learned") } else { ("sgd", "none") };

        // ---- ordered state inputs (params, momenta, bn-state) --------
        let mut params = vec![
            io("w1", "param", &[d, h], "f32", "he"),
            io("b1", "param", &[h], "f32", "zeros"),
            io("w2", "param", &[h, c], "f32", "he"),
            io("b2", "param", &[c], "f32", "zeros"),
        ];
        if gated {
            params.push(io("gate.w", "param", &[g], "f32", "zeros"));
        }
        let mut moms = vec![
            io("mom.w1", "mom", &[d, h], "f32", "zeros"),
            io("mom.b1", "mom", &[h], "f32", "zeros"),
            io("mom.w2", "mom", &[h, c], "f32", "zeros"),
            io("mom.b2", "mom", &[c], "f32", "zeros"),
        ];
        if gated {
            moms.push(io("mom.gate.w", "mom", &[g], "f32", "zeros"));
        }
        let state = vec![io("run_mean", "state", &[h], "f32", "zeros")];

        let mut train_inputs: Vec<Json> = Vec::new();
        train_inputs.extend(params.iter().cloned());
        train_inputs.extend(moms.iter().cloned());
        train_inputs.extend(state.iter().cloned());
        train_inputs.push(io("x", "data", &[spec.batch, spec.hw, spec.hw, 3], "f32", ""));
        train_inputs.push(io("y", "data", &[spec.batch], "i32", ""));
        train_inputs.push(io("lr", "scalar", &[], "f32", ""));
        if gated {
            train_inputs.push(io("alpha", "scalar", &[], "f32", ""));
            train_inputs.push(io("beta", "scalar", &[], "f32", ""));
        }

        let out_role = |spec_io: &Json, role: &str| -> Json {
            let mut m = spec_io.as_obj().unwrap().clone();
            m.insert("role".into(), Json::str(role));
            Json::Obj(m)
        };
        let mut train_outputs: Vec<Json> = Vec::new();
        train_outputs.extend(params.iter().map(|p| out_role(p, "out_param")));
        train_outputs.extend(moms.iter().map(|p| out_role(p, "out_mom")));
        train_outputs.extend(state.iter().map(|p| out_role(p, "out_state")));
        train_outputs.push(io("loss", "out_metric", &[], "f32", ""));
        train_outputs.push(io("correct", "out_metric", &[], "f32", ""));
        if gated {
            train_outputs.push(io("gate_fracs", "out_metric", &[g], "f32", ""));
            train_outputs.push(io("psg_frac", "out_metric", &[], "f32", ""));
        }

        let mut eval_inputs: Vec<Json> = params.iter().cloned().collect();
        eval_inputs.extend(state.iter().cloned());
        eval_inputs.push(io(
            "x",
            "data",
            &[spec.eval_batch, spec.hw, spec.hw, 3],
            "f32",
            "",
        ));
        eval_inputs.push(io("y", "data", &[spec.eval_batch], "i32", ""));
        let eval_outputs = vec![
            io("loss", "out_metric", &[], "f32", ""),
            io("correct", "out_metric", &[], "f32", ""),
            io("correct5", "out_metric", &[], "f32", ""),
            // Per-sample logits for the serving path; metric decoding
            // skips non-out_metric roles, so train/eval loops ignore it.
            io("logits", "out_aux", &[spec.eval_batch, c], "f32", ""),
        ];

        // ---- block table for the energy model ------------------------
        let mut blocks = vec![Json::obj(vec![
            ("name", Json::str("fc1")),
            ("flops", Json::num((d * h) as f64)),
            ("gateable", Json::Bool(false)),
            ("in_ch", Json::num(3.0)),
            ("out_ch", Json::num(h as f64)),
            ("in_hw", Json::num(spec.hw as f64)),
            (
                "params",
                Json::arr([Json::str("w1"), Json::str("b1")].into_iter()),
            ),
        ])];
        let mut gated_fracs: Vec<Json> = Vec::new();
        if gated {
            for k in 0..g {
                blocks.push(Json::obj(vec![
                    ("name", Json::str(format!("gated{k}"))),
                    ("flops", Json::num((h * h) as f64)),
                    ("gateable", Json::Bool(true)),
                    ("in_ch", Json::num(h as f64)),
                    ("out_ch", Json::num(h as f64)),
                    ("in_hw", Json::num(1.0)),
                    ("params", Json::arr(std::iter::empty())),
                ]));
                gated_fracs.push(Json::num(1.0 / g as f64));
            }
        }
        let block_flops = d * h + if gated { g * h * h } else { 0 };
        let head_flops = h * c;
        let gate_flops = if gated { g * h } else { 0 };
        let param_count = d * h + h + h * c + c + if gated { g } else { 0 };

        let manifest = Json::obj(vec![
            ("family", Json::str(&spec.family)),
            (
                "method",
                Json::obj(vec![
                    ("name", Json::str(method)),
                    ("update", Json::str(update)),
                    ("gating", Json::str(gating)),
                    ("alpha", Json::num(1.0)),
                    ("beta", Json::num(0.05)),
                    ("momentum", Json::num(0.9)),
                    ("weight_decay", Json::num(1e-4)),
                    ("psg_bits_x", Json::num(4.0)),
                    ("psg_bits_gy", Json::num(10.0)),
                ]),
            ),
            (
                "arch",
                Json::obj(vec![
                    ("name", Json::str("refmlp")),
                    ("kind", Json::str("mlp")),
                    ("num_classes", Json::num(c as f64)),
                    ("image_size", Json::num(spec.hw as f64)),
                    ("batch", Json::num(spec.batch as f64)),
                    ("eval_batch", Json::num(spec.eval_batch as f64)),
                    ("width", Json::num(1.0)),
                    ("feat_ch", Json::num(h as f64)),
                ]),
            ),
            ("train_inputs", Json::Arr(train_inputs.clone())),
            ("train_outputs", Json::Arr(train_outputs.clone())),
            ("eval_inputs", Json::Arr(eval_inputs.clone())),
            ("eval_outputs", Json::Arr(eval_outputs.clone())),
            ("blocks", Json::Arr(blocks)),
            ("head_flops", Json::num(head_flops as f64)),
            ("total_flops", Json::num((block_flops + head_flops) as f64)),
            ("gated_flop_fracs", Json::Arr(gated_fracs)),
            ("gate_flops", Json::num(gate_flops as f64)),
            ("param_count", Json::num(param_count as f64)),
        ]);
        std::fs::write(
            fam_dir.join(format!("{method}.json")),
            manifest.to_string(),
        )?;

        let prog = |kind: &str, inputs: &[Json], outputs: &[Json]| {
            Json::obj(vec![
                ("kind", Json::str(kind)),
                ("gating", Json::str(gating)),
                ("update", Json::str(update)),
                ("momentum", Json::num(0.9)),
                ("weight_decay", Json::num(1e-4)),
                ("inputs", Json::Arr(inputs.to_vec())),
                ("outputs", Json::Arr(outputs.to_vec())),
            ])
        };
        std::fs::write(
            fam_dir.join(format!("{method}.train.ref.json")),
            prog("train", &train_inputs, &train_outputs).to_string(),
        )?;
        std::fs::write(
            fam_dir.join(format!("{method}.eval.ref.json")),
            prog("eval", &eval_inputs, &eval_outputs).to_string(),
        )?;

        // Grad-emitting program for the sharded data-parallel path
        // (runtime::shard): same state inputs as eval (params +
        // persistent state), a per-shard (x, y) slice, and the GLOBAL
        // batch size n; outputs one per-sample gradient tensor per
        // non-gate param (in param order), then per-sample hidden
        // activations and metric contributions.  Gate gradients are
        // batch-independent, so the host applies them analytically.
        let b = spec.batch;
        let mut grad_inputs: Vec<Json> = params.iter().cloned().collect();
        grad_inputs.extend(state.iter().cloned());
        grad_inputs.push(io("x", "data", &[b, spec.hw, spec.hw, 3], "f32", ""));
        grad_inputs.push(io("y", "data", &[b], "i32", ""));
        grad_inputs.push(io("n", "scalar", &[], "f32", ""));
        let grad_outputs = vec![
            io("g.w1", "out_grad", &[b, d, h], "f32", ""),
            io("g.b1", "out_grad", &[b, h], "f32", ""),
            io("g.w2", "out_grad", &[b, h, c], "f32", ""),
            io("g.b2", "out_grad", &[b, c], "f32", ""),
            io("hact", "out_aux", &[b, h], "f32", ""),
            io("loss", "out_aux", &[b], "f32", ""),
            io("correct", "out_aux", &[b], "f32", ""),
        ];
        std::fs::write(
            fam_dir.join(format!("{method}.grad.ref.json")),
            prog("grad", &grad_inputs, &grad_outputs).to_string(),
        )?;
    }
    Ok(fam_dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn family_writes_and_programs_parse() {
        let tmp = TempDir::new().unwrap();
        let spec = RefFamilySpec::tiny();
        let fam = write_reference_family(tmp.path(), &spec).unwrap();
        for method in ["sgd32", "e2train"] {
            let m = crate::runtime::Manifest::load(&fam.join(format!("{method}.json")))
                .unwrap();
            assert_eq!(m.method.name, method);
            let train =
                RefProgram::load(&fam.join(format!("{method}.train.ref.json"))).unwrap();
            assert_eq!(train.kind, RefKind::Train);
            assert_eq!(train.inputs.len(), m.train_inputs.len());
            assert_eq!(train.outputs.len(), m.train_outputs.len());
            let eval =
                RefProgram::load(&fam.join(format!("{method}.eval.ref.json"))).unwrap();
            assert_eq!(eval.inputs.len(), m.eval_inputs.len());
            // Grad program: state inputs (params + persistent state)
            // plus x, y and the global batch size scalar.
            let grad =
                RefProgram::load(&fam.join(format!("{method}.grad.ref.json"))).unwrap();
            assert_eq!(grad.kind, RefKind::Grad);
            let n_grad_state = m
                .train_inputs
                .iter()
                .filter(|s| matches!(s.role.as_str(), "param" | "state"))
                .count();
            assert_eq!(grad.inputs.len(), n_grad_state + 3);
            let n_data_params = m
                .train_inputs
                .iter()
                .filter(|s| s.role == "param" && !s.name.starts_with("gate."))
                .count();
            assert_eq!(grad.outputs.len(), n_data_params + 3);
            // state outputs mirror the state prefix of the inputs
            let n_state = m
                .train_inputs
                .iter()
                .filter(|s| matches!(s.role.as_str(), "param" | "mom" | "state"))
                .count();
            let n_out = m
                .train_outputs
                .iter()
                .filter(|s| s.role.starts_with("out_") && s.role != "out_metric")
                .count();
            assert_eq!(n_state, n_out);
            assert_eq!(m.gated_flop_fracs.len(), m.num_gated());
        }
    }

    #[test]
    fn train_step_is_deterministic_and_learns() {
        use crate::runtime::{ModelState, StepHyper, TrainProgram};

        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = crate::runtime::Engine::cpu().unwrap();
        let prog = TrainProgram::load(&engine, &fam.join("sgd32.json")).unwrap();
        let data = crate::data::synthetic::generate(10, 64, 8, 0);
        let mut sampler = crate::data::Sampler::new(
            data.n,
            prog.batch(),
            crate::data::AugmentCfg { enabled: false, ..Default::default() },
            1,
        );
        let (x, y) = sampler.next_batch(&data);

        let mut s1 = ModelState::init(&prog.manifest, 7);
        let mut s2 = ModelState::init(&prog.manifest, 7);
        let mut losses = Vec::new();
        for _ in 0..15 {
            let a = prog.step(&mut s1, &x, &y, StepHyper::lr(0.02), None).unwrap();
            let b = prog.step(&mut s2, &x, &y, StepHyper::lr(0.02), None).unwrap();
            assert_eq!(a.loss, b.loss);
            losses.push(a.loss);
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "loss did not decrease on a fixed batch: {losses:?}"
        );
    }

    #[test]
    fn e2train_method_emits_gate_and_psg_telemetry() {
        use crate::runtime::{ModelState, StepHyper, TrainProgram};

        let tmp = TempDir::new().unwrap();
        let spec = RefFamilySpec::tiny();
        let fam = write_reference_family(tmp.path(), &spec).unwrap();
        let engine = crate::runtime::Engine::cpu().unwrap();
        let prog = TrainProgram::load(&engine, &fam.join("e2train.json")).unwrap();
        let mut state = ModelState::init(&prog.manifest, 3);
        let data = crate::data::synthetic::generate(10, 32, 8, 0);
        let mut sampler = crate::data::Sampler::new(
            data.n,
            prog.batch(),
            crate::data::AugmentCfg::default(),
            2,
        );
        let (x, y) = sampler.next_batch(&data);
        let sm = prog.step(&mut state, &x, &y, StepHyper::lr(0.03), None).unwrap();
        assert_eq!(sm.gate_fracs.len(), spec.gated_blocks);
        assert!(sm.gate_fracs.iter().all(|&f| (0.0..=1.0).contains(&f)));
        let p = sm.psg_frac.expect("psg telemetry");
        assert!((0.0..=1.0).contains(&p));
        assert!(sm.loss.is_finite() && sm.loss > 0.0);
    }

    #[test]
    fn grad_rows_are_slice_independent() {
        use crate::runtime::{ModelState, TrainProgram};

        let tmp = TempDir::new().unwrap();
        let spec = RefFamilySpec::tiny();
        let fam = write_reference_family(tmp.path(), &spec).unwrap();
        let engine = crate::runtime::Engine::cpu().unwrap();
        let prog = TrainProgram::load(&engine, &fam.join("sgd32.json")).unwrap();
        let grad = RefProgram::load(&fam.join("sgd32.grad.ref.json")).unwrap();
        let state = ModelState::init(&prog.manifest, 4);
        let data = crate::data::synthetic::generate(10, 32, 8, 0);
        let mut sampler = crate::data::Sampler::new(
            data.n,
            spec.batch,
            crate::data::AugmentCfg::default(),
            6,
        );
        let (x, y) = sampler.next_batch(&data);
        let n = HostTensor::scalar_f32(spec.batch as f32);

        let run_slice = |lo: usize, hi: usize| -> Vec<HostTensor> {
            let (xs, ys) =
                crate::data::sampler::slice_batch(&x, &y, lo..hi).unwrap();
            let mut ins: Vec<&HostTensor> = Vec::new();
            for name in ["w1", "b1", "w2", "b2", "run_mean"] {
                ins.push(state.by_name(name).unwrap());
            }
            ins.push(&xs);
            ins.push(&ys);
            ins.push(&n);
            grad.run(&ins).unwrap()
        };

        // Full batch in one slice vs an uneven 5/3 split: every
        // per-sample row must be bitwise identical — the property the
        // sharded fixed-order all-reduce rests on.
        let full = run_slice(0, spec.batch);
        let lo = run_slice(0, 5);
        let hi = run_slice(5, spec.batch);
        for (oi, f) in full.iter().enumerate() {
            let fv = f.as_f32().unwrap();
            let stride = fv.len() / spec.batch;
            let lv = lo[oi].as_f32().unwrap();
            let hv = hi[oi].as_f32().unwrap();
            assert_eq!(&fv[..5 * stride], lv, "output {oi}: leading slice drifted");
            assert_eq!(&fv[5 * stride..], hv, "output {oi}: trailing slice drifted");
        }
    }

    #[test]
    fn row_helpers_are_consistent() {
        let zr = [0.5f32, 2.0, 2.0, -1.0];
        // argmax ties to the lowest index
        assert_eq!(row_argmax(&zr), 1);
        assert_eq!(row_rank(&zr, 1), 0);
        assert_eq!(row_rank(&zr, 2), 1, "tie broken toward the lower index");
        assert_eq!(row_rank(&zr, 0), 2);
        assert_eq!(row_rank(&zr, 3), 3);
        // rank == 0 exactly when argmax lands on the true class
        for y in 0..zr.len() {
            assert_eq!(row_rank(&zr, y) == 0, row_argmax(&zr) == y);
        }
        assert!(row_softmax_loss(&zr, 1) < row_softmax_loss(&zr, 3));
    }

    #[test]
    fn eval_emits_slot_independent_logits() {
        let tmp = TempDir::new().unwrap();
        let spec = RefFamilySpec::tiny();
        let fam = write_reference_family(tmp.path(), &spec).unwrap();
        let prog = RefProgram::load(&fam.join("sgd32.eval.ref.json")).unwrap();
        assert!(prog.outputs.iter().any(|o| o.name == "logits" && o.role == "out_aux"));
        let eb = spec.eval_batch;
        let d = spec.dim();
        let h = spec.hidden;
        let c = spec.classes;
        let state = [
            HostTensor::f32(vec![d, h], (0..d * h).map(|i| (i % 7) as f32 * 0.01).collect()),
            HostTensor::f32(vec![h], vec![0.1; h]),
            HostTensor::f32(vec![h, c], (0..h * c).map(|i| (i % 5) as f32 * 0.02).collect()),
            HostTensor::f32(vec![c], vec![0.0; c]),
            HostTensor::f32(vec![h], vec![0.0; h]),
        ];
        let sample: Vec<f32> = (0..d).map(|i| (i % 11) as f32 * 0.1).collect();
        let run_with_slot = |slot: usize| -> Vec<f32> {
            let mut px = vec![0f32; eb * d];
            px[slot * d..(slot + 1) * d].copy_from_slice(&sample);
            let mut py = vec![-1i32; eb];
            py[slot] = 3;
            let x = HostTensor::f32(vec![eb, spec.hw, spec.hw, 3], px);
            let y = HostTensor::i32(vec![eb], py);
            let mut ins: Vec<&HostTensor> = state.iter().collect();
            ins.push(&x);
            ins.push(&y);
            let outs = prog.run(&ins).unwrap();
            let logits = outs.last().unwrap().as_f32().unwrap().to_vec();
            logits[slot * c..(slot + 1) * c].to_vec()
        };
        // The same sample lands in different slots of different batches:
        // its logits row must be bit-identical — the property the serve
        // micro-batcher relies on.
        let a = run_with_slot(0);
        let b = run_with_slot(eb - 1);
        assert_eq!(a, b, "logits depend on batch slot");
    }

    #[test]
    fn eval_ignores_padded_rows() {
        let tmp = TempDir::new().unwrap();
        let spec = RefFamilySpec::tiny();
        let fam = write_reference_family(tmp.path(), &spec).unwrap();
        let prog = RefProgram::load(&fam.join("sgd32.eval.ref.json")).unwrap();
        let eb = spec.eval_batch;
        let d = spec.dim();
        let h = spec.hidden;
        let c = spec.classes;
        let w1 = HostTensor::f32(vec![d, h], vec![0.01; d * h]);
        let b1 = HostTensor::f32(vec![h], vec![0.0; h]);
        let w2 = HostTensor::f32(vec![h, c], vec![0.02; h * c]);
        let b2 = HostTensor::f32(vec![c], vec![0.0; c]);
        let run_mean = HostTensor::f32(vec![h], vec![0.0; h]);
        let x = HostTensor::f32(vec![eb, spec.hw, spec.hw, 3], vec![0.5; eb * d]);
        let mut labels = vec![0i32; eb];
        for l in labels.iter_mut().skip(eb / 2) {
            *l = -1; // padding
        }
        let y_pad = HostTensor::i32(vec![eb], labels);
        let y_full = HostTensor::i32(vec![eb], vec![0i32; eb]);
        let ins = |y: &HostTensor| -> Vec<HostTensor> {
            vec![
                w1.clone(),
                b1.clone(),
                w2.clone(),
                b2.clone(),
                run_mean.clone(),
                x.clone(),
                y.clone(),
            ]
        };
        let run = |tensors: &[HostTensor]| {
            let refs: Vec<&HostTensor> = tensors.iter().collect();
            prog.run(&refs).unwrap()
        };
        let padded = run(&ins(&y_pad));
        let full = run(&ins(&y_full));
        // half the rows are padding: exactly half the correct count and
        // half the loss mass.
        let c_pad = padded[1].scalar().unwrap();
        let c_full = full[1].scalar().unwrap();
        assert_eq!(c_pad * 2.0, c_full);
        let l_pad = padded[0].scalar().unwrap();
        let l_full = full[0].scalar().unwrap();
        assert!((l_pad * 2.0 - l_full).abs() < 1e-5);
    }
}
