//! PJRT engine: one CPU client + a cache of compiled executables.
//!
//! HLO **text** artifacts (see aot.py) are parsed with
//! `HloModuleProto::from_text_file`, compiled once per path, and shared
//! via `Arc` across the coordinator's programs.  Compilation is the
//! expensive part (seconds for the bigger train steps), so the cache key
//! is the canonical artifact path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::tensor::HostTensor;

/// A compiled PJRT executable plus light metadata.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
    pub compile_time_s: f64,
}

impl Program {
    /// Execute with host inputs; outputs are the decomposed result tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute pre-built literals (hot path: avoids cloning host buffers
    /// into an intermediate Vec<HostTensor> — EXPERIMENTS.md §Perf).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        let result = self.exe.execute::<xla::Literal>(literals)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// The shared PJRT CPU client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Program>>>,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<Arc<Program>> {
        let key = path.canonicalize().unwrap_or_else(|_| path.to_path_buf());
        if let Some(p) = self.cache.lock().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let program = Arc::new(Program {
            exe,
            path: key.clone(),
            compile_time_s: t0.elapsed().as_secs_f64(),
        });
        self.cache.lock().unwrap().insert(key, program.clone());
        Ok(program)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn compile_and_cache() {
        let path = artifacts().join("resnet8-c10-tiny/sgd32.eval.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let p1 = engine.load(&path).unwrap();
        let p2 = engine.load(&path).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(engine.cached_count(), 1);
        assert!(p1.compile_time_s > 0.0);
    }
}
