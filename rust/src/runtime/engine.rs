//! Engine: one process-wide client + a cache of compiled executables,
//! now multi-backend.
//!
//! Two program kinds live behind one `Program` type:
//!
//! * **PJRT** — HLO **text** artifacts (see aot.py) parsed with
//!   `HloModuleProto::from_text_file` and compiled through the `xla`
//!   crate.  Compilation is the expensive part (seconds for the bigger
//!   train steps), so the cache key is the canonical artifact path.
//! * **Reference** — `*.ref.json` programs interpreted by the pure-rust
//!   [`super::reference`] backend; always executable, used by tests,
//!   benches and any machine without a PJRT runtime.
//!
//! The cache is a [`SharedProgramCache`] keyed by the **content hash**
//! of the artifact file (not its path), so the same program reached
//! through different paths — or loaded by different engines of an
//! [`super::pool::EnginePool`] — compiles exactly once.  `Engine` is
//! `Sync` in this build, which lets the experiment harness fan runs out
//! across threads while sharing compiled programs (experiments::runs);
//! [`Engine::fork`] creates additional engines (one per worker) that
//! share the cache, for clients that are not `Sync` themselves.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::Hasher;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::device::{DeviceValue, ValueRef};
use super::reference::RefProgram;
use super::tensor::HostTensor;

/// Which executor owns a program's buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Pjrt,
    Reference,
}

enum ProgramImpl {
    Pjrt(xla::PjRtLoadedExecutable),
    Reference(RefProgram),
}

/// A compiled/loaded executable plus light metadata.
pub struct Program {
    imp: ProgramImpl,
    pub path: PathBuf,
    pub compile_time_s: f64,
}

impl Program {
    pub fn backend(&self) -> BackendKind {
        match self.imp {
            ProgramImpl::Pjrt(_) => BackendKind::Pjrt,
            ProgramImpl::Reference(_) => BackendKind::Reference,
        }
    }

    /// Execute with host inputs; outputs are the decomposed result tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match &self.imp {
            ProgramImpl::Reference(p) => {
                let refs: Vec<&HostTensor> = inputs.iter().collect();
                p.run(&refs)
            }
            ProgramImpl::Pjrt(_) => {
                let literals: Vec<xla::Literal> = inputs
                    .iter()
                    .map(|t| t.to_literal())
                    .collect::<Result<_>>()?;
                self.run_literals(&literals)
            }
        }
    }

    /// Host-path execution from pre-built literals.  This is the legacy
    /// per-step route: every state tensor crosses the boundary twice per
    /// call (literal in, host tensor out) — the cost the resident path
    /// exists to remove.  Kept as the baseline for the equivalence tests
    /// and the bench comparison.
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        match &self.imp {
            ProgramImpl::Pjrt(exe) => {
                let result = exe.execute::<xla::Literal>(literals)?[0][0]
                    .to_literal_sync()?;
                let parts = result.to_tuple()?;
                parts.iter().map(HostTensor::from_literal).collect()
            }
            ProgramImpl::Reference(p) => {
                // Faithful host-path cost model: literals decode to host
                // tensors before interpretation, mirroring the transfer a
                // PJRT execute performs.
                let host: Vec<HostTensor> = literals
                    .iter()
                    .map(HostTensor::from_literal)
                    .collect::<Result<_>>()?;
                let refs: Vec<&HostTensor> = host.iter().collect();
                p.run(&refs)
            }
        }
    }

    /// Resident-path execution: inputs stay in backend-native form, and
    /// outputs are returned in backend-native form so state never
    /// bounces through the host between steps.
    pub fn execute_refs(&self, inputs: &[ValueRef<'_>]) -> Result<Vec<DeviceValue>> {
        match &self.imp {
            ProgramImpl::Reference(p) => {
                // Resolve every input to a borrowed host tensor without
                // copying; only foreign (literal) inputs materialize.
                enum Slot<'a> {
                    Direct(&'a HostTensor),
                    Temp(usize),
                }
                let mut temps: Vec<HostTensor> = Vec::new();
                let mut slots: Vec<Slot> = Vec::with_capacity(inputs.len());
                for r in inputs.iter().copied() {
                    match r {
                        ValueRef::Host(t) => slots.push(Slot::Direct(t)),
                        ValueRef::Dev(DeviceValue::Host(t)) => slots.push(Slot::Direct(t)),
                        ValueRef::Dev(DeviceValue::Literal(l)) => {
                            temps.push(HostTensor::from_literal(l)?);
                            slots.push(Slot::Temp(temps.len() - 1));
                        }
                    }
                }
                let resolved: Vec<&HostTensor> = slots
                    .iter()
                    .map(|s| match s {
                        Slot::Direct(t) => *t,
                        Slot::Temp(i) => &temps[*i],
                    })
                    .collect();
                let outs = p.run(&resolved)?;
                Ok(outs.into_iter().map(DeviceValue::Host).collect())
            }
            ProgramImpl::Pjrt(exe) => {
                let literals: Vec<xla::Literal> = inputs
                    .iter()
                    .map(|r| match r {
                        ValueRef::Host(t) => t.to_literal(),
                        ValueRef::Dev(DeviceValue::Literal(l)) => Ok((*l).clone()),
                        ValueRef::Dev(DeviceValue::Host(t)) => t.to_literal(),
                    })
                    .collect::<Result<_>>()?;
                let result = exe.execute::<xla::Literal>(&literals)?[0][0]
                    .to_literal_sync()?;
                let parts = result.to_tuple()?;
                Ok(parts.into_iter().map(DeviceValue::Literal).collect())
            }
        }
    }
}

/// Compiled-program cache shared across engines: artifact content hash
/// -> loaded program.  Content keying makes the cache portable between
/// engines of a pool (caveat for real PJRT in `runtime::pool`).
pub type SharedProgramCache = Arc<Mutex<HashMap<u64, Arc<Program>>>>;

fn content_key(bytes: &[u8]) -> u64 {
    let mut h = DefaultHasher::new();
    h.write(bytes);
    h.finish()
}

/// The single source of truth for "which backend loads this program
/// file": `*.ref.json` is a reference-interpreter program, everything
/// else is HLO text for PJRT.  Shared by `Engine::load` and
/// `Manifest::resolved_backend` so pool-mode selection can never drift
/// from what the loader actually does.
pub(crate) fn is_reference_program(path: &Path) -> bool {
    path.file_name()
        .map(|n| n.to_string_lossy().ends_with(".ref.json"))
        .unwrap_or(false)
}

/// The shared client + executable cache.
pub struct Engine {
    /// PJRT client, constructed **lazily** on the first HLO compile.
    /// Reference-backend engines never touch PJRT, so a pool fanned out
    /// over reference programs (`EnginePool`) pays nothing per worker;
    /// with the real `xla` crate a client allocates device state, so
    /// wide fan-outs that only serve reference programs would otherwise
    /// pay for clients they never use.
    client: Mutex<Option<xla::PjRtClient>>,
    cache: SharedProgramCache,
    /// Path -> loaded program memo, so repeat loads of the same path do
    /// no file I/O at all (the content read+hash runs once per path per
    /// engine).  Same staleness contract as the seed's path-keyed
    /// cache: a file edited after first load keeps serving the old
    /// program for this engine's lifetime.
    by_path: Mutex<HashMap<PathBuf, Arc<Program>>>,
    /// Serializes **cold** compiles across engines sharing `cache`, so
    /// a fan-out racing on one uncached artifact compiles it exactly
    /// once (double-checked inside the guard).  Cache hits never touch
    /// this lock; distinct programs briefly queue behind each other,
    /// which is the cheap side of the trade — compiles are rare.
    compiling: Arc<Mutex<()>>,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: Mutex::new(None),
            cache: Arc::new(Mutex::new(HashMap::new())),
            by_path: Mutex::new(HashMap::new()),
            compiling: Arc::new(Mutex::new(())),
        })
    }

    /// A new engine sharing this engine's program cache — the building
    /// block of [`super::pool::EnginePool`]: worker threads each own an
    /// engine, programs still compile once.  The fork's client is lazy
    /// like any other engine's: it is only created if the fork actually
    /// compiles HLO.
    pub fn fork(&self) -> Result<Self> {
        Ok(Self {
            client: Mutex::new(None),
            cache: self.cache.clone(),
            by_path: Mutex::new(HashMap::new()),
            compiling: self.compiling.clone(),
        })
    }

    /// Run `f` against the PJRT client, constructing it on first use.
    /// Client-creation failures surface here (at the first HLO compile)
    /// instead of at `Engine::cpu()` time.
    fn with_client<T>(
        &self,
        f: impl FnOnce(&xla::PjRtClient) -> Result<T>,
    ) -> Result<T> {
        let mut guard = self.client.lock().unwrap();
        if guard.is_none() {
            *guard =
                Some(xla::PjRtClient::cpu().context("creating PJRT CPU client")?);
        }
        f(guard.as_ref().unwrap())
    }

    /// Whether the lazy PJRT client has been constructed (diagnostics /
    /// tests; reference-only engines should report `false` forever).
    pub fn client_is_initialized(&self) -> bool {
        self.client.lock().unwrap().is_some()
    }

    pub fn platform(&self) -> String {
        self.with_client(|c| Ok(c.platform_name()))
            .unwrap_or_else(|e| format!("unavailable ({e:#})"))
    }

    /// Load + compile an artifact (cached by content hash, memoized by
    /// path): `*.ref.json` programs go to the reference backend,
    /// everything else is HLO text for PJRT.
    pub fn load(&self, path: &Path) -> Result<Arc<Program>> {
        let path_key = path.canonicalize().unwrap_or_else(|_| path.to_path_buf());
        if let Some(p) = self.by_path.lock().unwrap().get(&path_key) {
            return Ok(p.clone());
        }
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading artifact {}", path.display()))?;
        let key = content_key(&bytes);
        let cached = self.cache.lock().unwrap().get(&key).cloned();
        if let Some(p) = cached {
            self.by_path.lock().unwrap().insert(path_key, p.clone());
            return Ok(p);
        }
        // Cold: take the compile lock and re-check — a racing engine
        // may have compiled this artifact while we waited.
        let _compiling = self.compiling.lock().unwrap();
        let cached = self.cache.lock().unwrap().get(&key).cloned();
        if let Some(p) = cached {
            self.by_path.lock().unwrap().insert(path_key, p.clone());
            return Ok(p);
        }
        let t0 = Instant::now();
        let imp = if is_reference_program(path) {
            // Parse from the bytes the cache key was hashed over — no
            // second read, so the key always matches the compiled
            // content even if the file is rewritten concurrently.
            let text = std::str::from_utf8(&bytes)
                .with_context(|| format!("reference program {} is not utf-8", path.display()))?;
            ProgramImpl::Reference(
                RefProgram::from_text(text)
                    .with_context(|| format!("parsing reference program {}", path.display()))?,
            )
        } else {
            // HLO goes through the xla crate's file-based API (the only
            // one the real crate exposes); a concurrent rewrite between
            // hash and parse can mis-key — acceptable, artifacts are
            // not regenerated while engines are live.
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            ProgramImpl::Pjrt(self.with_client(|client| {
                client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", path.display()))
            })?)
        };
        let program = Arc::new(Program {
            imp,
            path: path.to_path_buf(),
            compile_time_s: t0.elapsed().as_secs_f64(),
        });
        self.cache.lock().unwrap().insert(key, program.clone());
        self.by_path.lock().unwrap().insert(path_key, program.clone());
        Ok(program)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::{write_reference_family, RefFamilySpec};
    use crate::util::tmp::TempDir;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn compile_and_cache() {
        let path = artifacts().join("resnet8-c10-tiny/sgd32.eval.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let p1 = engine.load(&path).unwrap();
        let p2 = engine.load(&path).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(engine.cached_count(), 1);
        assert!(p1.compile_time_s > 0.0);
    }

    #[test]
    fn reference_programs_load_and_cache() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let p1 = engine.load(&fam.join("sgd32.train.ref.json")).unwrap();
        let p2 = engine.load(&fam.join("sgd32.train.ref.json")).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(p1.backend(), BackendKind::Reference);
        assert_eq!(engine.cached_count(), 1);
    }

    #[test]
    fn forked_engines_share_the_program_cache() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let fork = engine.fork().unwrap();
        let p1 = engine.load(&fam.join("sgd32.eval.ref.json")).unwrap();
        let p2 = fork.load(&fam.join("sgd32.eval.ref.json")).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "fork must reuse the compiled program");
        assert_eq!(engine.cached_count(), 1);
        assert_eq!(fork.cached_count(), 1);
    }

    #[test]
    fn engine_is_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<Engine>();
        check::<Program>();
    }

    #[test]
    fn client_is_lazy_for_reference_only_engines() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        assert!(!engine.client_is_initialized(), "cpu() must not build a client");
        let fork = engine.fork().unwrap();
        assert!(!fork.client_is_initialized(), "fork() must not build a client");
        // Reference programs never need PJRT.
        let _ = fork.load(&fam.join("sgd32.train.ref.json")).unwrap();
        assert!(!fork.client_is_initialized());
        // HLO compile constructs it on demand.
        let hlo = artifacts().join("resnet8-c10-tiny/sgd32.eval.hlo.txt");
        if hlo.exists() {
            let _ = engine.load(&hlo).unwrap();
            assert!(engine.client_is_initialized());
        }
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let engine = Engine::cpu().unwrap();
        assert!(engine.load(Path::new("/nonexistent/x.train.hlo.txt")).is_err());
        assert!(engine.load(Path::new("/nonexistent/x.train.ref.json")).is_err());
    }
}
