//! TrainProgram: a (manifest, train exe, eval exe) triple plus the state
//! plumbing that moves model parameters through a step.
//!
//! The coordinator owns a [`ModelState`] (params + momenta + BN state in
//! manifest order); `step()` assembles the exact input list the HLO
//! expects, executes, writes the updated state back in place, and returns
//! the step metrics.  No Python anywhere on this path.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::engine::{Engine, Program};
use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::optim::init::Initializer;

/// Trainable + persistent state in train-manifest input order
/// (params..., momenta..., bn state...).
#[derive(Clone)]
pub struct ModelState {
    /// Tensor per train input with role `param | mom | state`.
    pub values: Vec<HostTensor>,
    /// Names aligned with `values` (manifest names; momenta are `mom.*`).
    pub names: Vec<String>,
}

impl ModelState {
    /// Initialize from the manifest's init kinds (He/zeros/ones/uniform),
    /// matching python `layers.materialize` in distribution.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let mut init = Initializer::new(seed);
        let mut values = Vec::new();
        let mut names = Vec::new();
        for spec in &manifest.train_inputs {
            match spec.role.as_str() {
                "param" | "mom" | "state" => {
                    values.push(init.materialize(&spec.shape, &spec.init));
                    names.push(spec.name.clone());
                }
                _ => {}
            }
        }
        Self { values, names }
    }

    /// Fresh init for `manifest`, then copy every tensor whose name and
    /// shape match from `source` — method migration for fine-tuning
    /// (Sec. 4.5: a sgd32-pretrained trunk resumes under e2train, whose
    /// state adds gate parameters/momenta that start fresh).
    pub fn init_from(manifest: &Manifest, seed: u64, source: &ModelState) -> Self {
        let mut fresh = Self::init(manifest, seed);
        let names = fresh.names.clone();
        for (i, name) in names.iter().enumerate() {
            if let Some(src) = source.by_name(name) {
                if src.shape == fresh.values[i].shape {
                    fresh.values[i] = src.clone();
                }
            }
        }
        fresh
    }

    pub fn num_tensors(&self) -> usize {
        self.values.len()
    }

    pub fn total_elems(&self) -> usize {
        self.values.iter().map(|t| t.elem_count()).sum()
    }

    pub fn by_name(&self, name: &str) -> Option<&HostTensor> {
        self.names.iter().position(|n| n == name).map(|i| &self.values[i])
    }

    /// Weighted in-place average: `self = self*(1-w) + other*w`.
    /// Used by SWA (stochastic weight averaging, Sec. 4.1) — applied to
    /// params only; momenta/BN state are copied from `other`.
    pub fn average_params_from(&mut self, other: &ModelState, w: f32, param_count: usize) {
        for i in 0..self.values.len() {
            let ov = other.values[i].as_f32().unwrap().to_vec();
            let sv = self.values[i].as_f32_mut().unwrap();
            if i < param_count {
                for (s, o) in sv.iter_mut().zip(ov.iter()) {
                    *s = *s * (1.0 - w) + *o * w;
                }
            } else {
                sv.copy_from_slice(&ov);
            }
        }
    }
}

/// Runtime-tunable hyper-parameters fed to the train step as scalars.
#[derive(Debug, Clone, Copy)]
pub struct StepHyper {
    pub lr: f32,
    /// Eq. (1) FLOPs-regularizer weight (learned gating only).
    pub alpha: f32,
    /// PSG adaptive-threshold ratio (psg update only).
    pub beta: f32,
}

impl StepHyper {
    pub fn lr(lr: f32) -> Self {
        Self { lr, alpha: 1.0, beta: 0.05 }
    }
}

/// Per-step metrics decoded from the train program's metric outputs.
#[derive(Debug, Clone, Default)]
pub struct StepMetrics {
    pub loss: f64,
    /// Correct predictions within the training batch.
    pub correct: f64,
    /// Mean hard-gate activation per gateable block (empty if ungated).
    pub gate_fracs: Vec<f64>,
    /// Fraction of weight-gradient entries resolved by the MSB predictor
    /// (PSG methods only).
    pub psg_frac: Option<f64>,
}

#[derive(Debug, Clone, Default)]
pub struct EvalMetrics {
    pub loss: f64,
    pub correct: f64,
    /// Top-5 correct (== correct when num_classes <= 5).
    pub correct5: f64,
    pub total: usize,
    pub gate_fracs: Vec<f64>,
}

/// A fully-loaded (family, method) artifact ready to train and evaluate.
pub struct TrainProgram {
    pub manifest: Manifest,
    train: Arc<Program>,
    eval: Arc<Program>,
    /// #tensors with role param (prefix of ModelState).
    pub num_params: usize,
    /// index in ModelState for each eval input (params + bn state).
    eval_state_idx: Vec<usize>,
    metric_offset: usize,
}

impl TrainProgram {
    /// Load from a manifest path (`artifacts/<family>/<method>.json`).
    pub fn load(engine: &Engine, manifest_path: &Path) -> Result<Self> {
        let manifest = Manifest::load(manifest_path)?;
        let (train_hlo, eval_hlo) = Manifest::hlo_paths(manifest_path);
        let train = engine.load(&train_hlo)?;
        let eval = engine.load(&eval_hlo)?;

        let num_params = manifest
            .train_inputs
            .iter()
            .filter(|s| s.role == "param")
            .count();
        let state_names: Vec<&str> = manifest
            .train_inputs
            .iter()
            .filter(|s| matches!(s.role.as_str(), "param" | "mom" | "state"))
            .map(|s| s.name.as_str())
            .collect();
        let mut eval_state_idx = Vec::new();
        for spec in &manifest.eval_inputs {
            if matches!(spec.role.as_str(), "param" | "state") {
                match state_names.iter().position(|n| *n == spec.name) {
                    Some(i) => eval_state_idx.push(i),
                    None => bail!("eval input {} missing from train state", spec.name),
                }
            }
        }
        let metric_offset = manifest
            .train_outputs
            .iter()
            .position(|o| o.role == "out_metric")
            .unwrap_or(manifest.train_outputs.len());
        Ok(Self { manifest, train, eval, num_params, eval_state_idx, metric_offset })
    }

    pub fn family(&self) -> &str {
        &self.manifest.family
    }

    pub fn method(&self) -> &str {
        &self.manifest.method.name
    }

    pub fn batch(&self) -> usize {
        self.manifest.arch.batch
    }

    pub fn eval_batch(&self) -> usize {
        self.manifest.arch.eval_batch
    }

    /// One optimizer step.  `mask` must be Some(per-gated-block mask) for
    /// `gating == "mask"` (stochastic depth) artifacts, None otherwise.
    /// `hp` carries the runtime-tunable knobs (lr always; alpha for
    /// learned gating; beta for PSG methods).
    pub fn step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<StepMetrics> {
        let needs_mask = self.manifest.method.gating == "mask";
        if needs_mask != mask.is_some() {
            bail!(
                "method {} gating={} but mask.is_some()={}",
                self.method(),
                self.manifest.method.gating,
                mask.is_some()
            );
        }
        // Hot path: convert straight to literals — no HostTensor clones.
        let mut literals: Vec<xla::Literal> =
            Vec::with_capacity(state.values.len() + 6);
        for v in &state.values {
            literals.push(v.to_literal()?);
        }
        literals.push(x.to_literal()?);
        literals.push(y.to_literal()?);
        literals.push(HostTensor::scalar_f32(hp.lr).to_literal()?);
        if self.manifest.method.gating == "learned" {
            literals.push(HostTensor::scalar_f32(hp.alpha).to_literal()?);
        }
        if self.manifest.method.update == "psg" {
            literals.push(HostTensor::scalar_f32(hp.beta).to_literal()?);
        }
        if let Some(m) = mask {
            literals.push(HostTensor::f32(vec![m.len()], m.to_vec()).to_literal()?);
        }

        let outputs = self.train.run_literals(&literals)?;
        if outputs.len() != self.manifest.train_outputs.len() {
            bail!(
                "train outputs: got {}, manifest says {}",
                outputs.len(),
                self.manifest.train_outputs.len()
            );
        }

        // Write back state (outputs are ordered params, momenta, bn state,
        // then metrics — mirroring the state prefix of the inputs).
        let mut out_iter = outputs.into_iter();
        for v in state.values.iter_mut() {
            *v = out_iter.next().unwrap();
        }
        let metrics: Vec<HostTensor> = out_iter.collect();

        let mut sm = StepMetrics::default();
        for (spec, tensor) in self.manifest.train_outputs[self.metric_offset..]
            .iter()
            .zip(metrics.iter())
        {
            match spec.name.as_str() {
                "loss" => sm.loss = tensor.scalar()?,
                "correct" => sm.correct = tensor.scalar()?,
                "gate_fracs" => {
                    sm.gate_fracs =
                        tensor.as_f32()?.iter().map(|&v| v as f64).collect()
                }
                "psg_frac" => sm.psg_frac = Some(tensor.scalar()?),
                other => bail!("unknown metric output {other}"),
            }
        }
        Ok(sm)
    }

    /// Evaluate one batch with running BN stats + hard gates.
    pub fn eval_batch_run(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<EvalMetrics> {
        let mut literals: Vec<xla::Literal> =
            Vec::with_capacity(self.eval_state_idx.len() + 2);
        for &i in &self.eval_state_idx {
            literals.push(state.values[i].to_literal()?);
        }
        literals.push(x.to_literal()?);
        literals.push(y.to_literal()?);
        let outputs = self.eval.run_literals(&literals)?;

        let mut em = EvalMetrics { total: y.elem_count(), ..Default::default() };
        for (spec, tensor) in self.manifest.eval_outputs.iter().zip(outputs.iter()) {
            match spec.name.as_str() {
                "loss" => em.loss = tensor.scalar()?,
                "correct" => em.correct = tensor.scalar()?,
                "correct5" => em.correct5 = tensor.scalar()?,
                "gate_fracs" => {
                    em.gate_fracs =
                        tensor.as_f32()?.iter().map(|&v| v as f64).collect()
                }
                other => bail!("unknown eval output {other}"),
            }
        }
        Ok(em)
    }
}
