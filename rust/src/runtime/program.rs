//! TrainProgram: a (manifest, train exe, eval exe) triple plus the state
//! plumbing that moves model parameters through a step.
//!
//! Two step routes exist:
//!
//! * the **host path** ([`TrainProgram::step`]) — the coordinator owns a
//!   [`ModelState`] of host tensors and every step converts the whole
//!   state in and out of the executing backend.  Kept as the equivalence
//!   baseline and for one-off host-side work;
//! * the **resident path** ([`TrainProgram::step_device`]) — state lives
//!   in a [`DeviceState`] across steps; only the small per-step inputs
//!   (x, y, scalars, SD mask) go in and only metric outputs come out.
//!
//! Both routes execute the same program, so for fixed seeds they produce
//! bitwise-identical metrics (tests/resident_equivalence.rs).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::device::{DeviceState, StateSnapshot, ValueRef};
use super::engine::{BackendKind, Engine, Program};
use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::optim::init::Initializer;

/// Trainable + persistent state in train-manifest input order
/// (params..., momenta..., bn state...).
#[derive(Clone)]
pub struct ModelState {
    /// Tensor per train input with role `param | mom | state`.
    pub values: Vec<HostTensor>,
    /// Names aligned with `values` (manifest names; momenta are `mom.*`).
    pub names: Vec<String>,
    /// name -> index, precomputed once so `by_name` (and the name-based
    /// migration in `init_from`) is O(1) instead of a linear scan.
    index: HashMap<String, usize>,
}

impl ModelState {
    pub fn new(values: Vec<HostTensor>, names: Vec<String>) -> Self {
        let index = names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), i))
            .collect();
        Self { values, names, index }
    }

    /// Initialize from the manifest's init kinds (He/zeros/ones/uniform),
    /// matching python `layers.materialize` in distribution.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let mut init = Initializer::new(seed);
        let mut values = Vec::new();
        let mut names = Vec::new();
        for spec in &manifest.train_inputs {
            match spec.role.as_str() {
                "param" | "mom" | "state" => {
                    values.push(init.materialize(&spec.shape, &spec.init));
                    names.push(spec.name.clone());
                }
                _ => {}
            }
        }
        Self::new(values, names)
    }

    /// Fresh init for `manifest`, then copy every tensor whose name and
    /// shape match from `source` — method migration for fine-tuning
    /// (Sec. 4.5: a sgd32-pretrained trunk resumes under e2train, whose
    /// state adds gate parameters/momenta that start fresh).
    pub fn init_from(manifest: &Manifest, seed: u64, source: &ModelState) -> Self {
        let mut fresh = Self::init(manifest, seed);
        for (name, value) in fresh.names.iter().zip(fresh.values.iter_mut()) {
            if let Some(src) = source.by_name(name) {
                if src.shape == value.shape {
                    *value = src.clone();
                }
            }
        }
        fresh
    }

    /// Decompose into (values, names) — used when moving the state into
    /// device-resident form without copying.
    pub fn into_parts(self) -> (Vec<HostTensor>, Vec<String>) {
        (self.values, self.names)
    }

    pub fn num_tensors(&self) -> usize {
        self.values.len()
    }

    pub fn total_elems(&self) -> usize {
        self.values.iter().map(|t| t.elem_count()).sum()
    }

    pub fn by_name(&self, name: &str) -> Option<&HostTensor> {
        self.index.get(name).map(|&i| &self.values[i])
    }

    /// Index of `name` in `values`/`names` (O(1)) — used by the sharded
    /// trainer to map grad-program inputs/outputs onto the master state.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Does this state match `spec` exactly — same tensor names and
    /// shapes, in order?  `spec` is a [`Manifest::state_spec`]; resume
    /// validation and serve hot-loads share this one comparison.
    ///
    /// [`Manifest::state_spec`]: super::manifest::Manifest::state_spec
    pub fn matches_spec(&self, spec: &[(String, Vec<usize>)]) -> bool {
        self.names.len() == spec.len()
            && spec
                .iter()
                .zip(self.names.iter().zip(self.values.iter()))
                .all(|((name, shape), (n, v))| name == n && *shape == v.shape)
    }

    /// Panic unless `other` is bitwise identical (names, shapes, f32
    /// payloads), naming the first drifting tensor.  Shared assertion
    /// behind the determinism contracts (the resident / sharded /
    /// streaming-ingestion equivalence suites) — diagnostic tooling,
    /// not a runtime comparison.
    pub fn assert_bitwise_eq(&self, other: &ModelState) {
        assert_eq!(self.names, other.names, "state tensor names drifted");
        for ((n, a), b) in self
            .names
            .iter()
            .zip(self.values.iter())
            .zip(other.values.iter())
        {
            assert_eq!(a.shape, b.shape, "{n}: shape drift");
            assert_eq!(
                a.as_f32().expect("bitwise compare expects f32"),
                b.as_f32().expect("bitwise compare expects f32"),
                "{n}: value drift"
            );
        }
    }

    /// Weighted in-place average: `self = self*(1-w) + other*w`.
    /// Used by SWA (stochastic weight averaging, Sec. 4.1) — applied to
    /// params only; momenta/BN state are copied from `other`.
    /// Allocation-free: walks both states' slices directly.
    pub fn average_params_from(&mut self, other: &ModelState, w: f32, param_count: usize) {
        for (i, (sv, ov)) in self
            .values
            .iter_mut()
            .zip(other.values.iter())
            .enumerate()
        {
            let ov = ov.as_f32().expect("SWA state is f32");
            let sv = sv.as_f32_mut().expect("SWA state is f32");
            if i < param_count {
                for (s, o) in sv.iter_mut().zip(ov.iter()) {
                    *s = *s * (1.0 - w) + *o * w;
                }
            } else {
                sv.copy_from_slice(ov);
            }
        }
    }
}

/// Runtime-tunable hyper-parameters fed to the train step as scalars.
#[derive(Debug, Clone, Copy)]
pub struct StepHyper {
    pub lr: f32,
    /// Eq. (1) FLOPs-regularizer weight (learned gating only).
    pub alpha: f32,
    /// PSG adaptive-threshold ratio (psg update only).
    pub beta: f32,
}

impl StepHyper {
    pub fn lr(lr: f32) -> Self {
        Self { lr, alpha: 1.0, beta: 0.05 }
    }
}

/// Per-step metrics decoded from the train program's metric outputs.
#[derive(Debug, Clone, Default)]
pub struct StepMetrics {
    pub loss: f64,
    /// Correct predictions within the training batch.
    pub correct: f64,
    /// Mean hard-gate activation per gateable block (empty if ungated).
    pub gate_fracs: Vec<f64>,
    /// Fraction of weight-gradient entries resolved by the MSB predictor
    /// (PSG methods only).
    pub psg_frac: Option<f64>,
}

#[derive(Debug, Clone, Default)]
pub struct EvalMetrics {
    pub loss: f64,
    pub correct: f64,
    /// Top-5 correct (== correct when num_classes <= 5).
    pub correct5: f64,
    pub total: usize,
    pub gate_fracs: Vec<f64>,
}

/// Full eval result: aggregate metrics plus any per-sample auxiliary
/// outputs the artifact emits (role `out_aux`).
#[derive(Debug, Clone, Default)]
pub struct EvalOutput {
    pub metrics: EvalMetrics,
    /// Per-sample logits, shape `[batch, classes]`, when the eval
    /// program declares a `logits` output (reference eval programs do).
    /// The serving path slices rows out of this to answer individual
    /// requests coalesced into one micro-batch.
    pub logits: Option<HostTensor>,
}

/// A fully-loaded (family, method) artifact ready to train and evaluate.
///
/// [`TrainProgram::load_eval_only`] skips the train executable — the
/// serve-worker path, which only ever evaluates, no longer pays the
/// train-program compile (the expensive half under real PJRT, where
/// isolated workers each compile their own copy).
pub struct TrainProgram {
    pub manifest: Manifest,
    /// `None` when loaded eval-only; step paths error with a clear
    /// message instead of compiling lazily (an eval worker silently
    /// compiling a train program would defeat the point).
    train: Option<Arc<Program>>,
    eval: Arc<Program>,
    /// #tensors with role param (prefix of ModelState).
    pub num_params: usize,
    /// index in ModelState for each eval input (params + bn state).
    eval_state_idx: Vec<usize>,
    metric_offset: usize,
}

impl TrainProgram {
    /// Load from a manifest path (`artifacts/<family>/<method>.json`).
    /// Program files resolve to `<method>.{train,eval}.hlo.txt` when the
    /// HLO text exists, else `<method>.{train,eval}.ref.json` (reference
    /// backend).
    pub fn load(engine: &Engine, manifest_path: &Path) -> Result<Self> {
        Self::load_with(engine, manifest_path, true)
    }

    /// Load only the manifest + eval executable.  For workloads that
    /// never step (the serve worker pool), this skips the train-program
    /// compile entirely.
    pub fn load_eval_only(engine: &Engine, manifest_path: &Path) -> Result<Self> {
        Self::load_with(engine, manifest_path, false)
    }

    fn load_with(engine: &Engine, manifest_path: &Path, with_train: bool) -> Result<Self> {
        let manifest = Manifest::load(manifest_path)?;
        let (train_path, eval_path) = Manifest::program_paths(manifest_path);
        let train = if with_train {
            Some(engine.load(&train_path)?)
        } else {
            None
        };
        let eval = engine.load(&eval_path)?;

        let num_params = manifest
            .train_inputs
            .iter()
            .filter(|s| s.role == "param")
            .count();
        let state_names: Vec<&str> = manifest
            .train_inputs
            .iter()
            .filter(|s| matches!(s.role.as_str(), "param" | "mom" | "state"))
            .map(|s| s.name.as_str())
            .collect();
        let mut eval_state_idx = Vec::new();
        for spec in &manifest.eval_inputs {
            if matches!(spec.role.as_str(), "param" | "state") {
                match state_names.iter().position(|n| *n == spec.name) {
                    Some(i) => eval_state_idx.push(i),
                    None => bail!("eval input {} missing from train state", spec.name),
                }
            }
        }
        let metric_offset = manifest
            .train_outputs
            .iter()
            .position(|o| o.role == "out_metric")
            .unwrap_or(manifest.train_outputs.len());
        Ok(Self { manifest, train, eval, num_params, eval_state_idx, metric_offset })
    }

    pub fn family(&self) -> &str {
        &self.manifest.family
    }

    pub fn method(&self) -> &str {
        &self.manifest.method.name
    }

    pub fn batch(&self) -> usize {
        self.manifest.arch.batch
    }

    pub fn eval_batch(&self) -> usize {
        self.manifest.arch.eval_batch
    }

    /// Backend the train/eval executables run on.
    pub fn backend(&self) -> BackendKind {
        self.train.as_ref().unwrap_or(&self.eval).backend()
    }

    /// Whether this program was loaded without its train executable.
    pub fn is_eval_only(&self) -> bool {
        self.train.is_none()
    }

    fn train_exe(&self) -> Result<&Program> {
        self.train.as_deref().ok_or_else(|| {
            anyhow!(
                "{}/{} was loaded eval-only: the train executable is not available",
                self.family(),
                self.method()
            )
        })
    }

    /// Move a host state into resident form for this program's backend.
    pub fn upload_state(&self, state: ModelState) -> Result<DeviceState> {
        DeviceState::upload(self.backend(), state)
    }

    fn check_mask(&self, mask: Option<&[f32]>) -> Result<()> {
        let needs_mask = self.manifest.method.gating == "mask";
        if needs_mask != mask.is_some() {
            bail!(
                "method {} gating={} but mask.is_some()={}",
                self.method(),
                self.manifest.method.gating,
                mask.is_some()
            );
        }
        Ok(())
    }

    /// The small per-step tensors after (x, y): lr scalar, then alpha /
    /// beta scalars and the SD mask when the method wants them.
    fn step_extras(&self, hp: StepHyper, mask: Option<&[f32]>) -> Vec<HostTensor> {
        let mut extras = Vec::with_capacity(4);
        extras.push(HostTensor::scalar_f32(hp.lr));
        if self.manifest.method.gating == "learned" {
            extras.push(HostTensor::scalar_f32(hp.alpha));
        }
        if self.manifest.method.update == "psg" {
            extras.push(HostTensor::scalar_f32(hp.beta));
        }
        if let Some(m) = mask {
            extras.push(HostTensor::f32(vec![m.len()], m.to_vec()));
        }
        extras
    }

    fn decode_step_metrics(&self, metrics: &[HostTensor]) -> Result<StepMetrics> {
        let mut sm = StepMetrics::default();
        for (spec, tensor) in self.manifest.train_outputs[self.metric_offset..]
            .iter()
            .zip(metrics.iter())
        {
            match spec.name.as_str() {
                "loss" => sm.loss = tensor.scalar()?,
                "correct" => sm.correct = tensor.scalar()?,
                "gate_fracs" => {
                    sm.gate_fracs =
                        tensor.as_f32()?.iter().map(|&v| v as f64).collect()
                }
                "psg_frac" => sm.psg_frac = Some(tensor.scalar()?),
                other => bail!("unknown metric output {other}"),
            }
        }
        Ok(sm)
    }

    fn decode_eval_outputs(
        &self,
        outputs: Vec<HostTensor>,
        total: usize,
    ) -> Result<EvalOutput> {
        if outputs.len() != self.manifest.eval_outputs.len() {
            bail!(
                "eval outputs: got {}, manifest says {}",
                outputs.len(),
                self.manifest.eval_outputs.len()
            );
        }
        let mut em = EvalMetrics { total, ..Default::default() };
        let mut logits = None;
        for (spec, tensor) in self.manifest.eval_outputs.iter().zip(outputs) {
            match spec.role.as_str() {
                "out_metric" => match spec.name.as_str() {
                    "loss" => em.loss = tensor.scalar()?,
                    "correct" => em.correct = tensor.scalar()?,
                    "correct5" => em.correct5 = tensor.scalar()?,
                    "gate_fracs" => {
                        em.gate_fracs =
                            tensor.as_f32()?.iter().map(|&v| v as f64).collect()
                    }
                    other => bail!("unknown eval metric output {other}"),
                },
                // Auxiliary per-sample outputs: only logits is known;
                // others pass through unread (forward compatibility).
                "out_aux" => {
                    if spec.name == "logits" {
                        logits = Some(tensor);
                    }
                }
                other => bail!("unknown eval output role {other}"),
            }
        }
        Ok(EvalOutput { metrics: em, logits })
    }

    /// One optimizer step on the host path.  `mask` must be
    /// Some(per-gated-block mask) for `gating == "mask"` (stochastic
    /// depth) artifacts, None otherwise.  `hp` carries the
    /// runtime-tunable knobs (lr always; alpha for learned gating; beta
    /// for PSG methods).
    pub fn step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<StepMetrics> {
        self.check_mask(mask)?;
        // Convert straight to literals — no HostTensor clones.  (Still
        // one full state conversion each way per step; that churn is
        // what step_device removes.)
        let extras = self.step_extras(hp, mask);
        let mut literals: Vec<xla::Literal> =
            Vec::with_capacity(state.values.len() + 2 + extras.len());
        for v in &state.values {
            literals.push(v.to_literal()?);
        }
        literals.push(x.to_literal()?);
        literals.push(y.to_literal()?);
        for e in &extras {
            literals.push(e.to_literal()?);
        }

        let outputs = self.train_exe()?.run_literals(&literals)?;
        if outputs.len() != self.manifest.train_outputs.len() {
            bail!(
                "train outputs: got {}, manifest says {}",
                outputs.len(),
                self.manifest.train_outputs.len()
            );
        }

        // Write back state (outputs are ordered params, momenta, bn state,
        // then metrics — mirroring the state prefix of the inputs).
        let mut out_iter = outputs.into_iter();
        for v in state.values.iter_mut() {
            *v = out_iter.next().unwrap();
        }
        let metrics: Vec<HostTensor> = out_iter.collect();
        self.decode_step_metrics(&metrics)
    }

    /// One optimizer step on the resident path: state buffers stay in
    /// backend-native form, only (x, y, scalars, mask) go in and only
    /// the metric outputs are synced back to host.
    pub fn step_device(
        &self,
        state: &mut DeviceState,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<StepMetrics> {
        self.check_mask(mask)?;
        let extras = self.step_extras(hp, mask);
        let mut inputs: Vec<ValueRef> =
            Vec::with_capacity(state.values.len() + 2 + extras.len());
        for v in &state.values {
            inputs.push(ValueRef::Dev(v));
        }
        inputs.push(ValueRef::Host(x));
        inputs.push(ValueRef::Host(y));
        for e in &extras {
            inputs.push(ValueRef::Host(e));
        }

        let outputs = self.train_exe()?.execute_refs(&inputs)?;
        if outputs.len() != self.manifest.train_outputs.len() {
            bail!(
                "train outputs: got {}, manifest says {}",
                outputs.len(),
                self.manifest.train_outputs.len()
            );
        }
        let mut out_iter = outputs.into_iter();
        for v in state.values.iter_mut() {
            *v = out_iter.next().unwrap();
        }
        let metrics: Vec<HostTensor> = out_iter
            .map(|dv| dv.into_host())
            .collect::<Result<_>>()?;
        self.decode_step_metrics(&metrics)
    }

    /// Evaluate one batch with running BN stats + hard gates (host path).
    pub fn eval_batch_run(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<EvalMetrics> {
        let mut literals: Vec<xla::Literal> =
            Vec::with_capacity(self.eval_state_idx.len() + 2);
        for &i in &self.eval_state_idx {
            literals.push(state.values[i].to_literal()?);
        }
        literals.push(x.to_literal()?);
        literals.push(y.to_literal()?);
        let outputs = self.eval.run_literals(&literals)?;
        Ok(self.decode_eval_outputs(outputs, y.elem_count())?.metrics)
    }

    /// Evaluate one batch straight from resident state — no host sync of
    /// the model, only the metric scalars come back.
    pub fn eval_batch_device(
        &self,
        state: &DeviceState,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<EvalMetrics> {
        let refs: Vec<&super::device::DeviceValue> =
            self.eval_state_idx.iter().map(|&i| &state.values[i]).collect();
        Ok(self.eval_batch_refs(&refs, x, y)?.metrics)
    }

    /// Evaluate one pre-assembled (and, for partial tails, pre-padded
    /// with `-1` labels) batch against a published [`StateSnapshot`] —
    /// the serving path.  Read-only: many workers may evaluate against
    /// the same snapshot concurrently, and the publisher may swap the
    /// cell mid-flight without draining anyone.
    pub fn eval_batch_snapshot(
        &self,
        snap: &StateSnapshot,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<EvalOutput> {
        let refs = self
            .eval_state_idx
            .iter()
            .map(|&i| {
                snap.values.get(i).ok_or_else(|| {
                    anyhow!(
                        "snapshot holds {} tensors but eval needs state index {i}",
                        snap.values.len()
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
        self.eval_batch_refs(&refs, x, y)
    }

    fn eval_batch_refs(
        &self,
        state_refs: &[&super::device::DeviceValue],
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<EvalOutput> {
        let mut inputs: Vec<ValueRef> = Vec::with_capacity(state_refs.len() + 2);
        for v in state_refs.iter().copied() {
            inputs.push(ValueRef::Dev(v));
        }
        inputs.push(ValueRef::Host(x));
        inputs.push(ValueRef::Host(y));
        let outputs = self
            .eval
            .execute_refs(&inputs)?
            .into_iter()
            .map(|dv| dv.into_host())
            .collect::<Result<Vec<_>>>()?;
        self.decode_eval_outputs(outputs, y.elem_count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state_with(names: &[&str]) -> ModelState {
        ModelState::new(
            names
                .iter()
                .enumerate()
                .map(|(i, _)| HostTensor::f32(vec![2], vec![i as f32, i as f32 + 0.5]))
                .collect(),
            names.iter().map(|n| n.to_string()).collect(),
        )
    }

    #[test]
    fn by_name_uses_index() {
        let s = state_with(&["w", "b", "mom.w"]);
        assert_eq!(s.by_name("b").unwrap().as_f32().unwrap(), &[1.0, 1.5]);
        assert!(s.by_name("nope").is_none());
        // clone keeps the index coherent
        let c = s.clone();
        assert_eq!(c.by_name("mom.w").unwrap().as_f32().unwrap(), &[2.0, 2.5]);
    }

    #[test]
    fn index_of_matches_by_name() {
        let s = state_with(&["w", "b", "mom.w"]);
        assert_eq!(s.index_of("mom.w"), Some(2));
        assert_eq!(s.index_of("nope"), None);
    }

    #[test]
    fn eval_only_load_skips_train_and_rejects_stepping() {
        use crate::runtime::reference::{write_reference_family, RefFamilySpec};
        use crate::util::tmp::TempDir;

        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let prog =
            TrainProgram::load_eval_only(&engine, &fam.join("sgd32.json")).unwrap();
        assert!(prog.is_eval_only());
        // Only the eval program entered the cache — no train compile.
        assert_eq!(engine.cached_count(), 1);

        // Eval works from the manifest + eval program alone.
        let state = ModelState::init(&prog.manifest, 0);
        let eb = prog.eval_batch();
        let hw = prog.manifest.arch.image_size;
        let x = HostTensor::f32(vec![eb, hw, hw, 3], vec![0.1; eb * hw * hw * 3]);
        let y = HostTensor::i32(vec![eb], vec![0; eb]);
        let em = prog.eval_batch_run(&state, &x, &y).unwrap();
        assert!(em.loss.is_finite());

        // Stepping must fail with a clear message, not a panic.
        let mut st = state.clone();
        let (bx, by) = (
            HostTensor::f32(
                vec![prog.batch(), hw, hw, 3],
                vec![0.1; prog.batch() * hw * hw * 3],
            ),
            HostTensor::i32(vec![prog.batch()], vec![0; prog.batch()]),
        );
        let err = prog.step(&mut st, &bx, &by, StepHyper::lr(0.1), None).unwrap_err();
        assert!(format!("{err:#}").contains("eval-only"));
    }

    #[test]
    fn average_params_from_blends_params_and_copies_rest() {
        let mut a = ModelState::new(
            vec![
                HostTensor::f32(vec![2], vec![0.0, 2.0]),
                HostTensor::f32(vec![2], vec![1.0, 1.0]),
            ],
            vec!["w".into(), "mom.w".into()],
        );
        let b = ModelState::new(
            vec![
                HostTensor::f32(vec![2], vec![4.0, 6.0]),
                HostTensor::f32(vec![2], vec![9.0, 9.0]),
            ],
            vec!["w".into(), "mom.w".into()],
        );
        a.average_params_from(&b, 0.5, 1);
        assert_eq!(a.values[0].as_f32().unwrap(), &[2.0, 4.0]); // blended
        assert_eq!(a.values[1].as_f32().unwrap(), &[9.0, 9.0]); // copied
    }
}
