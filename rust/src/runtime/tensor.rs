//! Host-side tensors crossing the PJRT boundary.

use anyhow::{bail, Result};
use xla::Literal;

/// A host tensor: shape + typed storage.  Only the two dtypes the AOT
/// interface uses (f32 data / i32 labels) are represented.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Self { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Self { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        Self::f32(shape.to_vec(), vec![0.0; n])
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// First element as f64 (metric scalars).
    pub fn scalar(&self) -> Result<f64> {
        match &self.data {
            TensorData::F32(v) => Ok(v[0] as f64),
            TensorData::I32(v) => Ok(v[0] as f64),
        }
    }

    /// Convert to an xla Literal of the right shape/dtype.
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    Literal::scalar(v[0])
                } else {
                    Literal::vec1(v).reshape(&dims)?
                }
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    Literal::scalar(v[0])
                } else {
                    Literal::vec1(v).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    /// Read a Literal back into a HostTensor (f32 or i32).
    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Self::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Self::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape, vec![2, 3]);
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn roundtrip_scalar() {
        let t = HostTensor::scalar_f32(3.5);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert!(back.shape.is_empty());
        assert_eq!(back.scalar().unwrap(), 3.5);
    }

    #[test]
    fn roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![1, 2, 3, 4]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        match back.data {
            TensorData::I32(v) => assert_eq!(v, vec![1, 2, 3, 4]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn zeros_and_counts() {
        let t = HostTensor::zeros(&[3, 4]);
        assert_eq!(t.elem_count(), 12);
        assert!(t.as_f32().unwrap().iter().all(|&v| v == 0.0));
    }
}
