//! Fixed-shape parallel reduction tree for per-sample gradient
//! contributions (the parallel half of killing the "determinism tax",
//! see PERF.md).
//!
//! ## Why the tree splits the *element* axis, not the sample axis
//!
//! The bitwise contract (`tests/backend_matrix.rs`) pins the sharded
//! backend to the single-device step: every element of every gradient
//! must see *exactly the same additions in exactly the same order* as
//! the reference `step_device` reduce.  Float addition is not
//! associative, so a tree over contiguous sample-range partial sums —
//! `(s0+s1)+(s2+s3)` instead of `((s0+s1)+s2)+s3` — would produce
//! different bits and break the frozen matrix.
//!
//! The axis that *is* free is the element (column) axis: additions to
//! distinct gradient elements are independent FP operations with no
//! ordering constraint between them.  So the tree here bisects the
//! element range into a static binary tree of disjoint column slices;
//! each leaf replays the full per-sample sequence (shard-major,
//! row-minor — global sample order, because shard ranges are contiguous
//! ascending) over its own columns.  Every element still accumulates in
//! ascending global sample order, so the result is bitwise identical to
//! the sequential fold *by construction*, for any thread scheduling.
//!
//! ## Fixed shape
//!
//! The tree shape is a pure function of the workload — `tree_depth` of
//! the element count, never of timing, thread availability, or load.
//! Two runs of the same workload always build the same tree; the tree
//! being bitwise-equal to the sequential fold makes even *that* a
//! non-observable implementation detail (pinned by a proptest in
//! `tests/proptests.rs`).

/// Minimum element count a leaf is worth a thread for.  Below this the
/// spawn/join overhead exceeds the fold itself.
pub const REDUCE_GRAIN: usize = 4096;

/// Depth cap: at most 2^MAX_TREE_DEPTH = 8 leaves, matching the small
/// host-core budget the sharded fan-out already assumes.
pub const MAX_TREE_DEPTH: u32 = 3;

/// Tree depth for `elems` gradient elements — a pure function of the
/// workload (halve until a leaf fits [`REDUCE_GRAIN`] or the depth cap
/// is hit), never of timing.
pub fn tree_depth(elems: usize) -> u32 {
    let mut depth = 0;
    let mut len = elems;
    while depth < MAX_TREE_DEPTH && len > REDUCE_GRAIN {
        len -= len / 2; // the larger half after a split_at(len / 2)
        depth += 1;
    }
    depth
}

/// The reference fold: for each shard view (a concatenation of
/// per-sample rows, each `acc.len()` wide), add every row into `acc`
/// element-wise, shard-major row-minor.  This is the original
/// sequential fixed-order merge from `shard.rs` and the oracle the
/// tree is pinned against.
///
/// Each view's length must be a multiple of `acc.len()` (callers
/// validate row shapes before handing views over).
pub fn fold_sequential(acc: &mut [f32], shards: &[&[f32]]) {
    fold_columns(acc, 0, acc.len().max(1), shards);
}

/// The fixed-shape tree fold: bitwise identical to [`fold_sequential`]
/// (see module docs), fanned across host threads over disjoint column
/// ranges.  Depth 0 (small `acc` or empty input) folds inline without
/// spawning.
pub fn fold_tree(acc: &mut [f32], shards: &[&[f32]]) {
    let total = acc.len();
    if total == 0 || shards.is_empty() {
        return;
    }
    debug_assert!(shards.iter().all(|v| v.len() % total == 0));
    bisect(acc, 0, total, shards, tree_depth(total));
}

/// Recursive bisection: split the accumulator at its midpoint, spawn
/// the left half on a scoped thread, fold the right half inline.  The
/// two halves touch disjoint columns, so there is no FP interaction —
/// only the per-leaf [`fold_columns`] order matters, and that is the
/// sequential order.
fn bisect(acc: &mut [f32], off: usize, total: usize, shards: &[&[f32]], depth: u32) {
    if depth == 0 || acc.len() <= 1 {
        fold_columns(acc, off, total, shards);
        return;
    }
    let mid = acc.len() / 2;
    let (left, right) = acc.split_at_mut(mid);
    std::thread::scope(|s| {
        s.spawn(|| bisect(left, off, total, shards, depth - 1));
        bisect(right, off + mid, total, shards, depth - 1);
    });
}

/// Leaf fold over one column slice: for every shard view, for every
/// per-sample row (ascending — global sample order), add that row's
/// `[off, off + acc.len())` columns into `acc`.
fn fold_columns(acc: &mut [f32], off: usize, total: usize, shards: &[&[f32]]) {
    if acc.is_empty() {
        return;
    }
    for v in shards {
        for row in v.chunks_exact(total) {
            for (a, g) in acc.iter_mut().zip(&row[off..off + acc.len()]) {
                *a += *g;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_rows(rng: &mut Rng, rows: usize, elems: usize) -> Vec<f32> {
        (0..rows * elems)
            .map(|_| {
                // Mixed magnitudes so reordered additions would actually
                // change bits (catastrophic-cancellation bait).
                let scale = 10f32.powi(rng.range_usize(0, 8) as i32 - 4);
                rng.range_f32(-1.0, 1.0) * scale
            })
            .collect()
    }

    #[test]
    fn depth_is_a_pure_function_of_elems() {
        assert_eq!(tree_depth(0), 0);
        assert_eq!(tree_depth(1), 0);
        assert_eq!(tree_depth(REDUCE_GRAIN), 0);
        assert_eq!(tree_depth(REDUCE_GRAIN + 1), 1);
        assert_eq!(tree_depth(4 * REDUCE_GRAIN), 2);
        // Huge inputs cap at MAX_TREE_DEPTH (8 leaves).
        assert_eq!(tree_depth(usize::MAX / 2), MAX_TREE_DEPTH);
        for n in [0, 7, 4096, 40960, 1 << 22] {
            assert_eq!(tree_depth(n), tree_depth(n), "must be deterministic");
        }
    }

    #[test]
    fn tree_is_bitwise_identical_to_sequential() {
        let mut rng = Rng::seed_from_u64(0xE27A_0010);
        // Multi-leaf element count with a remainder, shards with uneven
        // row counts (including an empty one).
        for elems in [1usize, 33, REDUCE_GRAIN, 3 * REDUCE_GRAIN + 17] {
            let views: Vec<Vec<f32>> = [2usize, 0, 3, 1]
                .iter()
                .map(|&rows| random_rows(&mut rng, rows, elems))
                .collect();
            let refs: Vec<&[f32]> = views.iter().map(|v| v.as_slice()).collect();
            // Non-zero starting accumulator: micro-batch accumulation
            // reuses the same acc across folds.
            let base: Vec<f32> = (0..elems).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let mut seq = base.clone();
            fold_sequential(&mut seq, &refs);
            let mut tree = base.clone();
            fold_tree(&mut tree, &refs);
            for (i, (a, b)) in seq.iter().zip(&tree).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "elems={elems} idx={i}");
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_noops() {
        let mut empty: Vec<f32> = vec![];
        fold_tree(&mut empty, &[&[1.0, 2.0]]);
        fold_sequential(&mut empty, &[]);
        let mut acc = vec![1.5f32, -2.5];
        fold_tree(&mut acc, &[]);
        assert_eq!(acc, vec![1.5, -2.5]);
        let no_rows: &[f32] = &[];
        fold_tree(&mut acc, &[no_rows]);
        assert_eq!(acc, vec![1.5, -2.5]);
    }
}
