//! Artifact manifests — the contract between `python/compile/aot.py`
//! and the coordinator.
//!
//! A manifest describes one `(family, method)` artifact pair: the ordered
//! input/output buffer specs of the train and eval HLO programs, the block
//! table (FLOPs, gateability) the energy ledger charges from, and the
//! method hyper-parameters baked into the HLO at lowering time.
//!
//! Parsed with the in-repo JSON substrate (`util::json`).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

/// One input or output buffer of an AOT program, in execution order.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    /// `param | mom | state | data | scalar | mask | out_param | out_mom
    /// | out_state | out_metric`
    pub role: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub init: String,
}

impl IoSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req_str("name")?.to_string(),
            role: v.req_str("role")?.to_string(),
            shape: v
                .req_arr("shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            dtype: v.req_str("dtype")?.to_string(),
            init: v.get("init").and_then(Json::as_str).unwrap_or("").to_string(),
        })
    }
}

/// Hyper-parameters of the lowered method (mirror of python MethodSpec).
#[derive(Debug, Clone)]
pub struct MethodInfo {
    pub name: String,
    pub qbits_act: Option<u32>,
    pub qbits_grad: Option<u32>,
    pub update: String,
    pub gating: String,
    pub alpha: f64,
    pub beta: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub psg_bits_x: u32,
    pub psg_bits_gy: u32,
    pub head_only: bool,
}

impl MethodInfo {
    fn from_json(v: &Json) -> Result<Self> {
        let opt_u32 = |key: &str| v.get(key).and_then(Json::as_f64).map(|x| x as u32);
        Ok(Self {
            name: v.req_str("name")?.to_string(),
            qbits_act: opt_u32("qbits_act"),
            qbits_grad: opt_u32("qbits_grad"),
            update: v.req_str("update")?.to_string(),
            gating: v.req_str("gating")?.to_string(),
            alpha: v.req_f64("alpha")?,
            beta: v.req_f64("beta")?,
            momentum: v.req_f64("momentum")?,
            weight_decay: v.req_f64("weight_decay")?,
            psg_bits_x: v.req_f64("psg_bits_x")? as u32,
            psg_bits_gy: v.req_f64("psg_bits_gy")? as u32,
            head_only: v.get("head_only").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArchInfo {
    pub name: String,
    pub kind: String,
    pub num_classes: usize,
    pub image_size: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub width: f64,
    pub feat_ch: usize,
}

impl ArchInfo {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req_str("name")?.to_string(),
            kind: v.req_str("kind")?.to_string(),
            num_classes: v.req_f64("num_classes")? as usize,
            image_size: v.req_f64("image_size")? as usize,
            batch: v.req_f64("batch")? as usize,
            eval_batch: v.req_f64("eval_batch")? as usize,
            width: v.req_f64("width")?,
            feat_ch: v.req_f64("feat_ch")? as usize,
        })
    }
}

/// One trunk block: cost + gating metadata for the energy ledger.
#[derive(Debug, Clone)]
pub struct BlockInfo {
    pub name: String,
    pub flops: u64,
    pub gateable: bool,
    pub in_ch: usize,
    pub out_ch: usize,
    pub in_hw: usize,
    pub params: Vec<String>,
}

impl BlockInfo {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req_str("name")?.to_string(),
            flops: v.req_f64("flops")? as u64,
            gateable: v.get("gateable").and_then(Json::as_bool).unwrap_or(false),
            in_ch: v.req_f64("in_ch")? as usize,
            out_ch: v.req_f64("out_ch")? as usize,
            in_hw: v.req_f64("in_hw")? as usize,
            params: v
                .req_arr("params")?
                .iter()
                .filter_map(|p| p.as_str().map(String::from))
                .collect(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub family: String,
    pub method: MethodInfo,
    pub arch: ArchInfo,
    pub train_inputs: Vec<IoSpec>,
    pub train_outputs: Vec<IoSpec>,
    pub eval_inputs: Vec<IoSpec>,
    pub eval_outputs: Vec<IoSpec>,
    pub blocks: Vec<BlockInfo>,
    pub head_flops: u64,
    pub total_flops: u64,
    pub gated_flop_fracs: Vec<f64>,
    pub gate_flops: u64,
    pub param_count: u64,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::from_text(&text)
            .with_context(|| format!("parsing manifest {}", path.display()))
    }

    pub fn from_text(text: &str) -> Result<Self> {
        let v = parse(text)?;
        let specs = |key: &str| -> Result<Vec<IoSpec>> {
            v.req_arr(key)?.iter().map(IoSpec::from_json).collect()
        };
        Ok(Self {
            family: v.req_str("family")?.to_string(),
            method: MethodInfo::from_json(
                v.get("method").context("missing method")?,
            )?,
            arch: ArchInfo::from_json(v.get("arch").context("missing arch")?)?,
            train_inputs: specs("train_inputs")?,
            train_outputs: specs("train_outputs")?,
            eval_inputs: specs("eval_inputs")?,
            eval_outputs: specs("eval_outputs")?,
            blocks: v
                .req_arr("blocks")?
                .iter()
                .map(BlockInfo::from_json)
                .collect::<Result<_>>()?,
            head_flops: v.req_f64("head_flops")? as u64,
            total_flops: v.req_f64("total_flops")? as u64,
            gated_flop_fracs: v
                .req_arr("gated_flop_fracs")?
                .iter()
                .filter_map(Json::as_f64)
                .collect(),
            gate_flops: v.req_f64("gate_flops")? as u64,
            param_count: v.req_f64("param_count")? as u64,
        })
    }

    /// Path of the train/eval HLO next to a manifest path.
    pub fn hlo_paths(manifest_path: &Path) -> (PathBuf, PathBuf) {
        let stem = manifest_path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_default();
        let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
        (
            dir.join(format!("{stem}.train.hlo.txt")),
            dir.join(format!("{stem}.eval.hlo.txt")),
        )
    }

    /// Executable program paths next to a manifest: prefer compiled HLO
    /// text when present, fall back to reference-backend programs
    /// (`*.ref.json`, see `runtime::reference`).
    pub fn program_paths(manifest_path: &Path) -> (PathBuf, PathBuf) {
        let (train_hlo, eval_hlo) = Self::hlo_paths(manifest_path);
        if train_hlo.exists() && eval_hlo.exists() {
            return (train_hlo, eval_hlo);
        }
        let stem = manifest_path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_default();
        let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
        let train_ref = dir.join(format!("{stem}.train.ref.json"));
        let eval_ref = dir.join(format!("{stem}.eval.ref.json"));
        if train_ref.exists() && eval_ref.exists() {
            (train_ref, eval_ref)
        } else {
            // Neither exists: report the HLO pair so the load error
            // names the canonical artifact.
            (train_hlo, eval_hlo)
        }
    }

    /// Path of the grad-emitting reference program next to a manifest
    /// (`<method>.grad.ref.json`) — the per-shard executable of the
    /// sharded data-parallel path (`runtime::shard`).  Only reference
    /// families provide one today; the real-PJRT path will use on-device
    /// collectives instead (ROADMAP).
    pub fn grad_program_path(manifest_path: &Path) -> PathBuf {
        let stem = manifest_path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_default();
        let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
        dir.join(format!("{stem}.grad.ref.json"))
    }

    /// Backend the resolved program files for `manifest_path` will load
    /// on — decidable from path resolution alone, without compiling
    /// anything (pool-mode selection uses this; see `runtime::pool`).
    pub fn resolved_backend(manifest_path: &Path) -> super::engine::BackendKind {
        let (train, _) = Self::program_paths(manifest_path);
        if super::engine::is_reference_program(&train) {
            super::engine::BackendKind::Reference
        } else {
            super::engine::BackendKind::Pjrt
        }
    }

    /// (name, shape) of every train-state tensor (roles `param | mom |
    /// state`), in train-input order — the order `ModelState` and
    /// published snapshots are indexed by.  The single definition of
    /// "state layout" shared by checkpoint-resume validation
    /// (`Trainer::resume`) and serve registry hot-loads
    /// (`serve::watch_registry`), via `ModelState::matches_spec`.
    pub fn state_spec(&self) -> Vec<(String, Vec<usize>)> {
        self.train_inputs
            .iter()
            .filter(|s| matches!(s.role.as_str(), "param" | "mom" | "state"))
            .map(|s| (s.name.clone(), s.shape.clone()))
            .collect()
    }

    /// Count of gateable blocks (length of `gate_fracs` outputs).
    pub fn num_gated(&self) -> usize {
        self.blocks.iter().filter(|b| b.gateable).count()
    }

    /// Index of a named output in `train_outputs`.
    pub fn train_output_index(&self, name: &str) -> Option<usize> {
        self.train_outputs.iter().position(|o| o.name == name)
    }

    pub fn eval_output_index(&self, name: &str) -> Option<usize> {
        self.eval_outputs.iter().position(|o| o.name == name)
    }
}

/// Top-level `artifacts/index.json` written by aot.py.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub families: Vec<(String, FamilyEntry)>,
    pub methods: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct FamilyEntry {
    pub methods: Vec<String>,
    pub batch: usize,
    pub eval_batch: usize,
}

impl ArtifactIndex {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("index.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading artifact index {}", path.display()))?;
        let v = parse(&text)?;
        let mut families = Vec::new();
        if let Some(fams) = v.get("families").and_then(Json::as_obj) {
            for (name, fv) in fams {
                families.push((
                    name.clone(),
                    FamilyEntry {
                        methods: fv
                            .req_arr("methods")?
                            .iter()
                            .filter_map(|m| m.as_str().map(String::from))
                            .collect(),
                        batch: fv.req_f64("batch")? as usize,
                        eval_batch: fv.req_f64("eval_batch")? as usize,
                    },
                ));
            }
        }
        let methods = v
            .req_arr("methods")?
            .iter()
            .filter_map(|m| m.as_str().map(String::from))
            .collect();
        Ok(Self { families, methods })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_index_and_manifest() {
        let dir = artifacts_dir();
        if !dir.join("index.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let idx = ArtifactIndex::load(&dir).unwrap();
        assert!(!idx.families.is_empty());
        let (fam, entry) = &idx.families[0];
        let m = Manifest::load(&dir.join(fam).join(format!("{}.json", entry.methods[0])))
            .unwrap();
        assert_eq!(&m.family, fam);
        assert!(m.total_flops > 0);
        assert!(!m.train_inputs.is_empty());
        // params come before momenta before state before data
        let roles: Vec<&str> = m.train_inputs.iter().map(|s| s.role.as_str()).collect();
        let first_data = roles.iter().position(|r| *r == "data").unwrap();
        assert!(roles[..first_data].iter().all(|r| *r != "data"));
    }

    #[test]
    fn all_manifests_parse_and_are_consistent() {
        let dir = artifacts_dir();
        if !dir.join("index.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let idx = ArtifactIndex::load(&dir).unwrap();
        for (fam, entry) in &idx.families {
            for method in &entry.methods {
                let p = dir.join(fam).join(format!("{method}.json"));
                let m = Manifest::load(&p).unwrap();
                assert_eq!(&m.method.name, method);
                // train outputs mirror the state prefix of the inputs
                let n_state = m
                    .train_inputs
                    .iter()
                    .filter(|s| matches!(s.role.as_str(), "param" | "mom" | "state"))
                    .count();
                let n_out_state = m
                    .train_outputs
                    .iter()
                    .filter(|s| s.role.starts_with("out_") && s.role != "out_metric")
                    .count();
                assert_eq!(n_state, n_out_state, "{fam}/{method}");
                // gated fracs line up with gateable blocks
                assert_eq!(m.gated_flop_fracs.len(), m.num_gated(), "{fam}/{method}");
                // both HLO files exist
                let (t, e) = Manifest::hlo_paths(&p);
                assert!(t.exists() && e.exists(), "{fam}/{method}");
            }
        }
    }

    #[test]
    fn hlo_paths_derivation() {
        let (t, e) = Manifest::hlo_paths(Path::new("/a/b/psg.json"));
        assert_eq!(t, Path::new("/a/b/psg.train.hlo.txt"));
        assert_eq!(e, Path::new("/a/b/psg.eval.hlo.txt"));
        assert_eq!(
            Manifest::grad_program_path(Path::new("/a/b/psg.json")),
            Path::new("/a/b/psg.grad.ref.json")
        );
    }

    #[test]
    fn resolved_backend_matches_program_resolution() {
        use crate::runtime::reference::{write_reference_family, RefFamilySpec};
        use crate::runtime::BackendKind;

        let tmp = crate::util::tmp::TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        assert_eq!(
            Manifest::resolved_backend(&fam.join("sgd32.json")),
            BackendKind::Reference
        );
        // No program files at all: resolution reports the canonical HLO
        // pair, i.e. the PJRT backend (load will then error usefully).
        assert_eq!(
            Manifest::resolved_backend(Path::new("/nonexistent/x.json")),
            BackendKind::Pjrt
        );
    }
}
