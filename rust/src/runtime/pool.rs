//! Per-worker engine pool.
//!
//! `ExpCtx::run_many` and the serve worker pool used to hand one shared
//! `&Engine` to every worker thread, which silently assumes
//! `Engine: Sync`.  That holds for the reference backend and the in-repo
//! xla stub, but the real PJRT CPU client holds raw pointers and is not
//! `Sync` — fanning out over it is unsound the day the real crate links.
//!
//! [`EnginePool`] removes the assumption: it owns **one engine per
//! worker** (each with its own client), all sharing one
//! [`super::engine::SharedProgramCache`] keyed by artifact content hash,
//! so each program still compiles exactly once no matter how many
//! workers load it.
//!
//! Real-PJRT caveat: compiled executables are bound to the client that
//! compiled them, so the *cache* sharing here is only sound for
//! backend-portable programs (the reference backend, and the stub's
//! stand-in executables).  When linking the real `xla` crate, construct
//! the pool with [`EnginePool::new_isolated`] so each worker compiles
//! its own copy — the per-worker-client structure is already right.

use anyhow::Result;

use crate::util::fault::{self, FaultPlan};

use super::engine::Engine;

/// A set of engines, one per worker, sharing (or not) a program cache.
pub struct EnginePool {
    engines: Vec<Engine>,
}

impl EnginePool {
    /// `n` engines forked from `base`, all sharing `base`'s program
    /// cache (programs already compiled by `base` are reused).
    pub fn from_base(base: &Engine, n: usize) -> Result<Self> {
        let mut engines = Vec::with_capacity(n.max(1));
        for _ in 0..n.max(1) {
            engines.push(base.fork()?);
        }
        Ok(Self { engines })
    }

    /// Fork one replacement engine from `base`, sharing its program
    /// cache — the recovery path (shard re-fork, serve worker respawn)
    /// rebuilds a dead worker's engine through here.  The `pool.fork`
    /// fault site makes a *transient* fork failure injectable, so the
    /// recovery-of-the-recovery path is testable too.
    pub fn fork_one(base: &Engine, faults: Option<&FaultPlan>) -> Result<Engine> {
        if let Some(p) = faults {
            p.check(fault::SITE_POOL_FORK)?;
        }
        base.fork()
    }

    /// `n` fully isolated engines — one private cache each.  The safe
    /// construction for real PJRT, where executables are client-bound.
    pub fn new_isolated(n: usize) -> Result<Self> {
        let mut engines = Vec::with_capacity(n.max(1));
        for _ in 0..n.max(1) {
            engines.push(Engine::cpu()?);
        }
        Ok(Self { engines })
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// Worker `i`'s engine (wraps around, so any index is valid).
    pub fn engine(&self, i: usize) -> &Engine {
        &self.engines[i % self.engines.len()]
    }

    /// Consume the pool into owned engines — used when worker threads
    /// need to own their engine (`'static` spawn, e.g. the serve pool).
    pub fn into_engines(self) -> Vec<Engine> {
        self.engines
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::{write_reference_family, RefFamilySpec};
    use crate::util::tmp::TempDir;
    use std::sync::Arc;

    #[test]
    fn pool_shares_cache_from_base() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let base = Engine::cpu().unwrap();
        let p0 = base.load(&fam.join("sgd32.train.ref.json")).unwrap();
        let pool = EnginePool::from_base(&base, 3).unwrap();
        assert_eq!(pool.len(), 3);
        for i in 0..pool.len() {
            let p = pool.engine(i).load(&fam.join("sgd32.train.ref.json")).unwrap();
            assert!(Arc::ptr_eq(&p0, &p), "worker {i} recompiled");
        }
        assert_eq!(base.cached_count(), 1);
    }

    #[test]
    fn isolated_pool_compiles_per_worker() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let pool = EnginePool::new_isolated(2).unwrap();
        let a = pool.engine(0).load(&fam.join("sgd32.train.ref.json")).unwrap();
        let b = pool.engine(1).load(&fam.join("sgd32.train.ref.json")).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(pool.engine(0).cached_count(), 1);
        assert_eq!(pool.engine(1).cached_count(), 1);
    }

    #[test]
    fn fork_one_shares_cache_and_honors_the_fault_site() {
        use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};

        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let base = Engine::cpu().unwrap();
        let p0 = base.load(&fam.join("sgd32.train.ref.json")).unwrap();

        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_POOL_FORK.into(),
                    at: 1,
                    times: 1,
                    after_bytes: None,
                }],
                ..Default::default()
            },
            0,
        )
        .unwrap();
        let err = EnginePool::fork_one(&base, Some(&plan)).unwrap_err();
        assert!(fault::is_injected(&err), "untyped fork failure: {err:#}");
        // the fault is spent: the retry succeeds and shares the cache
        let e = EnginePool::fork_one(&base, Some(&plan)).unwrap();
        let p1 = e.load(&fam.join("sgd32.train.ref.json")).unwrap();
        assert!(Arc::ptr_eq(&p0, &p1), "replacement engine recompiled");
    }

    #[test]
    fn index_wraps() {
        let base = Engine::cpu().unwrap();
        let pool = EnginePool::from_base(&base, 2).unwrap();
        let _ = pool.engine(7); // must not panic
        assert_eq!(pool.into_engines().len(), 2);
    }
}
