//! The execution layer: *where* a training step runs, behind one trait.
//!
//! E2-Train's savings levers (SMD, selective layer update, PSG) are
//! orthogonal to the execution strategy, so the trainer's step loop is
//! written once against [`StepBackend`] and the strategy is picked by
//! `cfg.backend` (`config::BackendChoice`):
//!
//! * [`HostBackend`] — the legacy host path: the full [`ModelState`]
//!   converts in and out of the executing backend every step.  Kept as
//!   the equivalence baseline;
//! * [`ResidentBackend`] — state lives in a [`DeviceState`] across
//!   steps; only per-step inputs and metric outputs cross the host
//!   boundary (the single-executor default);
//! * [`ShardedBackend`] — data-parallel execution over an engine pool
//!   with the deterministic host-side all-reduce
//!   ([`super::shard::ShardedTrainer`]).
//!
//! All three are **bitwise interchangeable** for a fixed seed
//! (tests/backend_matrix.rs): they execute the same program(s) and every
//! host-side update goes through the one shared
//! `optim::update::apply_update`.  That is also the extension contract —
//! a real-PJRT collective all-reduce or a buffer-donating resident path
//! (ROADMAP) lands as a new `StepBackend` impl, not as trainer surgery.
//!
//! Checkpointing goes through [`StepBackend::export_for_checkpoint`]:
//! every backend can export its authoritative state as a host-side
//! [`ModelState`], which is why a checkpoint taken under one backend
//! resumes under any other ([`StepBackend::prepare`] re-derives the
//! backend-native form, rebroadcasting replicas where needed).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::BackendChoice;
use crate::util::fault::FaultPlan;

use super::device::DeviceState;
use super::engine::Engine;
use super::program::{EvalMetrics, ModelState, StepHyper, StepMetrics, TrainProgram};
use super::shard::ShardedTrainer;
use super::tensor::HostTensor;

/// One execution strategy for the training step loop.  The trainer owns
/// a `Box<dyn StepBackend>` and never matches on the concrete type.
pub trait StepBackend {
    /// Stable name recorded in run metrics and bench rows
    /// ("host" | "resident" | "sharded").
    fn name(&self) -> &'static str;

    /// Data-parallel shard count (0 for single-executor backends).
    fn shard_count(&self) -> usize {
        0
    }

    /// Arm this backend's fault-injection sites (tests/supervised runs).
    /// Single-executor backends have no backend-local sites — their
    /// step-level faults are injected by the trainer — so the default
    /// is a no-op; the sharded backend forwards the plan to its shard
    /// fan-out for in-place shard recovery.
    fn set_faults(&mut self, _plan: Arc<FaultPlan>) {}

    /// Attach an observability handle (`obs` subsystem).  Mirrors
    /// `set_faults`: single-executor backends are timed from the
    /// trainer's step loop, so the default is a no-op; the sharded
    /// backend forwards the handle to record per-shard execution time,
    /// reduce/apply spans and the shard imbalance counter.
    fn set_obs(&mut self, _obs: crate::obs::Obs) {}

    /// Execute one optimizer step on a full batch.
    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<StepMetrics>;

    /// Time one step without perturbing the run (the prefetch depth
    /// auto-tuner's denominator).  Implementations either step a cloned
    /// state or step for real and restore — either way the live state,
    /// RNG streams and metrics are untouched.
    fn probe_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<f64>;

    /// Materialize a host copy of the authoritative model state (SWA
    /// snapshots, serve publishing).
    fn sync_master(&self) -> Result<ModelState>;

    /// Push the authoritative state back out to any execution replicas
    /// (no-op for backends whose authority *is* the executing buffer).
    /// Today's backends call this internally where needed (the sharded
    /// step/probe restore); it is part of the trait surface because the
    /// real-PJRT collective backend (ROADMAP) needs an externally
    /// drivable replica refresh, and
    /// `exec::tests::rebroadcast_is_state_preserving` pins its contract
    /// for every impl.
    fn rebroadcast(&mut self) -> Result<()> {
        Ok(())
    }

    /// The state a durable checkpoint captures.  Host-side by contract,
    /// so checkpoints are backend-agnostic and cross-backend resume
    /// falls out of the abstraction (tests/backend_matrix.rs).
    fn export_for_checkpoint(&self) -> Result<ModelState> {
        self.sync_master()
    }

    /// Evaluate one batch against the live training state, using the
    /// cheapest route this backend has (resident state evaluates
    /// in place; host-side masters evaluate directly).
    fn eval_batch(&self, x: &HostTensor, y: &HostTensor) -> Result<EvalMetrics>;

    /// Consume into the final host state (end of run).
    fn into_state(self: Box<Self>) -> Result<ModelState>;
}

/// Build the backend `choice` selects around an initial host state.
/// This is the only place the trainer's configuration meets concrete
/// backend types.
/// `accum` is the sharded backend's gradient-accumulation factor
/// (micro-batches per logical step, >= 1; bitwise identical to 1 for
/// any value).  Single-executor backends ignore it, like `shards`.
pub fn prepare_backend<'p>(
    engine: &Engine,
    program: &'p TrainProgram,
    manifest_path: &Path,
    choice: BackendChoice,
    shards: usize,
    accum: usize,
    init: ModelState,
) -> Result<Box<dyn StepBackend + 'p>> {
    Ok(match choice {
        BackendChoice::Host => Box::new(HostBackend::prepare(program, init)),
        BackendChoice::Resident => Box::new(ResidentBackend::prepare(program, init)?),
        BackendChoice::Sharded => Box::new(ShardedBackend::prepare(
            engine,
            program,
            manifest_path,
            shards,
            accum,
            init,
        )?),
        // The planner (`coordinator::planner`) replaces Auto with a
        // concrete choice before any backend is prepared; reaching here
        // means a caller skipped planning.
        BackendChoice::Auto => bail!(
            "backend \"auto\" must be resolved by the planner before prepare_backend"
        ),
    })
}

// ==========================================================================
// Host
// ==========================================================================

/// Legacy host path: the authoritative state is a host [`ModelState`]
/// and every step converts it in and out of the executing backend.
pub struct HostBackend<'p> {
    program: &'p TrainProgram,
    state: ModelState,
}

impl<'p> HostBackend<'p> {
    pub fn prepare(program: &'p TrainProgram, init: ModelState) -> Self {
        Self { program, state: init }
    }
}

impl StepBackend for HostBackend<'_> {
    fn name(&self) -> &'static str {
        "host"
    }

    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<StepMetrics> {
        self.program.step(&mut self.state, x, y, hp, mask)
    }

    fn probe_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<f64> {
        let mut probe = self.state.clone();
        let t0 = Instant::now();
        self.program.step(&mut probe, x, y, hp, mask)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    fn sync_master(&self) -> Result<ModelState> {
        Ok(self.state.clone())
    }

    fn eval_batch(&self, x: &HostTensor, y: &HostTensor) -> Result<EvalMetrics> {
        self.program.eval_batch_run(&self.state, x, y)
    }

    fn into_state(self: Box<Self>) -> Result<ModelState> {
        Ok(self.state)
    }
}

// ==========================================================================
// Resident
// ==========================================================================

/// Device-resident path: the authoritative state lives in
/// backend-native buffers across steps and syncs to host only on demand.
pub struct ResidentBackend<'p> {
    program: &'p TrainProgram,
    state: DeviceState,
}

impl<'p> ResidentBackend<'p> {
    pub fn prepare(program: &'p TrainProgram, init: ModelState) -> Result<Self> {
        Ok(Self { program, state: program.upload_state(init)? })
    }
}

impl StepBackend for ResidentBackend<'_> {
    fn name(&self) -> &'static str {
        "resident"
    }

    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<StepMetrics> {
        self.program.step_device(&mut self.state, x, y, hp, mask)
    }

    fn probe_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<f64> {
        let mut probe = self.state.clone();
        let t0 = Instant::now();
        self.program.step_device(&mut probe, x, y, hp, mask)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    fn sync_master(&self) -> Result<ModelState> {
        self.state.sync_to_host()
    }

    fn eval_batch(&self, x: &HostTensor, y: &HostTensor) -> Result<EvalMetrics> {
        self.program.eval_batch_device(&self.state, x, y)
    }

    fn into_state(self: Box<Self>) -> Result<ModelState> {
        self.state.into_host()
    }
}

// ==========================================================================
// Sharded
// ==========================================================================

/// Data-parallel path: wraps [`ShardedTrainer`] (per-shard grad
/// programs over resident replicas, fixed-order host all-reduce, the
/// shared update on a host-side master, replica rebroadcast).
pub struct ShardedBackend<'p> {
    program: &'p TrainProgram,
    inner: ShardedTrainer,
}

impl<'p> ShardedBackend<'p> {
    pub fn prepare(
        engine: &Engine,
        program: &'p TrainProgram,
        manifest_path: &Path,
        shards: usize,
        accum: usize,
        init: ModelState,
    ) -> Result<Self> {
        let mut inner = ShardedTrainer::new(engine, manifest_path, shards, init)?;
        inner.set_accum(accum);
        Ok(Self { program, inner })
    }
}

impl StepBackend for ShardedBackend<'_> {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn shard_count(&self) -> usize {
        self.inner.num_shards()
    }

    fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.inner.set_faults(plan);
    }

    fn set_obs(&mut self, obs: crate::obs::Obs) {
        self.inner.set_obs(obs);
    }

    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<StepMetrics> {
        if mask.is_some() {
            bail!("sharded training does not support SD masks");
        }
        self.inner.step(x, y, hp)
    }

    fn probe_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        hp: StepHyper,
        mask: Option<&[f32]>,
    ) -> Result<f64> {
        if mask.is_some() {
            bail!("sharded training does not support SD masks");
        }
        self.inner.probe_step(x, y, hp)
    }

    fn sync_master(&self) -> Result<ModelState> {
        // The master already lives host-side: no device round-trip.
        Ok(self.inner.state().clone())
    }

    fn rebroadcast(&mut self) -> Result<()> {
        self.inner.rebroadcast()
    }

    fn eval_batch(&self, x: &HostTensor, y: &HostTensor) -> Result<EvalMetrics> {
        self.program.eval_batch_run(self.inner.state(), x, y)
    }

    fn into_state(self: Box<Self>) -> Result<ModelState> {
        Ok(self.inner.into_state())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, AugmentCfg, Sampler};
    use crate::runtime::{write_reference_family, RefFamilySpec};
    use crate::util::tmp::TempDir;

    fn backends<'p>(
        engine: &Engine,
        program: &'p TrainProgram,
        manifest: &Path,
        init: &ModelState,
    ) -> Vec<Box<dyn StepBackend + 'p>> {
        vec![
            prepare_backend(
                engine,
                program,
                manifest,
                BackendChoice::Host,
                0,
                1,
                init.clone(),
            )
            .unwrap(),
            prepare_backend(
                engine,
                program,
                manifest,
                BackendChoice::Resident,
                0,
                1,
                init.clone(),
            )
            .unwrap(),
            // Pipelined by default, with gradient accumulation on — the
            // bitwise contract must hold with the new machinery engaged.
            prepare_backend(
                engine,
                program,
                manifest,
                BackendChoice::Sharded,
                2,
                2,
                init.clone(),
            )
            .unwrap(),
        ]
    }

    /// Step-granularity contract: the three backends agree bitwise on
    /// metrics, synced masters and eval — including after a probe step,
    /// which must be invisible everywhere.
    #[test]
    fn backends_agree_bitwise_at_step_granularity() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("e2train.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let data = synthetic::generate(10, 64, 8, 3);
        let init = ModelState::init(&prog.manifest, 11);
        let hp = StepHyper { lr: 0.03, alpha: 1.5, beta: 0.05 };

        let mut bs = backends(&engine, &prog, &manifest, &init);
        assert_eq!(
            bs.iter().map(|b| b.name()).collect::<Vec<_>>(),
            vec!["host", "resident", "sharded"]
        );
        assert_eq!(
            bs.iter().map(|b| b.shard_count()).collect::<Vec<_>>(),
            vec![0, 0, 2]
        );

        let mut sampler = Sampler::new(data.n, prog.batch(), AugmentCfg::default(), 5);
        for step in 0..4 {
            let (x, y) = sampler.next_batch(&data);
            if step == 2 {
                for b in bs.iter_mut() {
                    assert!(b.probe_step(&x, &y, hp, None).unwrap() > 0.0);
                }
            }
            let sms: Vec<StepMetrics> = bs
                .iter_mut()
                .map(|b| b.train_step(&x, &y, hp, None).unwrap())
                .collect();
            for sm in &sms[1..] {
                assert_eq!(sms[0].loss, sm.loss, "step {step}");
                assert_eq!(sms[0].correct, sm.correct, "step {step}");
                assert_eq!(sms[0].gate_fracs, sm.gate_fracs, "step {step}");
                assert_eq!(sms[0].psg_frac, sm.psg_frac, "step {step}");
            }
            let masters: Vec<ModelState> =
                bs.iter().map(|b| b.sync_master().unwrap()).collect();
            for m in &masters[1..] {
                masters[0].assert_bitwise_eq(m);
            }
            // export_for_checkpoint routes through the same master
            for b in bs.iter() {
                masters[0].assert_bitwise_eq(&b.export_for_checkpoint().unwrap());
            }
        }

        // Eval off the live state agrees bitwise too.
        let eb = prog.eval_batch();
        let hw = prog.manifest.arch.image_size;
        let ex = HostTensor::f32(vec![eb, hw, hw, 3], vec![0.25; eb * hw * hw * 3]);
        let ey = HostTensor::i32(vec![eb], vec![1; eb]);
        let evals: Vec<EvalMetrics> =
            bs.iter().map(|b| b.eval_batch(&ex, &ey).unwrap()).collect();
        for e in &evals[1..] {
            assert_eq!(evals[0].loss, e.loss);
            assert_eq!(evals[0].correct, e.correct);
        }

        // into_state agrees with the final synced master.
        let want = bs[0].sync_master().unwrap();
        for b in bs {
            want.assert_bitwise_eq(&b.into_state().unwrap());
        }
    }

    /// `rebroadcast` is callable on every backend (a no-op off the
    /// sharded path) and never perturbs the authoritative state.
    #[test]
    fn rebroadcast_is_state_preserving() {
        let tmp = TempDir::new().unwrap();
        let fam = write_reference_family(tmp.path(), &RefFamilySpec::tiny()).unwrap();
        let engine = Engine::cpu().unwrap();
        let manifest = fam.join("sgd32.json");
        let prog = TrainProgram::load(&engine, &manifest).unwrap();
        let init = ModelState::init(&prog.manifest, 0);
        for mut b in backends(&engine, &prog, &manifest, &init) {
            let before = b.sync_master().unwrap();
            b.rebroadcast().unwrap();
            before.assert_bitwise_eq(&b.sync_master().unwrap());
        }
    }
}
