//! Device-resident training state.
//!
//! The seed runtime re-uploaded the entire `ModelState` (params +
//! momenta + BN state) to the device every `step()` and downloaded it
//! all back afterwards, even though the state only matters *on device*
//! between steps.  [`DeviceState`] keeps the state in the executing
//! backend's native representation across steps; per iteration only the
//! small per-step inputs (batch, labels, scalars, SD mask) cross the
//! host boundary in, and only the metric outputs cross back out.
//! [`DeviceState::sync_to_host`] materializes a full `ModelState`
//! exactly when something host-side needs one: SWA averaging, eval on
//! the host path, fine-tune handoff, checkpointing.
//!
//! Backend representations:
//! * reference backend — the buffer *is* host memory, so residency means
//!   zero conversions (the host path pays literal conversions both ways);
//! * PJRT backend — the buffer is a staged `xla::Literal`, so residency
//!   halves the per-step conversions (outputs feed the next step
//!   directly instead of bouncing through `HostTensor`).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::engine::BackendKind;
use super::program::ModelState;
use super::tensor::HostTensor;

/// One tensor in backend-native form.
#[derive(Debug, Clone)]
pub enum DeviceValue {
    /// Reference backend: plain host memory, moved not copied.
    Host(HostTensor),
    /// PJRT backend: a staged literal (device buffer in a real build).
    Literal(xla::Literal),
}

impl DeviceValue {
    pub fn from_host(backend: BackendKind, t: HostTensor) -> Result<Self> {
        Ok(match backend {
            BackendKind::Reference => DeviceValue::Host(t),
            BackendKind::Pjrt => DeviceValue::Literal(t.to_literal()?),
        })
    }

    /// Copy out to host memory.
    pub fn to_host(&self) -> Result<HostTensor> {
        match self {
            DeviceValue::Host(t) => Ok(t.clone()),
            DeviceValue::Literal(l) => HostTensor::from_literal(l),
        }
    }

    /// Move out to host memory (free for the reference backend).
    pub fn into_host(self) -> Result<HostTensor> {
        match self {
            DeviceValue::Host(t) => Ok(t),
            DeviceValue::Literal(l) => HostTensor::from_literal(&l),
        }
    }
}

/// Borrowed executable input: either an already-resident value or a
/// host tensor staged for this call only (batch data, scalars, masks).
#[derive(Clone, Copy)]
pub enum ValueRef<'a> {
    Dev(&'a DeviceValue),
    Host(&'a HostTensor),
}

/// Model state living in backend-native buffers across steps.
#[derive(Clone)]
pub struct DeviceState {
    pub values: Vec<DeviceValue>,
    pub names: Vec<String>,
    backend: BackendKind,
}

impl DeviceState {
    /// Move a host `ModelState` into backend-native form (one-time cost
    /// at run start / fine-tune handoff).
    pub fn upload(backend: BackendKind, state: ModelState) -> Result<Self> {
        let (values, names) = state.into_parts();
        let values = values
            .into_iter()
            .map(|t| DeviceValue::from_host(backend, t))
            .collect::<Result<_>>()?;
        Ok(Self { values, names, backend })
    }

    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    pub fn num_tensors(&self) -> usize {
        self.values.len()
    }

    /// Materialize a host `ModelState` — the only place state crosses
    /// device->host.  Called on demand (SWA snapshot, eval handoff,
    /// checkpoint, end of run), never per step.
    pub fn sync_to_host(&self) -> Result<ModelState> {
        let values = self
            .values
            .iter()
            .map(DeviceValue::to_host)
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelState::new(values, self.names.clone()))
    }

    /// Move out to a host `ModelState`, consuming the device buffers
    /// (end-of-run path; avoids the final copy on the reference backend).
    pub fn into_host(self) -> Result<ModelState> {
        let values = self
            .values
            .into_iter()
            .map(DeviceValue::into_host)
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelState::new(values, self.names))
    }

    /// Replace tensor `i` with a fresh host value — the sharded-training
    /// rebroadcast: after the host-side all-reduce applies an update to
    /// the master state, every shard's resident replica refreshes the
    /// tensors that changed (params + persistent state; momenta never
    /// leave the host on the sharded path).
    pub fn refresh_from_host(&mut self, i: usize, t: HostTensor) -> Result<()> {
        if i >= self.values.len() {
            anyhow::bail!(
                "refresh index {i} out of range ({} resident tensors)",
                self.values.len()
            );
        }
        self.values[i] = DeviceValue::from_host(self.backend, t)?;
        Ok(())
    }

    /// Publishable read-only copy of this state (full train-state order).
    /// The copy is cheap relative to its cadence: publishing happens at
    /// checkpoint moments (SWA snapshots, end of run), never per step.
    pub fn snapshot(&self) -> StateSnapshot {
        StateSnapshot {
            values: Arc::new(self.values.clone()),
            names: Arc::new(self.names.clone()),
            backend: self.backend,
            version: 0,
        }
    }
}

/// An immutable, shareable copy of a model state in backend-native form,
/// ordered like `DeviceState::values` (params, momenta, bn state).  The
/// serve worker pool evaluates straight against one of these; `version`
/// identifies which published checkpoint served a request.
#[derive(Clone)]
pub struct StateSnapshot {
    pub values: Arc<Vec<DeviceValue>>,
    pub names: Arc<Vec<String>>,
    pub backend: BackendKind,
    /// Assigned by [`SnapshotCell::publish`]; 0 before publication.
    pub version: u64,
}

impl StateSnapshot {
    /// Build a snapshot from a host state (e.g. the SWA running average,
    /// which lives host-side).
    pub fn from_model_state(backend: BackendKind, state: &ModelState) -> Result<Self> {
        let values = state
            .values
            .iter()
            .map(|t| DeviceValue::from_host(backend, t.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            values: Arc::new(values),
            names: Arc::new(state.names.clone()),
            backend,
            version: 0,
        })
    }
}

/// The publish/subscribe handle between a training loop and readers
/// (the serve worker pool): the trainer publishes checkpoints, readers
/// `load()` the current one per micro-batch.  Swapping is atomic with
/// respect to readers — in-flight batches finish on the snapshot they
/// loaded, new batches see the new one; the queue never drains.
#[derive(Default)]
pub struct SnapshotCell {
    slot: Mutex<(u64, Option<Arc<StateSnapshot>>)>,
}

impl SnapshotCell {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a snapshot, stamping it with the next version.  Returns
    /// the version assigned.
    pub fn publish(&self, mut snap: StateSnapshot) -> u64 {
        let mut slot = self.slot.lock().unwrap();
        slot.0 += 1;
        snap.version = slot.0;
        slot.1 = Some(Arc::new(snap));
        slot.0
    }

    /// The currently-published snapshot, if any.
    pub fn load(&self) -> Option<Arc<StateSnapshot>> {
        self.slot.lock().unwrap().1.clone()
    }

    /// Version of the latest published snapshot (0 = nothing published).
    pub fn version(&self) -> u64 {
        self.slot.lock().unwrap().0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_state() -> ModelState {
        ModelState::new(
            vec![
                HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
                HostTensor::f32(vec![3], vec![-1.0, 0.5, 9.0]),
            ],
            vec!["w".into(), "b".into()],
        )
    }

    #[test]
    fn reference_roundtrip_is_bitwise() {
        let host = toy_state();
        let dev = DeviceState::upload(BackendKind::Reference, host.clone()).unwrap();
        let back = dev.sync_to_host().unwrap();
        assert_eq!(back.names, host.names);
        for (a, b) in back.values.iter().zip(host.values.iter()) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        // by-name lookup survives the round trip
        assert_eq!(back.by_name("b").unwrap().as_f32().unwrap(), &[-1.0, 0.5, 9.0]);
    }

    #[test]
    fn pjrt_staging_roundtrip_is_bitwise() {
        let host = toy_state();
        let dev = DeviceState::upload(BackendKind::Pjrt, host.clone()).unwrap();
        assert!(matches!(dev.values[0], DeviceValue::Literal(_)));
        let back = dev.into_host().unwrap();
        for (a, b) in back.values.iter().zip(host.values.iter()) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn snapshot_cell_publishes_and_versions() {
        let cell = SnapshotCell::new();
        assert!(cell.load().is_none());
        assert_eq!(cell.version(), 0);

        let host = toy_state();
        let dev = DeviceState::upload(BackendKind::Reference, host.clone()).unwrap();
        let v1 = cell.publish(dev.snapshot());
        assert_eq!(v1, 1);
        let snap = cell.load().unwrap();
        assert_eq!(snap.version, 1);
        assert_eq!(snap.names.as_slice(), host.names.as_slice());

        // Readers holding the old Arc keep it across a swap.
        let v2 = cell.publish(StateSnapshot::from_model_state(
            BackendKind::Reference,
            &host,
        )
        .unwrap());
        assert_eq!(v2, 2);
        assert_eq!(snap.version, 1, "held snapshot must be immutable");
        assert_eq!(cell.load().unwrap().version, 2);
    }

    #[test]
    fn snapshot_matches_state_values() {
        let host = toy_state();
        let dev = DeviceState::upload(BackendKind::Reference, host.clone()).unwrap();
        let snap = dev.snapshot();
        assert_eq!(snap.values.len(), host.num_tensors());
        for (dv, hv) in snap.values.iter().zip(host.values.iter()) {
            assert_eq!(dv.to_host().unwrap().as_f32().unwrap(), hv.as_f32().unwrap());
        }
    }
}
