//! Layer-3 runtime: load AOT artifacts and execute them — multi-backend.
//!
//! * [`engine`] — client + compiled-executable cache (PJRT HLO text or
//!   pure-rust reference programs behind one `Program` type).
//! * [`manifest`] — the JSON contract emitted by `python/compile/aot.py`.
//! * [`tensor`] — host tensors and Literal conversion.
//! * [`device`] — device-resident training state ([`DeviceState`]): the
//!   model stays in backend-native buffers across steps and syncs to
//!   host only when SWA/eval/checkpointing needs it.
//! * [`pool`] — per-worker engine pool sharing one program cache (the
//!   fan-out structure that stays sound when `Engine` loses `Sync`).
//! * [`program`] — (train, eval) executable pairs + state plumbing, with
//!   a host step path, a resident step path, an eval-only load for serve
//!   workers, and a snapshot eval path for the serving workload.
//! * [`exec`] — the execution layer: [`exec::StepBackend`] abstracts
//!   *where* a step runs (host / resident / sharded) behind one trait
//!   the trainer's loop is written against; see ARCHITECTURE.md.
//! * [`reduce`] — the fixed-shape parallel reduction tree: bisects the
//!   gradient *element* axis across host threads while every element
//!   still accumulates in global sample order, so the tree is bitwise
//!   identical to the sequential fold by construction.
//! * [`shard`] — data-parallel sharded training over an engine pool with
//!   a deterministic (fixed-order, bitwise-reproducible) host-side
//!   all-reduce of per-sample gradient contributions, pipelined across
//!   micro-batches onto a dedicated reducer thread.
//! * [`reference`] — the pure-rust reference backend + fixture
//!   generator; keeps the whole stack executable without a PJRT runtime.

pub mod device;
pub mod engine;
pub mod exec;
pub mod manifest;
pub mod pool;
pub mod program;
pub mod reduce;
pub mod reference;
pub mod shard;
pub mod tensor;

pub use device::{DeviceState, DeviceValue, SnapshotCell, StateSnapshot, ValueRef};
pub use engine::{BackendKind, Engine, Program, SharedProgramCache};
pub use exec::{
    prepare_backend, HostBackend, ResidentBackend, ShardedBackend, StepBackend,
};
pub use manifest::{ArtifactIndex, BlockInfo, IoSpec, Manifest, MethodInfo};
pub use pool::EnginePool;
pub use program::{
    EvalMetrics, EvalOutput, ModelState, StepHyper, StepMetrics, TrainProgram,
};
pub use reduce::{fold_sequential, fold_tree, tree_depth, MAX_TREE_DEPTH, REDUCE_GRAIN};
pub use shard::ShardedTrainer;
pub use reference::{
    row_argmax, row_rank, row_softmax_loss, write_reference_family, RefFamilySpec,
};
pub use tensor::{HostTensor, TensorData};
