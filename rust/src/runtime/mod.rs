//! Layer-3 runtime: load AOT artifacts and execute them — multi-backend.
//!
//! * [`engine`] — client + compiled-executable cache (PJRT HLO text or
//!   pure-rust reference programs behind one `Program` type).
//! * [`manifest`] — the JSON contract emitted by `python/compile/aot.py`.
//! * [`tensor`] — host tensors and Literal conversion.
//! * [`device`] — device-resident training state ([`DeviceState`]): the
//!   model stays in backend-native buffers across steps and syncs to
//!   host only when SWA/eval/checkpointing needs it.
//! * [`program`] — (train, eval) executable pairs + state plumbing, with
//!   a host step path and a resident step path.
//! * [`reference`] — the pure-rust reference backend + fixture
//!   generator; keeps the whole stack executable without a PJRT runtime.

pub mod device;
pub mod engine;
pub mod manifest;
pub mod program;
pub mod reference;
pub mod tensor;

pub use device::{DeviceState, DeviceValue, ValueRef};
pub use engine::{BackendKind, Engine, Program};
pub use manifest::{ArtifactIndex, BlockInfo, IoSpec, Manifest, MethodInfo};
pub use program::{EvalMetrics, ModelState, StepHyper, StepMetrics, TrainProgram};
pub use reference::{write_reference_family, RefFamilySpec};
pub use tensor::{HostTensor, TensorData};
