//! Layer-3 runtime: load AOT artifacts (HLO text) and execute them on the
//! PJRT CPU client — the `xla` crate path proven by /opt/xla-example.
//!
//! * [`engine`] — PJRT client + compiled-executable cache.
//! * [`manifest`] — the JSON contract emitted by `python/compile/aot.py`.
//! * [`tensor`] — host tensors and Literal conversion.
//! * [`program`] — (train, eval) executable pairs + model-state plumbing.

pub mod engine;
pub mod manifest;
pub mod program;
pub mod tensor;

pub use engine::{Engine, Program};
pub use manifest::{ArtifactIndex, BlockInfo, IoSpec, Manifest, MethodInfo};
pub use program::{EvalMetrics, ModelState, StepHyper, StepMetrics, TrainProgram};
pub use tensor::{HostTensor, TensorData};
