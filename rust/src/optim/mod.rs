//! Host-side optimizer bookkeeping: parameter initialization, learning
//! rate schedules, stochastic weight averaging — and the one shared
//! update application ([`update::apply_update`]).  For AOT artifacts the
//! update rules (SGD-momentum / SignSGD / PSG, Sec. 3.3) are baked into
//! the lowered train step; every host-side apply (the reference
//! interpreter, the sharded all-reduce path) goes through
//! `optim::update` so the wd/PSG/momentum/gates/run_mean semantics live
//! in exactly one place.

pub mod init;
pub mod schedule;
pub mod update;

pub use init::Initializer;
pub use schedule::{LrSchedule, SwaState};
