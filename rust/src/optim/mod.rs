//! Host-side optimizer bookkeeping: parameter initialization, learning
//! rate schedules, and stochastic weight averaging.  The update rules
//! themselves (SGD-momentum / SignSGD / PSG, Sec. 3.3) are baked into the
//! AOT train-step artifacts; rust owns everything *around* them.

pub mod init;
pub mod schedule;

pub use init::Initializer;
pub use schedule::{LrSchedule, SwaState};
