//! Learning-rate schedules (Sec. 4.1).
//!
//! The paper trains 64k iterations, lr 0.1 decayed 10x at 32k and 48k;
//! PSG/SignSGD variants start at 0.03.  When an SMB baseline is run with a
//! reduced iteration budget (Fig. 3a), the decay boundaries scale
//! proportionally — `scaled_to` implements exactly that protocol.

#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// Piecewise-constant: lr0 multiplied by `decay` at each boundary.
    Step {
        lr0: f64,
        decay: f64,
        /// Iteration indices where the decay is applied.
        boundaries: Vec<u64>,
    },
    /// Constant (grid-search comparisons of Fig. 3b).
    Constant { lr0: f64 },
}

impl LrSchedule {
    /// The paper's default protocol scaled to `total_iters`: boundaries at
    /// 1/2 and 3/4 of the run (32k/48k out of 64k).
    pub fn paper_default(lr0: f64, total_iters: u64) -> Self {
        LrSchedule::Step {
            lr0,
            decay: 0.1,
            boundaries: vec![total_iters / 2, total_iters * 3 / 4],
        }
    }

    pub fn at(&self, iter: u64) -> f64 {
        match self {
            LrSchedule::Constant { lr0 } => *lr0,
            LrSchedule::Step { lr0, decay, boundaries } => {
                let k = boundaries.iter().filter(|&&b| iter >= b).count();
                lr0 * decay.powi(k as i32)
            }
        }
    }

    /// Rescale boundaries proportionally to a new total-iteration budget
    /// (the Fig. 3a SMB-with-fewer-iterations protocol).
    pub fn scaled_to(&self, old_total: u64, new_total: u64) -> Self {
        match self {
            LrSchedule::Constant { .. } => self.clone(),
            LrSchedule::Step { lr0, decay, boundaries } => LrSchedule::Step {
                lr0: *lr0,
                decay: *decay,
                boundaries: boundaries
                    .iter()
                    .map(|&b| (b as u128 * new_total as u128 / old_total.max(1) as u128) as u64)
                    .collect(),
            },
        }
    }
}

/// Stochastic weight averaging bookkeeping (SWALP-style [64]): the paper
/// enables SWA when PSG is in play to stabilize sign-based updates.
/// The coordinator calls `observe()` at each averaging point; `weight()`
/// is the running-average weight for the incoming model.
#[derive(Debug, Clone, Default)]
pub struct SwaState {
    pub n_models: u64,
    /// Start averaging only after this iteration (post first decay).
    pub start_iter: u64,
    /// Average every `period` iterations.
    pub period: u64,
}

impl SwaState {
    pub fn new(start_iter: u64, period: u64) -> Self {
        Self { n_models: 0, start_iter, period: period.max(1) }
    }

    pub fn should_average(&self, iter: u64) -> bool {
        iter >= self.start_iter && (iter - self.start_iter) % self.period == 0
    }

    /// Weight the incoming model gets in the running average.
    pub fn observe(&mut self) -> f32 {
        self.n_models += 1;
        1.0 / self.n_models as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schedule_boundaries() {
        let s = LrSchedule::paper_default(0.1, 64_000);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(31_999), 0.1);
        assert!((s.at(32_000) - 0.01).abs() < 1e-12);
        assert!((s.at(48_000) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn scaling_preserves_fractions() {
        let s = LrSchedule::paper_default(0.1, 64_000).scaled_to(64_000, 1_000);
        assert_eq!(s.at(499), 0.1);
        assert!((s.at(500) - 0.01).abs() < 1e-12);
        assert!((s.at(750) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant { lr0: 0.14 };
        assert_eq!(s.at(0), s.at(1_000_000));
    }

    #[test]
    fn swa_weights_form_running_mean() {
        let mut swa = SwaState::new(100, 10);
        assert!(!swa.should_average(99));
        assert!(swa.should_average(100));
        assert!(swa.should_average(110));
        assert!(!swa.should_average(111));
        assert_eq!(swa.observe(), 1.0);
        assert_eq!(swa.observe(), 0.5);
        assert!((swa.observe() - 1.0 / 3.0).abs() < 1e-7);
    }
}
