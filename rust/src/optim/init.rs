//! Parameter initialization — rust owns all model state; the manifest's
//! `init` kinds mirror python `layers.materialize` in distribution
//! (He-normal for conv/fc weights [63], zeros/ones for BN and biases,
//! fan-in uniform for the gate LSTM).

use crate::runtime::HostTensor;
use crate::util::Rng;

pub struct Initializer {
    rng: Rng,
}

impl Initializer {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::seed_from_u64(seed) }
    }

    fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn materialize(&mut self, shape: &[usize], kind: &str) -> HostTensor {
        let n = shape.iter().product::<usize>().max(1);
        let data: Vec<f32> = match kind {
            "he" => {
                // fan_in = prod(shape[..-1]) matching python materialize.
                let fan_in = if shape.len() > 1 {
                    shape[..shape.len() - 1].iter().product::<usize>()
                } else {
                    shape.first().copied().unwrap_or(1)
                }
                .max(1);
                let std = (2.0 / fan_in as f32).sqrt();
                (0..n).map(|_| self.normal() * std).collect()
            }
            "ones" => vec![1.0; n],
            "uniform" => {
                let bound = 1.0 / (shape.first().copied().unwrap_or(1).max(1) as f32).sqrt();
                (0..n).map(|_| self.rng.range_f32(-bound, bound)).collect()
            }
            // zeros (momenta, biases) and anything unknown default to 0.
            _ => vec![0.0; n],
        };
        HostTensor::f32(shape.to_vec(), data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn he_statistics() {
        let mut init = Initializer::new(7);
        let t = init.materialize(&[3, 3, 16, 32], "he");
        let v = t.as_f32().unwrap();
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 =
            v.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / v.len() as f32;
        let expect = 2.0 / (3.0 * 3.0 * 16.0);
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - expect).abs() / expect < 0.15, "var {var} vs {expect}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Initializer::new(5).materialize(&[64], "he");
        let b = Initializer::new(5).materialize(&[64], "he");
        let c = Initializer::new(6).materialize(&[64], "he");
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        assert_ne!(a.as_f32().unwrap(), c.as_f32().unwrap());
    }

    #[test]
    fn kinds() {
        let mut init = Initializer::new(0);
        assert!(init
            .materialize(&[4], "ones")
            .as_f32()
            .unwrap()
            .iter()
            .all(|&v| v == 1.0));
        assert!(init
            .materialize(&[4], "zeros")
            .as_f32()
            .unwrap()
            .iter()
            .all(|&v| v == 0.0));
        let u = init.materialize(&[16, 40], "uniform");
        let bound = 1.0 / 4.0;
        assert!(u.as_f32().unwrap().iter().all(|&v| v.abs() <= bound));
    }
}
