//! The optimizer update, in exactly one place.
//!
//! Every execution backend reaches the same parameter update through
//! [`apply_update`]: weight decay on weight matrices, PSG predictor
//! telemetry over the decayed gradients, momentum SGD, the analytic
//! learned-gate update, and the running-mean EMA of hidden activations.
//! The reference train-step interpreter (`runtime::reference::run_train`)
//! and the sharded host-side apply (`runtime::shard`) used to mirror
//! this math expression-for-expression in two files; the bitwise
//! equivalence contracts (tests/{resident,shard}_equivalence.rs,
//! tests/backend_matrix.rs) rested on that mirror never drifting.  Now
//! they rest on there being nothing to mirror.
//!
//! Bitwise discipline: callers hand in *reduced* gradients (and reduced
//! hidden-activation column sums) accumulated in the canonical global
//! sample order; this function performs only element-wise arithmetic in
//! input order, with every expression written exactly once.  Identical
//! inputs therefore produce bit-identical outputs on every backend.

/// Scalar knobs of one update application.
#[derive(Debug, Clone, Copy)]
pub struct UpdateCfg {
    pub lr: f32,
    /// Eq. (1) FLOPs-regularizer weight (learned gating only; unused
    /// otherwise).
    pub alpha: f32,
    /// PSG adaptive-threshold ratio (psg update only; unused otherwise).
    pub beta: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Whether the method's update rule is "psg" (emit predictor
    /// telemetry over the decayed gradients).
    pub psg: bool,
    /// Global batch size — the denominator of the running-mean EMA.
    pub batch: f32,
}

/// One non-gate trainable parameter entering the update: current value,
/// momentum buffer, and the *reduced* raw gradient (weight decay is
/// applied in here, not by the caller).
pub struct ParamIn<'a> {
    pub w: &'a [f32],
    pub mom: &'a [f32],
    pub grad: Vec<f32>,
    /// Weight decay applies to weight matrices (rank >= 2), not biases.
    pub decay: bool,
}

/// The learned-gate parameter (batch-independent analytic gradient).
pub struct GateIn<'a> {
    pub w: &'a [f32],
    pub mom: &'a [f32],
}

/// The running-mean persistent state: current value plus per-column
/// sums of this step's hidden activations (accumulated by the caller in
/// global sample order).
pub struct RunMeanIn<'a> {
    pub current: &'a [f32],
    pub col_sums: Vec<f32>,
}

/// Updated gate parameter + the pre-update activation fractions the
/// energy ledger charges.
pub struct GateOut {
    pub w: Vec<f32>,
    pub mom: Vec<f32>,
    pub fracs: Vec<f32>,
}

/// Everything [`apply_update`] produces, in the input order of `params`.
pub struct UpdateOut {
    /// `(new_w, new_mom)` per [`ParamIn`], same order.
    pub params: Vec<(Vec<f32>, Vec<f32>)>,
    pub gate: Option<GateOut>,
    pub run_mean: Option<Vec<f32>>,
    /// Fraction of gradient entries the PSG MSB predictor would resolve
    /// (`cfg.psg` only).
    pub psg_frac: Option<f32>,
}

/// Apply one optimizer update: wd -> PSG telemetry -> momentum SGD ->
/// gates -> run_mean, each expression written once, evaluated in a
/// fixed order.
pub fn apply_update(
    cfg: &UpdateCfg,
    mut params: Vec<ParamIn>,
    gate: Option<GateIn>,
    run_mean: Option<RunMeanIn>,
) -> UpdateOut {
    // ---- weight decay on weight matrices (biases exempt) -------------
    let wd = cfg.weight_decay;
    for p in params.iter_mut().filter(|p| p.decay) {
        for (g, w) in p.grad.iter_mut().zip(p.w) {
            *g += wd * *w;
        }
    }

    // ---- PSG predictor telemetry over the decayed gradients ----------
    // Entries small relative to the per-step max are the ones the MSB
    // predictor resolves (Sec. 3.3).
    let psg_frac = if cfg.psg {
        let beta = cfg.beta;
        let gmax = params
            .iter()
            .flat_map(|p| p.grad.iter())
            .fold(0f32, |m, &v| m.max(v.abs()));
        if gmax > 0.0 {
            let total: usize = params.iter().map(|p| p.grad.len()).sum();
            let confident = params
                .iter()
                .flat_map(|p| p.grad.iter())
                .filter(|v| v.abs() <= beta * gmax)
                .count();
            Some(confident as f32 / total as f32)
        } else {
            Some(0.0)
        }
    } else {
        None
    };

    // ---- momentum SGD ------------------------------------------------
    let mu = cfg.momentum;
    let lr = cfg.lr;
    let new_params: Vec<(Vec<f32>, Vec<f32>)> = params
        .iter()
        .map(|p| {
            let mut nw = Vec::with_capacity(p.w.len());
            let mut nm = Vec::with_capacity(p.mom.len());
            for i in 0..p.w.len() {
                let mi = mu * p.mom[i] + p.grad[i];
                nm.push(mi);
                nw.push(p.w[i] - lr * mi);
            }
            (nw, nm)
        })
        .collect();

    // ---- learned gates: batch-independent, applied analytically ------
    // The FLOPs regularizer (Eq. 1 analog): alpha pushes the gate
    // logits down; the reported fraction is the pre-update activity.
    let gate_out = gate.map(|gp| {
        let alpha = cfg.alpha;
        let g = gp.w.len().max(1) as f32;
        let mut fracs = Vec::with_capacity(gp.w.len());
        let mut ngw = Vec::with_capacity(gp.w.len());
        let mut ngm = Vec::with_capacity(gp.w.len());
        for i in 0..gp.w.len() {
            let sig = 1.0 / (1.0 + (-gp.w[i]).exp());
            fracs.push(sig);
            let grad = alpha * sig * (1.0 - sig) / g;
            let mi = mu * gp.mom[i] + grad;
            ngm.push(mi);
            ngw.push(gp.w[i] - lr * mi);
        }
        GateOut { w: ngw, mom: ngm, fracs }
    });

    // ---- running-mean state: EMA over the batch-mean activation ------
    let run_mean_out = run_mean.map(|rm| {
        rm.current
            .iter()
            .zip(rm.col_sums.iter())
            .map(|(&cur, &s)| 0.9 * cur + 0.1 * s / cfg.batch)
            .collect()
    });

    UpdateOut {
        params: new_params,
        gate: gate_out,
        run_mean: run_mean_out,
        psg_frac,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> UpdateCfg {
        UpdateCfg {
            lr: 0.1,
            alpha: 2.0,
            beta: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            psg: true,
            batch: 4.0,
        }
    }

    #[test]
    fn momentum_and_decay_follow_the_reference_expressions() {
        let w = [1.0f32, -2.0];
        let m = [0.5f32, 0.0];
        let out = apply_update(
            &cfg(),
            vec![ParamIn { w: &w, mom: &m, grad: vec![0.2, -0.1], decay: true }],
            None,
            None,
        );
        let (nw, nm) = &out.params[0];
        // grad after decay: g + wd*w
        let g0 = 0.2 + 1e-4 * 1.0;
        let g1 = -0.1 + 1e-4 * -2.0;
        assert_eq!(nm[0], 0.9 * 0.5 + g0);
        assert_eq!(nm[1], 0.9 * 0.0 + g1);
        assert_eq!(nw[0], 1.0 - 0.1 * nm[0]);
        assert_eq!(nw[1], -2.0 - 0.1 * nm[1]);
    }

    #[test]
    fn biases_are_not_decayed() {
        let w = [1.0f32];
        let m = [0.0f32];
        let out = apply_update(
            &cfg(),
            vec![ParamIn { w: &w, mom: &m, grad: vec![0.0], decay: false }],
            None,
            None,
        );
        // No decay, zero grad, zero momentum: the weight must not move.
        assert_eq!(out.params[0].0[0], 1.0);
    }

    #[test]
    fn psg_counts_confident_entries_after_decay() {
        // grads 1.0 and 0.04 with beta 0.05: only the small one is
        // within beta * gmax.
        let w = [0.0f32, 0.0];
        let m = [0.0f32, 0.0];
        let out = apply_update(
            &cfg(),
            vec![ParamIn { w: &w, mom: &m, grad: vec![1.0, 0.04], decay: false }],
            None,
            None,
        );
        assert_eq!(out.psg_frac, Some(0.5));
        // All-zero gradients report 0.0, not NaN.
        let out = apply_update(
            &cfg(),
            vec![ParamIn { w: &w, mom: &m, grad: vec![0.0, 0.0], decay: false }],
            None,
            None,
        );
        assert_eq!(out.psg_frac, Some(0.0));
    }

    #[test]
    fn gate_update_reports_pre_update_activity() {
        let gw = [0.0f32, 0.0];
        let gm = [0.0f32, 0.0];
        let out = apply_update(
            &cfg(),
            Vec::new(),
            Some(GateIn { w: &gw, mom: &gm }),
            None,
        );
        let gate = out.gate.unwrap();
        // sigmoid(0) = 0.5 activity, and the regularizer pushes the
        // logits down.
        assert_eq!(gate.fracs, vec![0.5, 0.5]);
        assert!(gate.w.iter().all(|&v| v < 0.0));
    }

    #[test]
    fn run_mean_is_the_ema_of_the_batch_mean() {
        let out = apply_update(
            &cfg(),
            Vec::new(),
            None,
            Some(RunMeanIn { current: &[1.0, 0.0], col_sums: vec![8.0, 2.0] }),
        );
        let rm = out.run_mean.unwrap();
        assert_eq!(rm[0], 0.9 * 1.0 + 0.1 * 8.0 / 4.0);
        assert_eq!(rm[1], 0.9 * 0.0 + 0.1 * 2.0 / 4.0);
    }
}
