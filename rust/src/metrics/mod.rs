//! Run metrics: loss/accuracy traces, convergence curves keyed by energy
//! (the x-axis of Fig. 5), and JSON export for the experiment harness.

use crate::util::Json;

/// One recorded point of a training run.
#[derive(Debug, Clone)]
pub struct TracePoint {
    pub iter: u64,
    pub loss: f64,
    pub train_acc: f64,
    /// Cumulative simulated energy (J) when recorded.
    pub joules: f64,
    /// Test accuracy if an eval ran at this point.
    pub test_acc: Option<f64>,
}

#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub trace: Vec<TracePoint>,
    pub final_test_acc: f64,
    pub final_test_acc_top5: f64,
    pub final_loss: f64,
    pub total_joules: f64,
    pub executed_macs: f64,
    pub steps_run: u64,
    pub steps_skipped: u64,
    pub wall_seconds: f64,
    /// Mean gate activity per gated block over the run (SLU diagnostics).
    pub mean_gate_fracs: Vec<f64>,
    /// Mean PSG predictor usage over the run.
    pub mean_psg_frac: Option<f64>,
    /// Prefetch channel depth the auto-tuner picked (None when the run
    /// sampled synchronously).
    pub prefetch_depth: Option<usize>,
    /// Execution backend the step loop ran on ("host" | "resident" |
    /// "sharded") — recorded so bench trajectories stay attributable
    /// across the `cfg.backend` knob.
    pub backend: String,
    /// Data-parallel shard count (0 = single-executor backend).
    pub shards: usize,
    /// Supervised-run restarts that recovered from a transient fault
    /// (0 for an unsupervised or fault-free run).  Deliberately *not*
    /// part of the determinism contract: a recovered run's trace,
    /// ledger and final state are bitwise those of the fault-free run.
    pub recoveries: u64,
    /// Checkpoint retention prunes that failed (logged and tolerated —
    /// pruning is best-effort and never aborts training).
    pub prune_failures: u64,
    /// Local iterations not yet on the replica when the run ended
    /// (0 when replication is off or fully drained).  Like
    /// `recoveries`, replication stats live outside the determinism
    /// contract.
    pub replica_lag_iters: u64,
    /// Payload bytes the replicator appended to the remote store.
    pub replica_bytes: u64,
    /// Uploads resumed from a prior attempt's verified staged bytes.
    pub replica_retries: u64,
    /// Source checkpoints pruned away before they could be evacuated.
    pub replica_skipped_vanished: u64,
    /// Per-phase wall-time summary from the observability plane
    /// (`obs` subsystem).  Timing only — lives outside the determinism
    /// contract, like `wall_seconds`: two bitwise-identical runs will
    /// differ here.
    pub obs: Option<crate::obs::ObsSummary>,
    /// The execution plan the planner chose (`backend = "auto"`), with
    /// predicted-vs-actual steps/sec and J/step accounting.  Layout
    /// only — outside the determinism contract like `backend`/`shards`.
    pub plan: Option<crate::obs::catalog::PlanRecord>,
}

impl RunMetrics {
    pub fn record(
        &mut self,
        iter: u64,
        loss: f64,
        train_acc: f64,
        joules: f64,
        test_acc: Option<f64>,
    ) {
        self.trace.push(TracePoint { iter, loss, train_acc, joules, test_acc });
    }

    /// Smoothed loss over the last `k` recorded points.
    pub fn recent_loss(&self, k: usize) -> f64 {
        if self.trace.is_empty() {
            return f64::NAN;
        }
        let tail = &self.trace[self.trace.len().saturating_sub(k)..];
        tail.iter().map(|p| p.loss).sum::<f64>() / tail.len() as f64
    }

    pub fn json_value(&self) -> Json {
        let mut pairs = vec![
            (
                "trace",
                Json::arr(self.trace.iter().map(|p| {
                    Json::obj(vec![
                        ("iter", Json::num(p.iter as f64)),
                        ("loss", Json::num(p.loss)),
                        ("train_acc", Json::num(p.train_acc)),
                        ("joules", Json::num(p.joules)),
                        (
                            "test_acc",
                            p.test_acc.map(Json::num).unwrap_or(Json::Null),
                        ),
                    ])
                })),
            ),
            ("final_test_acc", Json::num(self.final_test_acc)),
            ("final_test_acc_top5", Json::num(self.final_test_acc_top5)),
            ("final_loss", Json::num(self.final_loss)),
            ("total_joules", Json::num(self.total_joules)),
            ("executed_macs", Json::num(self.executed_macs)),
            ("steps_run", Json::num(self.steps_run as f64)),
            ("steps_skipped", Json::num(self.steps_skipped as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            (
                "mean_gate_fracs",
                Json::arr(self.mean_gate_fracs.iter().map(|&g| Json::num(g))),
            ),
            (
                "mean_psg_frac",
                self.mean_psg_frac.map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "prefetch_depth",
                self.prefetch_depth
                    .map(|d| Json::num(d as f64))
                    .unwrap_or(Json::Null),
            ),
            ("backend", Json::str(&self.backend)),
            ("shards", Json::num(self.shards as f64)),
            ("recoveries", Json::num(self.recoveries as f64)),
            ("prune_failures", Json::num(self.prune_failures as f64)),
            ("replica_lag_iters", Json::num(self.replica_lag_iters as f64)),
            ("replica_bytes", Json::num(self.replica_bytes as f64)),
            ("replica_retries", Json::num(self.replica_retries as f64)),
            (
                "replica_skipped_vanished",
                Json::num(self.replica_skipped_vanished as f64),
            ),
        ];
        if let Some(obs) = &self.obs {
            pairs.push(("obs", obs.to_json()));
        }
        if let Some(plan) = &self.plan {
            pairs.push(("plan", plan.to_json()));
        }
        Json::obj(pairs)
    }

    pub fn to_json(&self) -> String {
        self.json_value().to_string()
    }
}

/// Streaming mean helper.
#[derive(Debug, Clone, Default)]
pub struct Mean {
    sum: f64,
    n: u64,
}

impl Mean {
    pub fn push(&mut self, v: f64) {
        self.sum += v;
        self.n += 1;
    }

    /// `(sum, count)` — exported by checkpoints so a resumed run's
    /// lifetime means keep accumulating the identical f64 sums.
    pub fn parts(&self) -> (f64, u64) {
        (self.sum, self.n)
    }

    pub fn from_parts(sum: f64, n: u64) -> Self {
        Self { sum, n }
    }

    pub fn get(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_and_recent_loss() {
        let mut m = RunMetrics::default();
        for i in 0..10 {
            m.record(i, 10.0 - i as f64, 0.5, i as f64, None);
        }
        assert_eq!(m.trace.len(), 10);
        assert!((m.recent_loss(2) - 1.5).abs() < 1e-12);
        assert!(m.recent_loss(100) > m.recent_loss(2));
    }

    #[test]
    fn mean_stream() {
        let mut s = Mean::default();
        assert!(s.get().is_nan());
        s.push(1.0);
        s.push(3.0);
        assert_eq!(s.get(), 2.0);
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn json_export() {
        let mut m = RunMetrics::default();
        m.record(0, 2.3, 0.1, 0.0, Some(0.1));
        let j = m.to_json();
        assert!(j.contains("\"iter\":0"));
        assert!(j.contains("test_acc"));
        // parses back with our own parser
        crate::util::json::parse(&j).unwrap();
    }
}
