//! The execution stage: micro-batches -> per-sample results.
//!
//! Each worker owns an engine from the service's
//! [`crate::runtime::EnginePool`] (per-worker clients stay sound when
//! `Engine` loses `Sync` under real PJRT; the program cache is shared
//! so the artifact compiles once).  Per batch, the worker loads the
//! *current* published
//! [`crate::runtime::StateSnapshot`] — a mid-flight publish swaps state
//! between batches without draining the queue — executes the eval
//! program, slices logits rows, and completes each sample's collector.
//!
//! Failure containment is layered: per-batch panics are caught and fail
//! that batch's collectors; a death that escapes the batch level (an
//! artifact that won't load, a panic outside batch isolation, an
//! injected `serve.worker` fault) reports to the service monitor
//! (`super::run_monitor`), which respawns the worker within budget.  A
//! batch a dying worker takes down with it resolves through
//! [`super::batcher::Route`]'s drop hook — clients get an explicit
//! error, never a hung `Ticket::wait`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use crate::obs::{self, Obs};
use crate::runtime::{
    row_argmax, row_rank, row_softmax_loss, Engine, SnapshotCell, TensorData,
    TrainProgram,
};
use crate::util::fault::{self, FaultPlan};

use super::batcher::MicroBatch;
use super::queue::Bounded;
use super::stats::StatsCollector;
use super::SampleResult;

/// Everything one worker thread owns.
pub(crate) struct WorkerCtx {
    pub engine: Engine,
    pub manifest: PathBuf,
    pub cell: Arc<SnapshotCell>,
    pub batch_q: Arc<Bounded<MicroBatch>>,
    pub stats: Arc<StatsCollector>,
    /// Workers still consuming the batch queue (respawns re-increment).
    pub live: Arc<AtomicUsize>,
    pub faults: Option<Arc<FaultPlan>>,
    /// Records `serve-infer` spans and batch fill-ratio counters on
    /// this worker's thread.
    pub obs: Obs,
    /// Stable worker slot (respawns reuse the dead worker's index).
    pub index: usize,
    /// Death reports to the service monitor.
    pub deaths: mpsc::Sender<MonitorMsg>,
}

/// Messages into the service monitor thread.
pub(crate) enum MonitorMsg {
    /// A worker stopped consuming for a reason other than queue close.
    Died { index: usize, reason: String },
    /// Graceful shutdown: stop monitoring, respawn nothing.
    Shutdown,
}

/// Why a worker's serve loop ended.
enum WorkerExit {
    /// Normal shutdown: the batch queue closed and drained.
    QueueClosed,
    /// Abnormal: load failure, an escaped panic, or an injected death.
    Died(String),
}

pub(crate) fn fail_batch(mb: &MicroBatch, msg: &str) {
    for r in &mb.routes {
        r.collector.fail(msg);
    }
}

/// Worker thread body: drains the batch queue until it closes, then
/// reports how it went.  The `live` decrement happens before the death
/// report so the monitor's "is anybody still consuming?" check is
/// accurate by the time it processes the message.
pub(crate) fn run(ctx: WorkerCtx) {
    let exit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_loop(&ctx)
    }))
    .unwrap_or_else(|p| WorkerExit::Died(panic_message(p.as_ref())));
    ctx.live.fetch_sub(1, Ordering::AcqRel);
    if let WorkerExit::Died(reason) = exit {
        // A closed channel means the monitor is already gone (service
        // tear-down); nothing left to notify.
        let _ = ctx.deaths.send(MonitorMsg::Died { index: ctx.index, reason });
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

fn serve_loop(ctx: &WorkerCtx) -> WorkerExit {
    // Eval-only load: serve workers never step, so they skip the
    // train-program compile entirely — under real PJRT (isolated
    // per-worker engines) that was a full wasted compile per worker.
    let prog = match TrainProgram::load_eval_only(&ctx.engine, &ctx.manifest) {
        Ok(p) => p,
        Err(e) => return WorkerExit::Died(format!("could not load artifact: {e:#}")),
    };

    while let Some(mb) = ctx.batch_q.pop() {
        // Injected worker death: die *holding* the popped batch, the
        // way a real crash would.  Dropping it resolves its tickets
        // through Route's drop hook — the harness pins that contract.
        if let Some(p) = &ctx.faults {
            if p.hit(fault::SITE_SERVE_WORKER).is_some() {
                drop(mb);
                return WorkerExit::Died(format!(
                    "injected fault at {}",
                    fault::SITE_SERVE_WORKER
                ));
            }
        }
        // Per-batch panic isolation: the batch is only borrowed by the
        // closure, so if execution panics (e.g. a published snapshot
        // with mismatched shapes) we still own it and can fail its
        // collectors — no client may ever hang in Ticket::wait.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(&prog, &mb, &ctx.cell, &ctx.stats, &ctx.obs)
        }));
        if r.is_err() {
            fail_batch(&mb, "serve worker panicked executing the batch");
        }
    }
    WorkerExit::QueueClosed
}

fn process_batch(
    prog: &TrainProgram,
    mb: &MicroBatch,
    cell: &SnapshotCell,
    stats: &StatsCollector,
    obs_handle: &Obs,
) {
    let classes = prog.manifest.arch.num_classes;
    let snap = match cell.load() {
        Some(s) => s,
        None => {
            fail_batch(mb, "no state snapshot published yet");
            return;
        }
    };
    let t_infer = std::time::Instant::now();
    let out = match prog.eval_batch_snapshot(&snap, &mb.x, &mb.y) {
        Ok(o) => o,
        Err(e) => {
            fail_batch(mb, &format!("serve eval failed: {e:#}"));
            return;
        }
    };
    obs_handle.record(obs::PHASE_SERVE_INFER, t_infer.elapsed());
    let logits = match out.logits.as_ref().map(|t| t.as_f32()) {
        Some(Ok(v)) => v,
        Some(Err(_)) => {
            fail_batch(mb, "eval logits are not f32");
            return;
        }
        None => {
            fail_batch(mb, "eval program emits no per-sample logits");
            return;
        }
    };
    let labels = match &mb.y.data {
        TensorData::I32(v) => v,
        _ => {
            fail_batch(mb, "labels are not i32");
            return;
        }
    };
    if logits.len() < mb.routes.len() * classes || labels.len() < mb.routes.len() {
        fail_batch(mb, "eval outputs shorter than the batch");
        return;
    }

    // The batch actually executed: this is where occupancy counts
    // (failed batches above never reach the coalescing stats).
    stats.record_batch(mb.routes.len());
    // Fill ratio: real rows over padded capacity of executed batches
    // (labels carry the padded length — one row per micro-batch slot).
    obs_handle.count(obs::CTR_SERVE_BATCH_REAL, mb.routes.len() as u64);
    obs_handle.count(obs::CTR_SERVE_BATCH_SLOTS, labels.len() as u64);
    for (i, route) in mb.routes.iter().enumerate() {
        let zr = &logits[i * classes..(i + 1) * classes];
        let label = labels[i];
        let (correct, loss) = if label >= 0 && (label as usize) < classes {
            let y = label as usize;
            (row_rank(zr, y) == 0, row_softmax_loss(zr, y))
        } else {
            (false, 0.0)
        };
        route.collector.fill(
            route.slot,
            SampleResult {
                logits: zr.to_vec(),
                label,
                pred: row_argmax(zr) as i32,
                correct,
                loss,
                snapshot_version: snap.version,
            },
        );
        stats.record_sample(route.t_submit);
    }
}
