//! The execution stage: micro-batches -> per-sample results.
//!
//! Each worker owns an engine from the service's
//! [`crate::runtime::EnginePool`] (per-worker clients stay sound when
//! `Engine` loses `Sync` under real PJRT; the program cache is shared
//! so the artifact compiles once).  Per batch, the worker loads the
//! *current* published
//! [`crate::runtime::StateSnapshot`] — a mid-flight publish swaps state
//! between batches without draining the queue — executes the eval
//! program, slices logits rows, and completes each sample's collector.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::runtime::{
    row_argmax, row_rank, row_softmax_loss, Engine, SnapshotCell, TensorData,
    TrainProgram,
};

use super::batcher::MicroBatch;
use super::stats::StatsCollector;
use super::queue::Bounded;
use super::SampleResult;

fn fail_batch(mb: &MicroBatch, msg: &str) {
    for r in &mb.routes {
        r.collector.fail(msg);
    }
}

/// Worker thread body: drains the batch queue until it closes.
///
/// `live` counts workers still consuming the batch queue.  A worker
/// that stops early (artifact load failure, or a panic that escaped
/// the per-batch isolation) simply exits while healthy workers remain
/// — they keep serving.  Only the **last** consumer out falls back to
/// a drain-and-fail loop: with nobody popping, the batcher could block
/// forever in `push` and every pending `Ticket::wait` would hang.
pub(crate) fn run(
    engine: Engine,
    manifest_path: &Path,
    cell: &SnapshotCell,
    batch_q: &Bounded<MicroBatch>,
    stats: &StatsCollector,
    live: &AtomicUsize,
) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_loop(&engine, manifest_path, cell, batch_q, stats)
    }));
    if live.fetch_sub(1, Ordering::AcqRel) == 1 {
        // Last consumer out: on a normal shutdown the queue is closed
        // and drained so this is a no-op; on an abnormal exit it keeps
        // the pipeline failing fast instead of deadlocking.
        while let Some(mb) = batch_q.pop() {
            fail_batch(&mb, "all serve workers stopped");
        }
    }
    let _ = result;
}

fn serve_loop(
    engine: &Engine,
    manifest_path: &Path,
    cell: &SnapshotCell,
    batch_q: &Bounded<MicroBatch>,
    stats: &StatsCollector,
) {
    // Eval-only load: serve workers never step, so they skip the
    // train-program compile entirely — under real PJRT (isolated
    // per-worker engines) that was a full wasted compile per worker.
    let prog = match TrainProgram::load_eval_only(engine, manifest_path) {
        Ok(p) => p,
        Err(e) => {
            // Can't serve anything: exit and let the remaining workers
            // (or the last-consumer drain in `run`) handle the queue.
            eprintln!("[serve] worker could not load artifact: {e:#}");
            return;
        }
    };

    while let Some(mb) = batch_q.pop() {
        // Per-batch panic isolation: the batch is only borrowed by the
        // closure, so if execution panics (e.g. a published snapshot
        // with mismatched shapes) we still own it and can fail its
        // collectors — no client may ever hang in Ticket::wait.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(&prog, &mb, cell, stats)
        }));
        if r.is_err() {
            fail_batch(&mb, "serve worker panicked executing the batch");
        }
    }
}

fn process_batch(
    prog: &TrainProgram,
    mb: &MicroBatch,
    cell: &SnapshotCell,
    stats: &StatsCollector,
) {
    let classes = prog.manifest.arch.num_classes;
    let snap = match cell.load() {
        Some(s) => s,
        None => {
            fail_batch(mb, "no state snapshot published yet");
            return;
        }
    };
    let out = match prog.eval_batch_snapshot(&snap, &mb.x, &mb.y) {
        Ok(o) => o,
        Err(e) => {
            fail_batch(mb, &format!("serve eval failed: {e:#}"));
            return;
        }
    };
    let logits = match out.logits.as_ref().map(|t| t.as_f32()) {
        Some(Ok(v)) => v,
        Some(Err(_)) => {
            fail_batch(mb, "eval logits are not f32");
            return;
        }
        None => {
            fail_batch(mb, "eval program emits no per-sample logits");
            return;
        }
    };
    let labels = match &mb.y.data {
        TensorData::I32(v) => v,
        _ => {
            fail_batch(mb, "labels are not i32");
            return;
        }
    };
    if logits.len() < mb.routes.len() * classes || labels.len() < mb.routes.len() {
        fail_batch(mb, "eval outputs shorter than the batch");
        return;
    }

    // The batch actually executed: this is where occupancy counts
    // (failed batches above never reach the coalescing stats).
    stats.record_batch(mb.routes.len());
    for (i, route) in mb.routes.iter().enumerate() {
        let zr = &logits[i * classes..(i + 1) * classes];
        let label = labels[i];
        let (correct, loss) = if label >= 0 && (label as usize) < classes {
            let y = label as usize;
            (row_rank(zr, y) == 0, row_softmax_loss(zr, y))
        } else {
            (false, 0.0)
        };
        route.collector.fill(
            route.slot,
            SampleResult {
                logits: zr.to_vec(),
                label,
                pred: row_argmax(zr) as i32,
                correct,
                loss,
                snapshot_version: snap.version,
            },
        );
        stats.record_sample(route.t_submit);
    }
}
