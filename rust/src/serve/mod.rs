//! `serve` — a queued micro-batching inference service over shared
//! device-resident state.
//!
//! Training squeezes wasted computation out of the step loop (SMD / SLU
//! / PSG); the serving-side analogue is amortizing the fixed per-launch
//! cost of an eval dispatch by coalescing concurrent classification
//! requests into full `eval_batch`-sized micro-batches against state
//! that is already resident.  The pipeline:
//!
//! ```text
//!  clients ──submit──▶ bounded request queue (MPSC, backpressure)
//!                           │ batcher thread
//!                           ▼ coalesce: flush on size OR deadline,
//!                           │ pad the tail with zero rows + label -1
//!                           ▼
//!                      micro-batch queue ──▶ worker pool (one engine
//!                           │                per worker, shared program
//!                           │                cache: runtime::pool)
//!                           ▼ eval against the published StateSnapshot
//!                      per-sample results routed back through
//!                      oneshot completions (Ticket::wait)
//! ```
//!
//! The model state is a read-only [`StateSnapshot`] behind a
//! [`SnapshotCell`]: a training loop publishes SWA / fine-tuned
//! checkpoints mid-flight ([`crate::coordinator::Trainer::set_publisher`])
//! and the queue never drains — in-flight batches finish on the
//! snapshot they loaded, later batches see the new version (reported
//! per sample in [`SampleResult::snapshot_version`]).
//!
//! Publishing also works **across processes**: a [`RegistryWatcher`]
//! ([`ServeService::watch_registry`]) polls a checkpoint registry
//! directory (`crate::checkpoint`) and hot-loads each new checkpoint
//! into the cell with a bumped version — a trainer writing `ckpt/v1`
//! files in another process updates this server with no in-process
//! coupling at all.
//!
//! Admission: requests may carry a client deadline; the batcher drops
//! a request whose deadline already passed before dispatch, completing
//! it with an explicit `expired` error instead of burning worker eval
//! slots ([`ServeStats::expired`]).
//!
//! Correctness contract: the eval program computes logits row-by-row,
//! so a sample's result is bitwise independent of which micro-batch it
//! was coalesced into — N concurrent clients receive exactly the
//! per-sample logits a serial `evaluate_full` pass computes
//! (tests/serve_equivalence.rs), padding included (`one_hot(-1) == 0`).

pub mod batcher;
pub mod queue;
pub mod stats;
pub mod worker;

pub use stats::{ServeStats, StatsCollector};

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{
    format, CheckpointEntry, CheckpointRegistry, FsRemoteStore, RemoteRegistry,
    RetentionCfg,
};
use crate::obs::Obs;
use crate::util::hash::fnv1a64_hex;
use crate::runtime::{
    BackendKind, Engine, EnginePool, Manifest, SnapshotCell, StateSnapshot,
    TrainProgram,
};
use crate::util::fault::FaultPlan;

use batcher::MicroBatch;
use queue::Bounded;
use worker::{MonitorMsg, WorkerCtx};

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Eval worker threads (one engine each).
    pub workers: usize,
    /// Bound of the client-facing request queue (backpressure).
    pub queue_cap: usize,
    /// Longest a staged request waits before a partial flush.  The
    /// deadline-vs-size trade-off knob: small values favor latency,
    /// large values favor occupancy (see PERF.md).
    pub max_delay: Duration,
    /// Micro-batch size; `None` uses the artifact's `eval_batch`.
    pub micro_batch: Option<usize>,
    /// Worker deaths the monitor answers with a respawn (fresh engine
    /// fork) before declaring the pool unrecoverable; past the budget,
    /// pending and future requests fail fast with an explicit error.
    pub max_respawns: usize,
    /// Fault-injection plan (tests): arms the `serve.worker` death site
    /// and the `pool.fork` respawn-failure site.
    pub faults: Option<Arc<FaultPlan>>,
    /// Observability handle ([`Obs::off`] by default): the batcher
    /// records `serve-batch-assembly` spans and queue-depth samples,
    /// workers record `serve-infer` spans and batch fill-ratio
    /// counters — all into the same trace a co-located trainer writes.
    pub obs: Obs,
}

impl Default for ServeCfg {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_cap: 64,
            max_delay: Duration::from_millis(2),
            micro_batch: None,
            max_respawns: 4,
            faults: None,
            obs: Obs::off(),
        }
    }
}

/// Per-sample classification answer.
#[derive(Debug, Clone)]
pub struct SampleResult {
    /// The sample's logits row (num_classes values).
    pub logits: Vec<f32>,
    /// Label the client submitted (-1 = unlabeled).
    pub label: i32,
    /// Predicted class (argmax, ties to the lowest index).
    pub pred: i32,
    /// `pred == label` under the artifact's ranking rule; false when
    /// unlabeled.
    pub correct: bool,
    /// Softmax cross-entropy against `label`; 0.0 when unlabeled.
    pub loss: f32,
    /// Version of the published checkpoint that served this sample.
    pub snapshot_version: u64,
}

struct CollectorInner {
    results: Vec<Option<SampleResult>>,
    remaining: usize,
    error: Option<String>,
}

/// Oneshot completion shared by all samples of one request: workers
/// fill slots (possibly from different micro-batches), the client's
/// [`Ticket::wait`] unblocks when the last slot lands.
pub(crate) struct Collector {
    m: Mutex<CollectorInner>,
    cv: Condvar,
}

impl Collector {
    fn new(n: usize) -> Arc<Self> {
        Arc::new(Self {
            m: Mutex::new(CollectorInner {
                results: (0..n).map(|_| None).collect(),
                remaining: n,
                error: None,
            }),
            cv: Condvar::new(),
        })
    }

    pub(crate) fn fill(&self, slot: usize, r: SampleResult) {
        let mut g = self.m.lock().unwrap();
        if slot < g.results.len() && g.results[slot].is_none() {
            g.results[slot] = Some(r);
            g.remaining -= 1;
        }
        if g.remaining == 0 {
            self.cv.notify_all();
        }
    }

    pub(crate) fn fail(&self, msg: &str) {
        let mut g = self.m.lock().unwrap();
        if g.error.is_none() {
            g.error = Some(msg.to_string());
        }
        self.cv.notify_all();
    }

    /// A completion route was dropped without filling its slot (a
    /// worker died holding the batch): resolve the request with an
    /// explicit error so its [`Ticket::wait`] can never hang.  No-op
    /// when the slot was already filled or the request already failed —
    /// the normal paths drop routes *after* resolving them.
    pub(crate) fn abandon(&self, slot: usize, msg: &str) {
        let mut g = self.m.lock().unwrap();
        if g.error.is_none() && slot < g.results.len() && g.results[slot].is_none() {
            g.error = Some(msg.to_string());
        }
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<Vec<SampleResult>> {
        let mut g = self.m.lock().unwrap();
        loop {
            if let Some(e) = &g.error {
                return Err(anyhow!("serve request failed: {e}"));
            }
            if g.remaining == 0 {
                return Ok(g.results.drain(..).map(|r| r.unwrap()).collect());
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Handle to one submitted request.
pub struct Ticket {
    collector: Arc<Collector>,
}

impl Ticket {
    /// Block until every sample of the request completed; results come
    /// back in submission order.
    pub fn wait(self) -> Result<Vec<SampleResult>> {
        self.collector.wait()
    }
}

/// One queued request: `n` samples travelling together (they may still
/// be split across micro-batches at full-batch boundaries).
pub(crate) struct Request {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub collector: Arc<Collector>,
    pub t_submit: Instant,
    /// Client deadline: past this instant the answer is worthless, so
    /// the batcher drops the request instead of dispatching it.
    pub deadline: Option<Instant>,
}

/// Cloneable client handle: submit single samples or small batches.
#[derive(Clone)]
pub struct ServeClient {
    queue: Arc<Bounded<Request>>,
    hw: usize,
    classes: usize,
}

impl ServeClient {
    /// Submit `labels.len()` samples; `pixels` is the concatenated
    /// `hw*hw*3` rows.  Unlabeled samples pass label `-1` (they get
    /// logits + prediction, no loss/correctness).  Blocks while the
    /// request queue is full (backpressure), errors once the service
    /// shut down.
    pub fn submit(&self, pixels: &[f32], labels: &[i32]) -> Result<Ticket> {
        self.submit_with_deadline(pixels, labels, None)
    }

    /// [`ServeClient::submit`] with a client deadline: if the request
    /// is still queued when `deadline` passes, the batcher completes it
    /// with an explicit `expired` error instead of dispatching it
    /// (the answer would arrive after the client stopped caring — the
    /// eval slots go to requests that can still make their deadline).
    pub fn submit_with_deadline(
        &self,
        pixels: &[f32],
        labels: &[i32],
        deadline: Option<Instant>,
    ) -> Result<Ticket> {
        let stride = self.sample_stride();
        if labels.is_empty() {
            bail!("empty request");
        }
        if pixels.len() != labels.len() * stride {
            bail!(
                "request shape mismatch: {} pixels for {} samples of stride {stride}",
                pixels.len(),
                labels.len()
            );
        }
        if labels.iter().any(|&l| l >= self.classes as i32) {
            bail!("label out of range for {}-class artifact", self.classes);
        }
        let collector = Collector::new(labels.len());
        let req = Request {
            x: pixels.to_vec(),
            y: labels.to_vec(),
            collector: collector.clone(),
            t_submit: Instant::now(),
            deadline,
        };
        self.queue
            .push(req)
            .map_err(|_| anyhow!("serve queue closed"))?;
        Ok(Ticket { collector })
    }

    /// Floats per sample (`hw * hw * 3`).
    pub fn sample_stride(&self) -> usize {
        self.hw * self.hw * 3
    }

    pub fn num_classes(&self) -> usize {
        self.classes
    }
}

/// The running service: batcher thread + worker pool + a supervision
/// monitor over one artifact.  The monitor answers worker deaths with
/// respawns (fresh engine fork, shared program cache) up to
/// `ServeCfg::max_respawns`; past the budget it drains the batch queue
/// failing every batch, so clients always get explicit errors, never a
/// hung [`Ticket::wait`].
pub struct ServeService {
    queue: Arc<Bounded<Request>>,
    batch_q: Arc<Bounded<MicroBatch>>,
    batcher: Option<JoinHandle<()>>,
    /// Shared with the monitor thread, which pushes respawned workers'
    /// handles; drained (after the monitor joins) on shutdown.
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    monitor: Option<JoinHandle<()>>,
    deaths: mpsc::Sender<MonitorMsg>,
    stats: Arc<StatsCollector>,
    /// The publish point workers read snapshots from — kept here so a
    /// registry watcher can be attached after start.
    cell: Arc<SnapshotCell>,
    backend: BackendKind,
    /// (name, shape) of every train-state tensor the served artifact
    /// expects — the registry watcher refuses checkpoints that don't
    /// match instead of poisoning the snapshot cell.
    state_spec: Arc<StateSpec>,
    /// Kept so an attached registry watcher shares the service's armed
    /// fault sites (`registry.read` in particular).
    faults: Option<Arc<FaultPlan>>,
    hw: usize,
    classes: usize,
    micro_batch: usize,
}

impl ServeService {
    /// Boot the service for one `(family, method)` artifact.  `cell` is
    /// the checkpoint publish point — typically shared with a `Trainer`
    /// via [`crate::coordinator::Trainer::set_publisher`]; at least one
    /// snapshot must be published before requests can be answered.
    pub fn start(
        engine: &Engine,
        manifest_path: &Path,
        cell: Arc<SnapshotCell>,
        cfg: ServeCfg,
    ) -> Result<Self> {
        // Probe-load up front (eval-only — the serve pipeline never
        // steps, so neither the probe nor any worker compiles the train
        // program): resolves artifact errors synchronously.  On the
        // reference backend this also warms the shared program cache
        // for the (from_base) worker pool; isolated PJRT workers each
        // compile their own eval copy at thread start — executables are
        // client-bound there, so that cost is irreducible.
        let probe = TrainProgram::load_eval_only(engine, manifest_path)
            .with_context(|| format!("loading serve artifact {}", manifest_path.display()))?;
        let hw = probe.manifest.arch.image_size;
        let classes = probe.manifest.arch.num_classes;
        let micro_batch = cfg.micro_batch.unwrap_or_else(|| probe.eval_batch()).max(1);
        // Compiled HLO has its eval batch baked into the input shapes —
        // an override that disagrees would fail on every single batch
        // at execute time; reject it once, here.  (The reference
        // interpreter shapes off the actual input, so any size works.)
        if probe.backend() == BackendKind::Pjrt && micro_batch != probe.eval_batch() {
            bail!(
                "micro_batch {} != compiled eval batch {} for {}",
                micro_batch,
                probe.eval_batch(),
                manifest_path.display()
            );
        }
        // Serving needs per-sample logits; an artifact that only emits
        // aggregate metrics (the python lowering today) must fail here
        // with a clear message, not per-request at runtime.
        if !probe
            .manifest
            .eval_outputs
            .iter()
            .any(|o| o.name == "logits")
        {
            bail!(
                "{} emits no per-sample `logits` eval output — the serve path \
                 cannot route results back to requesters (re-lower the artifact \
                 with a logits out_aux output, or serve a reference family)",
                manifest_path.display()
            );
        }
        let n_workers = cfg.workers.max(1);

        // Everything fallible that needs no threads happens first, so a
        // failed start leaks nothing.  Reference programs are
        // backend-portable: workers share the base engine's
        // compiled-program cache.  Real-PJRT executables are bound to
        // the client that compiled them — isolate.
        let pool = match probe.backend() {
            BackendKind::Reference => EnginePool::from_base(engine, n_workers)?,
            BackendKind::Pjrt => EnginePool::new_isolated(n_workers)?,
        };

        let queue = Arc::new(Bounded::<Request>::new(cfg.queue_cap));
        let batch_q = Arc::new(Bounded::<MicroBatch>::new(n_workers * 2));
        let stats = Arc::new(StatsCollector::new());

        let batcher = {
            let queue = queue.clone();
            let batch_q = batch_q.clone();
            let st = stats.clone();
            let max_delay = cfg.max_delay;
            let obs = cfg.obs.clone();
            std::thread::Builder::new()
                .name("e2train-serve-batcher".into())
                .spawn(move || {
                    batcher::run(&queue, &batch_q, &st, &obs, micro_batch, hw, max_delay)
                })
                .context("spawning serve batcher")?
        };

        // Respawn source: reference programs are backend-portable, so
        // replacement workers fork from this engine and share the warm
        // cache; under real PJRT (client-bound executables) the monitor
        // builds a fresh isolated client per respawn instead.
        let respawn_base = match probe.backend() {
            BackendKind::Reference => Some(engine.fork()?),
            BackendKind::Pjrt => None,
        };

        let (deaths, death_rx) = mpsc::channel::<MonitorMsg>();
        let mut spawned_workers = Vec::with_capacity(n_workers);
        let live = Arc::new(AtomicUsize::new(n_workers));
        for (i, worker_engine) in pool.into_engines().into_iter().enumerate() {
            let ctx = WorkerCtx {
                engine: worker_engine,
                manifest: manifest_path.to_path_buf(),
                cell: cell.clone(),
                batch_q: batch_q.clone(),
                stats: stats.clone(),
                live: live.clone(),
                faults: cfg.faults.clone(),
                obs: cfg.obs.clone(),
                index: i,
                deaths: deaths.clone(),
            };
            match spawn_worker(ctx) {
                Ok(h) => spawned_workers.push(h),
                Err(e) => {
                    // Unwind the threads already running — a parked
                    // batcher holding an open queue would leak forever.
                    // (The monitor isn't up yet; queued death messages
                    // die with the channel.)
                    queue.close();
                    let _ = batcher.join();
                    batch_q.close();
                    for w in spawned_workers.drain(..) {
                        let _ = w.join();
                    }
                    return Err(e).context("spawning serve worker");
                }
            }
        }
        let workers = Arc::new(Mutex::new(spawned_workers));

        // The supervision monitor: receives worker deaths, respawns
        // within budget, and — once the pool is gone for good — turns
        // into the batch queue's consumer of last resort so pending and
        // future requests fail explicitly instead of hanging.
        let monitor = {
            let ctx = MonitorCtx {
                rx: death_rx,
                budget: cfg.max_respawns,
                respawn_base,
                manifest: manifest_path.to_path_buf(),
                cell: cell.clone(),
                batch_q: batch_q.clone(),
                stats: stats.clone(),
                live: live.clone(),
                faults: cfg.faults.clone(),
                obs: cfg.obs.clone(),
                deaths: deaths.clone(),
                workers: workers.clone(),
            };
            std::thread::Builder::new()
                .name("e2train-serve-monitor".into())
                .spawn(move || run_monitor(ctx))
                .context("spawning serve monitor")?
        };

        Ok(Self {
            queue,
            batch_q,
            batcher: Some(batcher),
            workers,
            monitor: Some(monitor),
            deaths,
            stats,
            backend: probe.backend(),
            state_spec: Arc::new(probe.manifest.state_spec()),
            faults: cfg.faults,
            cell,
            hw,
            classes,
            micro_batch,
        })
    }

    /// Attach a checkpoint-registry watcher: newly published
    /// checkpoints under `dir` hot-load into this service's snapshot
    /// cell with a bumped `snapshot_version`.  This is the
    /// cross-process publish path — the trainer writing the registry
    /// may live in a different process entirely; this service needs no
    /// in-process trainer.  Checkpoints whose state doesn't match the
    /// served artifact are rejected (logged, snapshot kept).  The
    /// watcher stops when the returned handle drops.  Failed polls
    /// (torn manifest read mid-publish, a partially copied file) are
    /// absorbed: the current snapshot keeps serving, the retry is
    /// counted in [`ServeStats::registry_retries`], and consecutive
    /// failures back the poll interval off exponentially (capped at
    /// 8× `poll`).
    pub fn watch_registry(&self, dir: &Path, poll: Duration) -> RegistryWatcher {
        watch_registry_opts(
            self.cell.clone(),
            self.backend,
            self.state_spec.clone(),
            dir,
            poll,
            self.faults.clone(),
            Some(self.stats.clone()),
        )
    }

    /// Like [`ServeService::watch_registry`], but following a
    /// **replicated** registry root in another failure domain — the
    /// serve fleet hot-loads evacuated checkpoints with no local
    /// registry at all.  Every fetched file is verified (manifest hash
    /// + `ckpt/v1` trailer) before it can reach the snapshot cell.
    pub fn watch_replica(&self, root: &Path, poll: Duration) -> RegistryWatcher {
        watch_replica_opts(
            self.cell.clone(),
            self.backend,
            self.state_spec.clone(),
            root,
            poll,
            self.faults.clone(),
            Some(self.stats.clone()),
        )
    }

    /// A new client handle (cheap, cloneable, sendable across threads).
    pub fn client(&self) -> ServeClient {
        ServeClient {
            queue: self.queue.clone(),
            hw: self.hw,
            classes: self.classes,
        }
    }

    pub fn micro_batch(&self) -> usize {
        self.micro_batch
    }

    /// Telemetry so far, without stopping the service.
    pub fn stats(&self) -> ServeStats {
        self.stats.snapshot()
    }

    /// Graceful shutdown: stop accepting, flush everything staged,
    /// drain the worker pool, and return the lifetime stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        self.stats.snapshot()
    }

    fn stop(&mut self) {
        // Order matters: close the request queue first so the batcher
        // drains + flushes its tail, join it, then close the batch
        // queue so workers drain the flushed batches before exiting.
        // The monitor stops next (an explicit Shutdown message; the
        // closed batch queue also unblocks its drain-of-last-resort),
        // and only then are worker handles drained — the monitor is the
        // one other pusher into that vec, so after it joins the list is
        // final.
        self.queue.close();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        self.batch_q.close();
        let _ = self.deaths.send(MonitorMsg::Shutdown);
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
        let handles: Vec<JoinHandle<()>> =
            self.workers.lock().unwrap().drain(..).collect();
        for w in handles {
            let _ = w.join();
        }
    }
}

/// Spawn one worker thread around its context.
fn spawn_worker(ctx: WorkerCtx) -> std::io::Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("e2train-serve-worker{}", ctx.index))
        .spawn(move || worker::run(ctx))
}

/// Everything the supervision monitor owns.
struct MonitorCtx {
    rx: mpsc::Receiver<MonitorMsg>,
    /// Remaining respawns before the pool is declared unrecoverable.
    budget: usize,
    /// Fork source for replacement engines (None = isolated clients).
    respawn_base: Option<Engine>,
    manifest: PathBuf,
    cell: Arc<SnapshotCell>,
    batch_q: Arc<Bounded<MicroBatch>>,
    stats: Arc<StatsCollector>,
    live: Arc<AtomicUsize>,
    faults: Option<Arc<FaultPlan>>,
    obs: Obs,
    deaths: mpsc::Sender<MonitorMsg>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// Monitor thread body: respawn dead workers within budget; once no
/// consumer is left, drain the batch queue failing every batch (pending
/// *and* future — the drain blocks on the open queue) until shutdown
/// closes it.
fn run_monitor(mut ctx: MonitorCtx) {
    while let Ok(msg) = ctx.rx.recv() {
        let (index, reason) = match msg {
            MonitorMsg::Shutdown => return,
            MonitorMsg::Died { index, reason } => (index, reason),
        };
        if ctx.budget > 0 {
            ctx.budget -= 1;
            match respawn_worker(&ctx, index) {
                Ok(handle) => {
                    ctx.stats.record_respawn();
                    ctx.workers.lock().unwrap().push(handle);
                    eprintln!(
                        "[serve] worker {index} died ({reason}); respawned \
                         ({} respawn(s) left)",
                        ctx.budget
                    );
                    continue;
                }
                Err(e) => eprintln!(
                    "[serve] worker {index} died ({reason}) and its respawn \
                     failed ({e:#})"
                ),
            }
        } else {
            eprintln!(
                "[serve] worker {index} died ({reason}); respawn budget exhausted"
            );
        }
        if ctx.live.load(Ordering::Acquire) == 0 {
            // Consumer of last resort: nobody else pops, so without
            // this the batcher would eventually block in push and every
            // pending Ticket::wait would hang.  Exits when shutdown
            // closes the queue.
            while let Some(mb) = ctx.batch_q.pop() {
                worker::fail_batch(&mb, "all serve workers stopped");
            }
        }
    }
}

/// Build a replacement engine (a fork sharing the warm cache, or a
/// fresh isolated client) and spawn a worker on it.  The fork goes
/// through the injectable [`EnginePool::fork_one`] and is retried a
/// couple of times so one transient failure doesn't burn the pool.
fn respawn_worker(ctx: &MonitorCtx, index: usize) -> Result<JoinHandle<()>> {
    const FORK_TRIES: usize = 3;
    let mut engine = None;
    for attempt in 0..FORK_TRIES {
        let forked = match &ctx.respawn_base {
            Some(base) => EnginePool::fork_one(base, ctx.faults.as_deref()),
            None => Engine::cpu(),
        };
        match forked {
            Ok(e) => {
                engine = Some(e);
                break;
            }
            Err(e) if attempt + 1 < FORK_TRIES => {
                eprintln!("[serve] respawn fork failed ({e:#}); retrying");
            }
            Err(e) => return Err(e.context("forking a replacement worker engine")),
        }
    }
    let engine = engine.expect("loop either set an engine or returned");
    // Count the replacement as live *before* it runs: a gap would let a
    // concurrent death observe live == 0 and start the terminal drain
    // while a healthy worker is on the way up.
    ctx.live.fetch_add(1, Ordering::AcqRel);
    let wctx = WorkerCtx {
        engine,
        manifest: ctx.manifest.clone(),
        cell: ctx.cell.clone(),
        batch_q: ctx.batch_q.clone(),
        stats: ctx.stats.clone(),
        live: ctx.live.clone(),
        faults: ctx.faults.clone(),
        obs: ctx.obs.clone(),
        index,
        deaths: ctx.deaths.clone(),
    };
    match spawn_worker(wctx) {
        Ok(h) => Ok(h),
        Err(e) => {
            ctx.live.fetch_sub(1, Ordering::AcqRel);
            Err(anyhow::Error::new(e).context("spawning a replacement serve worker"))
        }
    }
}

impl Drop for ServeService {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Handle to a background registry watcher; dropping it stops the
/// polling thread promptly (condvar-signalled, no poll-interval wait).
pub struct RegistryWatcher {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

/// (name, shape) per train-state tensor, in manifest order — what a
/// hot-loaded checkpoint's serving state must match exactly.  Produced
/// by [`Manifest::state_spec`].
pub type StateSpec = Vec<(String, Vec<usize>)>;

/// Where a watcher reads checkpoints from: the local registry on this
/// box, or a replicated registry root in another failure domain
/// (pull-through [`RemoteRegistry`]).  Both speak `ckpt_registry/v1`
/// and feed the same verify-then-publish tick.
enum WatchSource {
    Local(CheckpointRegistry),
    Replica(RemoteRegistry),
}

impl WatchSource {
    fn latest(&self) -> Result<Option<CheckpointEntry>> {
        match self {
            WatchSource::Local(r) => r.latest(),
            WatchSource::Replica(r) => r.latest(),
        }
    }

    /// Raw, unverified bytes — the tick owns the integrity check so it
    /// can tell corruption (permanent, counted reject) from a failed
    /// read (transient, retried).
    fn read_raw(&self, entry: &CheckpointEntry) -> Result<Vec<u8>> {
        match self {
            WatchSource::Local(r) => r.read_raw(entry),
            WatchSource::Replica(r) => r.read_entry_bytes(entry),
        }
    }
}

impl RegistryWatcher {
    /// Checkpoints successfully published into the cell so far is
    /// observable through `SnapshotCell::version`; this handle only
    /// controls the thread's lifetime.
    fn spawn(
        cell: Arc<SnapshotCell>,
        backend: BackendKind,
        spec: Arc<StateSpec>,
        source: WatchSource,
        poll: Duration,
        stats: Option<Arc<StatsCollector>>,
    ) -> Self {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("e2train-ckpt-watcher".into())
            .spawn(move || {
                // (iter, hash) of the last checkpoint published into the
                // cell — a re-published iteration with new content (new
                // hash) still hot-loads.
                let mut seen: Option<(u64, String)> = None;
                let mut last_err = String::new();
                // Consecutive failed polls: backs the poll interval off
                // exponentially (1×, 2×, 4×, 8× capped) so a registry
                // that is down for a while isn't hammered at full rate.
                let mut consec_errs: u32 = 0;
                loop {
                    match watch_tick(&source, &cell, backend, &spec, &mut seen, &stats)
                    {
                        Ok(()) => {
                            last_err.clear();
                            consec_errs = 0;
                        }
                        Err(e) => {
                            // Transient by assumption (mid-publish read,
                            // partial copy): keep serving the snapshot we
                            // have and retry next tick.  Log once per
                            // distinct cause, not once per poll.
                            consec_errs += 1;
                            if let Some(s) = &stats {
                                s.record_registry_retry();
                            }
                            let msg = format!("{e:#}");
                            if msg != last_err {
                                eprintln!("[serve] registry watch: {msg}");
                                last_err = msg;
                            }
                        }
                    }
                    // First retry comes at the normal poll rate (a torn
                    // read usually heals immediately); repeats back off.
                    let wait = poll * 2u32.pow(consec_errs.saturating_sub(1).min(3));
                    let (lock, cv) = &*stop2;
                    let mut stopped = lock.lock().unwrap();
                    while !*stopped {
                        let (g, timeout) = cv.wait_timeout(stopped, wait).unwrap();
                        stopped = g;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    if *stopped {
                        return;
                    }
                }
            })
            .expect("spawning registry watcher thread");
        Self { stop, handle: Some(handle) }
    }
}

impl Drop for RegistryWatcher {
    fn drop(&mut self) {
        let (lock, cv) = &*self.stop;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One poll: if the source's newest checkpoint differs from what was
/// last published, load + verify it — whole-file FNV hash against the
/// manifest and the `ckpt/v1` trailer *before* any decode, then
/// names/shapes against the served artifact's state spec — and
/// publish its serving state (the SWA average when present, like the
/// in-process trainer publish).  A checkpoint from a different
/// family/method fails here and the cell keeps its current snapshot;
/// it never reaches the workers.
fn watch_tick(
    source: &WatchSource,
    cell: &SnapshotCell,
    backend: BackendKind,
    spec: &StateSpec,
    seen: &mut Option<(u64, String)>,
    stats: &Option<Arc<StatsCollector>>,
) -> Result<()> {
    let entry = match source.latest()? {
        Some(e) => e,
        None => return Ok(()), // nothing published yet
    };
    let key = (entry.iter, entry.hash.clone());
    if seen.as_ref() == Some(&key) {
        return Ok(());
    }
    // Raw bytes first; a failed *read* (mid-publish copy, replica
    // hiccup) is transient and retried next tick.
    let bytes = source.read_raw(&entry)?;
    // Cheap integrity gate before decode: manifest hash, then trailer.
    // Corrupt bytes are a permanent property of this (iter, hash) key —
    // reject once, count it, and stop re-reading the file every poll.
    let hash = fnv1a64_hex(&bytes);
    if hash != entry.hash {
        *seen = Some(key);
        if let Some(s) = stats {
            s.record_hot_load_reject();
        }
        bail!(
            "checkpoint iter {} hash {hash} does not match manifest ({}) — \
             refusing to hot-load corrupt bytes",
            entry.iter,
            entry.hash
        );
    }
    if let Err(e) = format::verify_trailer(&bytes) {
        *seen = Some(key);
        if let Some(s) = stats {
            s.record_hot_load_reject();
        }
        return Err(e.context(format!(
            "checkpoint iter {} failed the ckpt/v1 trailer check — refusing to \
             hot-load corrupt bytes",
            entry.iter
        )));
    }
    let ckpt = format::decode(&bytes)
        .with_context(|| format!("decoding checkpoint iter {}", entry.iter))?;
    let state = ckpt.serving_state();
    if !state.matches_spec(spec) {
        // Deterministic rejection: this exact file can never become
        // loadable, so remember its key — otherwise every poll would
        // re-read and re-decode the whole checkpoint just to refuse it
        // again.  A future checkpoint (new iter or content) gets a new
        // key and a fresh look.
        *seen = Some(key);
        bail!(
            "checkpoint iter {} ({}/{}) does not match the served artifact's \
             state layout — refusing to hot-load it",
            entry.iter,
            ckpt.cfg.family,
            ckpt.cfg.method
        );
    }
    let snap = StateSnapshot::from_model_state(backend, state)?;
    let version = cell.publish(snap);
    eprintln!(
        "[serve] hot-loaded checkpoint iter {} ({} bytes) -> snapshot v{version}",
        entry.iter, entry.bytes
    );
    *seen = Some(key);
    Ok(())
}

/// Watch a checkpoint registry directory and hot-load each new
/// checkpoint into `cell` — the standalone form of
/// [`ServeService::watch_registry`] for callers that own the cell
/// (e.g. one watcher feeding services across several sweep levels).
/// `spec` pins the state layout hot-loads must match
/// ([`Manifest::state_spec`] of the served artifact).
pub fn watch_registry(
    cell: Arc<SnapshotCell>,
    backend: BackendKind,
    spec: Arc<StateSpec>,
    dir: &Path,
    poll: Duration,
) -> RegistryWatcher {
    watch_registry_opts(cell, backend, spec, dir, poll, None, None)
}

/// [`watch_registry`] with fault-injection and telemetry hooks: `faults`
/// arms the registry's `registry.read` site (torn manifest reads), and
/// failed polls are counted into `stats` as
/// [`ServeStats::registry_retries`] (corrupt checkpoints additionally as
/// [`ServeStats::hot_load_rejects`]).
pub fn watch_registry_opts(
    cell: Arc<SnapshotCell>,
    backend: BackendKind,
    spec: Arc<StateSpec>,
    dir: &Path,
    poll: Duration,
    faults: Option<Arc<FaultPlan>>,
    stats: Option<Arc<StatsCollector>>,
) -> RegistryWatcher {
    let mut registry = CheckpointRegistry::new(dir, RetentionCfg::default());
    if let Some(p) = faults {
        registry = registry.with_faults(p);
    }
    RegistryWatcher::spawn(cell, backend, spec, WatchSource::Local(registry), poll, stats)
}

/// Watch a **replicated** registry root in another failure domain and
/// hot-load each new verified checkpoint into `cell` — the serve fleet's
/// disaster-recovery path: it needs no local registry, only the replica
/// the training box evacuates to.  Same tick as [`watch_registry`]
/// (hash + trailer verified before decode, spec-mismatch and corrupt
/// checkpoints rejected without touching the snapshot).
pub fn watch_replica(
    cell: Arc<SnapshotCell>,
    backend: BackendKind,
    spec: Arc<StateSpec>,
    root: &Path,
    poll: Duration,
) -> RegistryWatcher {
    watch_replica_opts(cell, backend, spec, root, poll, None, None)
}

/// [`watch_replica`] with fault-injection (`remote.read` transient
/// errors) and telemetry hooks, mirroring [`watch_registry_opts`].
pub fn watch_replica_opts(
    cell: Arc<SnapshotCell>,
    backend: BackendKind,
    spec: Arc<StateSpec>,
    root: &Path,
    poll: Duration,
    faults: Option<Arc<FaultPlan>>,
    stats: Option<Arc<StatsCollector>>,
) -> RegistryWatcher {
    let mut store = FsRemoteStore::new(root);
    if let Some(p) = faults {
        store = store.with_faults(p);
    }
    let remote = RemoteRegistry::new(Box::new(store));
    RegistryWatcher::spawn(cell, backend, spec, WatchSource::Replica(remote), poll, stats)
}
