//! The coalescing stage: requests -> padded micro-batches.
//!
//! Flush policy is **size-or-deadline**: a batch ships the moment it is
//! full (`micro_batch` samples), or when the oldest staged sample has
//! waited `max_delay` with the queue idle.  Partial flushes reuse the
//! eval-tail padding contract — zero rows with label `-1` contribute
//! nothing to any output (`one_hot(-1) == 0`), so padded batches are
//! safe to run through the unmodified eval program.
//!
//! Requests stage atomically (all samples of a request enter the
//! staging buffer before any flush decision) and only split across
//! batches at full-batch boundaries — the leading side always ships
//! full; the trailing fragment starts the next batch and may itself
//! deadline-flush partial if the queue goes idle.
//!
//! Admission check: a request whose **client deadline** already passed
//! when the batcher pops it is never staged — its samples complete
//! immediately with an explicit `expired` error instead of burning a
//! worker eval slot on an answer nobody is waiting for.  Drops are
//! counted in [`super::ServeStats::expired`].

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{self, Obs};
use crate::runtime::HostTensor;

use super::queue::{Bounded, PopResult};
use super::stats::StatsCollector;
use super::{Collector, Request};

/// One executable unit: a padded `[micro_batch, hw, hw, 3]` batch plus
/// the routing table mapping its first `routes.len()` rows back to the
/// requests that contributed them.
pub(crate) struct MicroBatch {
    pub x: HostTensor,
    pub y: HostTensor,
    pub routes: Vec<Route>,
}

/// Row -> (request completion, request-local slot) routing.
pub(crate) struct Route {
    pub collector: Arc<Collector>,
    pub slot: usize,
    pub t_submit: Instant,
}

/// The no-hung-ticket backstop: if a route is dropped before its slot
/// was filled (a worker died holding the batch, a queue path forgot a
/// failure branch), resolve the request with an explicit error.  On the
/// normal paths the slot is already filled — or the collector already
/// failed — by drop time, and [`Collector::abandon`] is a no-op.
impl Drop for Route {
    fn drop(&mut self) {
        self.collector
            .abandon(self.slot, "serve worker dropped the batch mid-flight");
    }
}

struct Staging {
    x: Vec<f32>,
    y: Vec<i32>,
    routes: Vec<Route>,
    micro_batch: usize,
    stride: usize,
    hw: usize,
}

impl Staging {
    fn flush(&mut self, batch_q: &Bounded<MicroBatch>) {
        if self.routes.is_empty() {
            return;
        }
        // Swap in pre-sized replacements (a plain `take` would leave
        // zero-capacity vecs that regrow through reallocation on every
        // subsequent batch of the hot path).
        let mut px = std::mem::replace(
            &mut self.x,
            Vec::with_capacity(self.micro_batch * self.stride),
        );
        let mut py =
            std::mem::replace(&mut self.y, Vec::with_capacity(self.micro_batch));
        px.resize(self.micro_batch * self.stride, 0.0);
        py.resize(self.micro_batch, -1);
        let mb = MicroBatch {
            x: HostTensor::f32(vec![self.micro_batch, self.hw, self.hw, 3], px),
            y: HostTensor::i32(vec![self.micro_batch], py),
            routes: std::mem::replace(
                &mut self.routes,
                Vec::with_capacity(self.micro_batch),
            ),
        };
        // Occupancy is recorded by the worker on successful execution
        // (serve/worker.rs), so failed or rejected batches never skew
        // the coalescing stats.
        if let Err(mb) = batch_q.push(mb) {
            // Shutdown race: the batch queue closed under us — fail the
            // affected requests instead of hanging their tickets.
            for r in &mb.routes {
                r.collector.fail("serve batch queue closed");
            }
        }
    }
}

/// The batcher thread body.  Exits when the request queue is closed and
/// fully drained, flushing whatever is staged on the way out.
pub(crate) fn run(
    queue: &Bounded<Request>,
    batch_q: &Bounded<MicroBatch>,
    stats: &StatsCollector,
    obs: &Obs,
    micro_batch: usize,
    hw: usize,
    max_delay: Duration,
) {
    let stride = hw * hw * 3;
    let mut staging = Staging {
        x: Vec::with_capacity(micro_batch * stride),
        y: Vec::with_capacity(micro_batch),
        routes: Vec::with_capacity(micro_batch),
        micro_batch,
        stride,
        hw,
    };
    // Deadline of the oldest staged sample; meaningful only while the
    // staging buffer is non-empty.
    let mut deadline = Instant::now();
    // Assembly span start: set when the first sample of a batch stages,
    // taken when that batch flushes — the coalescing wait the
    // deadline-vs-size knob trades against (`serve-batch-assembly`).
    let mut t_assembly: Option<Instant> = None;

    loop {
        let req = if staging.routes.is_empty() {
            // Nothing staged: park until work or shutdown arrives.
            match queue.pop() {
                Some(r) => r,
                None => break,
            }
        } else {
            match queue.pop_deadline(deadline) {
                PopResult::Item(r) => r,
                PopResult::TimedOut => {
                    if let Some(t0) = t_assembly.take() {
                        obs.record(obs::PHASE_SERVE_ASSEMBLY, t0.elapsed());
                    }
                    staging.flush(batch_q);
                    continue;
                }
                PopResult::Closed => break,
            }
        };
        // Request-queue depth the moment after this pop: how much work
        // clients have backed up behind the batcher.  Guarded so the
        // untraced path never takes the queue mutex just for the sample.
        if obs.is_on() {
            obs.count(obs::CTR_SERVE_QUEUE_DEPTH_SUM, queue.len() as u64);
            obs.count(obs::CTR_SERVE_QUEUE_DEPTH_SAMPLES, 1);
        }

        // Drop-before-dispatch: a request that already missed its
        // client deadline completes with an explicit expired error —
        // it never occupies micro-batch rows or worker time.
        if let Some(d) = req.deadline {
            if Instant::now() >= d {
                stats.record_expired(req.y.len());
                req.collector
                    .fail("request expired before dispatch (client deadline passed)");
                continue;
            }
        }

        // Stage the whole request; ship full batches as they fill.
        for (k, &label) in req.y.iter().enumerate() {
            if staging.routes.is_empty() {
                deadline = Instant::now() + max_delay;
                t_assembly = Some(Instant::now());
            }
            staging
                .x
                .extend_from_slice(&req.x[k * stride..(k + 1) * stride]);
            staging.y.push(label);
            staging.routes.push(Route {
                collector: req.collector.clone(),
                slot: k,
                t_submit: req.t_submit,
            });
            if staging.routes.len() == micro_batch {
                if let Some(t0) = t_assembly.take() {
                    obs.record(obs::PHASE_SERVE_ASSEMBLY, t0.elapsed());
                }
                staging.flush(batch_q);
            }
        }
    }
    // Closed: flush the tail so no ticket is left pending.
    if let Some(t0) = t_assembly.take() {
        obs.record(obs::PHASE_SERVE_ASSEMBLY, t0.elapsed());
    }
    staging.flush(batch_q);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::SampleResult;

    fn result() -> SampleResult {
        SampleResult {
            logits: vec![0.0],
            label: 0,
            pred: 0,
            correct: true,
            loss: 0.0,
            snapshot_version: 1,
        }
    }

    #[test]
    fn dropping_an_unfilled_route_fails_the_request_explicitly() {
        let c = Collector::new(2);
        c.fill(0, result());
        // A worker died holding the batch: its routes drop unfilled.
        drop(Route { collector: c.clone(), slot: 1, t_submit: Instant::now() });
        let err = c.wait().unwrap_err().to_string();
        assert!(err.contains("dropped the batch mid-flight"), "{err}");
    }

    #[test]
    fn route_drop_is_a_noop_once_its_slot_was_filled() {
        let c = Collector::new(1);
        c.fill(0, result());
        // The normal path: fill first, then the route drops with the
        // batch — must not poison the completed request.
        drop(Route { collector: c.clone(), slot: 0, t_submit: Instant::now() });
        let r = c.wait().expect("completed request must stay completed");
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].snapshot_version, 1);
    }
}
