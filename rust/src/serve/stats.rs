//! Serving-side telemetry: per-sample latency percentiles, micro-batch
//! occupancy, and throughput over the observed completion window.
//!
//! The worker records one occupancy point per **executed** micro-batch
//! (real samples / capacity matters for amortization: occupancy 1 means
//! the fixed per-launch cost is unamortized, occupancy == micro_batch
//! means it is fully amortized) and one latency point per completed
//! sample (submit -> result fill).
//!
//! Bounded by design: occupancy keeps running sums, and latencies land
//! in a fixed 252-bucket log-scale [`Histogram`]
//! (`obs::hist`) — observing is O(1), memory is constant no matter how
//! long the service lives, and a `stats()` snapshot never sorts or
//! clones sample history while workers wait on the lock.  Bucket upper
//! bounds overestimate a sample by at most 25%, clamped to the exact
//! observed max; counts and means stay exact and lifetime.

use std::sync::Mutex;
use std::time::Instant;

use crate::obs::hist::Histogram;

#[derive(Default)]
struct StatsInner {
    /// Completion latencies in nanoseconds (fixed-size log histogram).
    latency: Histogram,
    /// Lifetime completed-sample count.
    samples: usize,
    /// Lifetime latency sum (for the exact lifetime mean).
    latency_sum_s: f64,
    /// Lifetime executed-batch count.
    batches: usize,
    /// Lifetime sum of real samples over executed batches.
    occupancy_sum: usize,
    /// Samples dropped by the batcher because their client deadline
    /// expired before dispatch (completed with an `expired` error
    /// instead of burning a worker eval slot).
    expired: usize,
    /// Dead workers the monitor replaced with a fresh engine fork.
    respawns: usize,
    /// Registry-watcher polls that failed (torn manifest read, partial
    /// copy) and were retried on a later tick.
    registry_retries: usize,
    /// Checkpoints the watcher refused to hot-load because their bytes
    /// failed integrity verification (manifest hash or `ckpt/v1`
    /// trailer) — bit-flips and truncated transfers, rejected before
    /// decode and never re-read.
    hot_load_rejects: usize,
    /// Completion-window bounds for throughput.
    first_done: Option<Instant>,
    last_done: Option<Instant>,
}

/// Shared collector: every worker holds an `Arc` to one.
#[derive(Default)]
pub struct StatsCollector {
    inner: Mutex<StatsInner>,
}

impl StatsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// One executed micro-batch with `n_real` real samples.
    pub fn record_batch(&self, n_real: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.occupancy_sum += n_real;
    }

    /// `n` samples dropped before dispatch on an expired deadline.
    pub fn record_expired(&self, n: usize) {
        self.inner.lock().unwrap().expired += n;
    }

    /// One dead worker replaced by the supervision monitor.
    pub fn record_respawn(&self) {
        self.inner.lock().unwrap().respawns += 1;
    }

    /// One failed registry-watcher poll (retried next tick).
    pub fn record_registry_retry(&self) {
        self.inner.lock().unwrap().registry_retries += 1;
    }

    /// One checkpoint rejected by the watcher's integrity gate (corrupt
    /// bytes: manifest-hash or trailer mismatch).
    pub fn record_hot_load_reject(&self) {
        self.inner.lock().unwrap().hot_load_rejects += 1;
    }

    /// One completed sample submitted at `t_submit`.
    pub fn record_sample(&self, t_submit: Instant) {
        let now = Instant::now();
        let lat = now.duration_since(t_submit);
        let mut g = self.inner.lock().unwrap();
        g.latency.observe(lat.as_nanos() as u64);
        g.samples += 1;
        g.latency_sum_s += lat.as_secs_f64();
        if g.first_done.is_none() {
            g.first_done = Some(now);
        }
        g.last_done = Some(now);
    }

    /// Aggregate everything recorded so far.  Percentiles come straight
    /// off the histogram — no sort, no history clone, O(buckets) under
    /// the lock.
    pub fn snapshot(&self) -> ServeStats {
        let g = self.inner.lock().unwrap();
        let wall_s = match (g.first_done, g.last_done) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        };
        ServeStats {
            samples: g.samples,
            batches: g.batches,
            expired: g.expired,
            worker_respawns: g.respawns,
            registry_retries: g.registry_retries,
            hot_load_rejects: g.hot_load_rejects,
            occupancy_mean: if g.batches == 0 {
                0.0
            } else {
                g.occupancy_sum as f64 / g.batches as f64
            },
            latency_p50_s: g.latency.percentile(0.50) / 1e9,
            latency_p99_s: g.latency.percentile(0.99) / 1e9,
            latency_mean_s: if g.samples == 0 {
                0.0
            } else {
                g.latency_sum_s / g.samples as f64
            },
            // Completion-window throughput; the bench harness also
            // reports end-to-end wall throughput around the client run.
            throughput_sps: if wall_s > 0.0 {
                g.samples as f64 / wall_s
            } else {
                0.0
            },
        }
    }
}

/// `p` in [0, 1] over an ascending-sorted slice (nearest-rank).  The
/// exact-sample counterpart of [`Histogram::percentile`]; bench
/// harnesses that hold their own sample vectors still use it.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * p).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1);
    sorted[idx]
}

/// Aggregated serving statistics for one service lifetime.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub samples: usize,
    pub batches: usize,
    /// Samples completed with an `expired` error instead of being
    /// dispatched (client deadline passed while queued).
    pub expired: usize,
    /// Dead workers the supervision monitor replaced within its
    /// respawn budget ([`super::ServeCfg::max_respawns`]).
    pub worker_respawns: usize,
    /// Failed registry-watcher polls that were absorbed by retrying on
    /// a later tick (the served snapshot is kept meanwhile).
    pub registry_retries: usize,
    /// Checkpoints refused by the hot-load integrity gate (corrupt
    /// bytes rejected before decode; the served snapshot is kept).
    pub hot_load_rejects: usize,
    /// Mean real samples per executed micro-batch (> 1 means requests
    /// actually coalesced).
    pub occupancy_mean: f64,
    /// Lifetime latency percentiles off the fixed-bucket histogram:
    /// a bucket upper bound, so ≤ 25% above the true sample, clamped
    /// to the exact observed max.
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    /// Lifetime mean completion latency (exact).
    pub latency_mean_s: f64,
    /// Samples per second over the completion window.
    pub throughput_sps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.99), 3.0);
    }

    #[test]
    fn collector_aggregates() {
        let c = StatsCollector::new();
        c.record_batch(4);
        c.record_batch(2);
        c.record_expired(3);
        let t0 = Instant::now() - Duration::from_millis(10);
        c.record_sample(t0);
        c.record_sample(t0);
        c.record_respawn();
        c.record_registry_retry();
        c.record_registry_retry();
        c.record_hot_load_reject();
        let s = c.snapshot();
        assert_eq!(s.samples, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.expired, 3);
        assert_eq!(s.worker_respawns, 1);
        assert_eq!(s.registry_retries, 2);
        assert_eq!(s.hot_load_rejects, 1);
        assert!((s.occupancy_mean - 3.0).abs() < 1e-12);
        // Histogram percentiles are upper bounds clamped to the exact
        // max, so they can never under-report the 10ms latency floor.
        assert!(s.latency_p50_s >= 0.010);
        assert!(s.latency_p99_s >= s.latency_p50_s);
        assert!(s.latency_mean_s >= 0.010);
        // ≤ 25% bucket overestimate, and the max clamp keeps p99 at or
        // below the largest real sample (well under double the floor).
        assert!(s.latency_p99_s < 0.020, "p99 {} too loose", s.latency_p99_s);
    }

    #[test]
    fn latency_memory_is_bounded() {
        let c = StatsCollector::new();
        let t0 = Instant::now();
        let n = (1 << 16) + 10;
        for _ in 0..n {
            c.record_sample(t0);
        }
        let g = c.inner.lock().unwrap();
        assert_eq!(
            g.latency.count(),
            n as u64,
            "histogram absorbs every sample"
        );
        assert_eq!(g.samples, n, "lifetime count keeps going");
        // The histogram's storage is a fixed bucket array — no
        // per-sample history exists to grow.
        assert!(
            std::mem::size_of::<Histogram>() < 64,
            "histogram header stays constant-size"
        );
    }
}
