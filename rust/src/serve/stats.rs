//! Serving-side telemetry: per-sample latency percentiles, micro-batch
//! occupancy, and throughput over the observed completion window.
//!
//! The worker records one occupancy point per **executed** micro-batch
//! (real samples / capacity matters for amortization: occupancy 1 means
//! the fixed per-launch cost is unamortized, occupancy == micro_batch
//! means it is fully amortized) and one latency point per completed
//! sample (submit -> result fill).
//!
//! Bounded by design: occupancy keeps running sums, and latencies live
//! in a fixed-size ring ([`LATENCY_WINDOW`] most recent samples), so a
//! long-lived service neither grows memory without bound nor stalls
//! the worker pool while a `stats()` snapshot clones history.
//! Percentiles therefore describe the recent window; counts and means
//! are lifetime.

use std::sync::Mutex;
use std::time::Instant;

/// Latency samples retained for percentile estimation (most recent).
pub const LATENCY_WINDOW: usize = 1 << 16;

#[derive(Default)]
struct StatsInner {
    /// Ring of the most recent completion latencies (seconds).
    latencies: Vec<f64>,
    /// Ring cursor (next slot to overwrite once the ring is full).
    cursor: usize,
    /// Lifetime completed-sample count.
    samples: usize,
    /// Lifetime latency sum (for the lifetime mean).
    latency_sum_s: f64,
    /// Lifetime executed-batch count.
    batches: usize,
    /// Lifetime sum of real samples over executed batches.
    occupancy_sum: usize,
    /// Samples dropped by the batcher because their client deadline
    /// expired before dispatch (completed with an `expired` error
    /// instead of burning a worker eval slot).
    expired: usize,
    /// Dead workers the monitor replaced with a fresh engine fork.
    respawns: usize,
    /// Registry-watcher polls that failed (torn manifest read, partial
    /// copy) and were retried on a later tick.
    registry_retries: usize,
    /// Completion-window bounds for throughput.
    first_done: Option<Instant>,
    last_done: Option<Instant>,
}

/// Shared collector: every worker holds an `Arc` to one.
#[derive(Default)]
pub struct StatsCollector {
    inner: Mutex<StatsInner>,
}

impl StatsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// One executed micro-batch with `n_real` real samples.
    pub fn record_batch(&self, n_real: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.occupancy_sum += n_real;
    }

    /// `n` samples dropped before dispatch on an expired deadline.
    pub fn record_expired(&self, n: usize) {
        self.inner.lock().unwrap().expired += n;
    }

    /// One dead worker replaced by the supervision monitor.
    pub fn record_respawn(&self) {
        self.inner.lock().unwrap().respawns += 1;
    }

    /// One failed registry-watcher poll (retried next tick).
    pub fn record_registry_retry(&self) {
        self.inner.lock().unwrap().registry_retries += 1;
    }

    /// One completed sample submitted at `t_submit`.
    pub fn record_sample(&self, t_submit: Instant) {
        let now = Instant::now();
        let lat = now.duration_since(t_submit).as_secs_f64();
        let mut g = self.inner.lock().unwrap();
        if g.latencies.len() < LATENCY_WINDOW {
            g.latencies.push(lat);
        } else {
            let i = g.cursor;
            g.latencies[i] = lat;
        }
        g.cursor = (g.cursor + 1) % LATENCY_WINDOW;
        g.samples += 1;
        g.latency_sum_s += lat;
        if g.first_done.is_none() {
            g.first_done = Some(now);
        }
        g.last_done = Some(now);
    }

    /// Aggregate everything recorded so far.  The latency history is
    /// cloned under the lock but sorted outside it, so workers are
    /// never blocked behind the sort.
    pub fn snapshot(&self) -> ServeStats {
        let (
            mut lat,
            samples,
            latency_sum_s,
            batches,
            occupancy_sum,
            expired,
            respawns,
            registry_retries,
            wall_s,
        ) = {
            let g = self.inner.lock().unwrap();
            (
                g.latencies.clone(),
                g.samples,
                g.latency_sum_s,
                g.batches,
                g.occupancy_sum,
                g.expired,
                g.respawns,
                g.registry_retries,
                match (g.first_done, g.last_done) {
                    (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
                    _ => 0.0,
                },
            )
        };
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ServeStats {
            samples,
            batches,
            expired,
            worker_respawns: respawns,
            registry_retries,
            occupancy_mean: if batches == 0 {
                0.0
            } else {
                occupancy_sum as f64 / batches as f64
            },
            latency_p50_s: percentile(&lat, 0.50),
            latency_p99_s: percentile(&lat, 0.99),
            latency_mean_s: if samples == 0 {
                0.0
            } else {
                latency_sum_s / samples as f64
            },
            // Completion-window throughput; the bench harness also
            // reports end-to-end wall throughput around the client run.
            throughput_sps: if wall_s > 0.0 {
                samples as f64 / wall_s
            } else {
                0.0
            },
        }
    }
}

/// `p` in [0, 1] over an ascending-sorted slice (nearest-rank).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * p).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1);
    sorted[idx]
}

/// Aggregated serving statistics for one service lifetime.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub samples: usize,
    pub batches: usize,
    /// Samples completed with an `expired` error instead of being
    /// dispatched (client deadline passed while queued).
    pub expired: usize,
    /// Dead workers the supervision monitor replaced within its
    /// respawn budget ([`super::ServeCfg::max_respawns`]).
    pub worker_respawns: usize,
    /// Failed registry-watcher polls that were absorbed by retrying on
    /// a later tick (the served snapshot is kept meanwhile).
    pub registry_retries: usize,
    /// Mean real samples per executed micro-batch (> 1 means requests
    /// actually coalesced).
    pub occupancy_mean: f64,
    /// Percentiles over the most recent [`LATENCY_WINDOW`] samples.
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    /// Lifetime mean completion latency.
    pub latency_mean_s: f64,
    /// Samples per second over the completion window.
    pub throughput_sps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.99), 3.0);
    }

    #[test]
    fn collector_aggregates() {
        let c = StatsCollector::new();
        c.record_batch(4);
        c.record_batch(2);
        c.record_expired(3);
        let t0 = Instant::now() - Duration::from_millis(10);
        c.record_sample(t0);
        c.record_sample(t0);
        c.record_respawn();
        c.record_registry_retry();
        c.record_registry_retry();
        let s = c.snapshot();
        assert_eq!(s.samples, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.expired, 3);
        assert_eq!(s.worker_respawns, 1);
        assert_eq!(s.registry_retries, 2);
        assert!((s.occupancy_mean - 3.0).abs() < 1e-12);
        assert!(s.latency_p50_s >= 0.010);
        assert!(s.latency_p99_s >= s.latency_p50_s);
        assert!(s.latency_mean_s >= 0.010);
    }

    #[test]
    fn latency_ring_is_bounded() {
        let c = StatsCollector::new();
        let t0 = Instant::now();
        for _ in 0..(LATENCY_WINDOW + 10) {
            c.record_sample(t0);
        }
        let g = c.inner.lock().unwrap();
        assert_eq!(g.latencies.len(), LATENCY_WINDOW, "ring must not grow");
        assert_eq!(g.samples, LATENCY_WINDOW + 10, "lifetime count keeps going");
        assert_eq!(g.cursor, 10);
    }
}
