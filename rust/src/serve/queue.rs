//! Bounded blocking queues for the serve pipeline.
//!
//! `std::sync::mpsc` channels are single-consumer, but the serve
//! pipeline needs one multi-producer stage (clients -> batcher) and one
//! multi-consumer stage (batcher -> worker pool), both bounded so a
//! burst of clients applies backpressure instead of growing memory.
//! [`Bounded`] covers both with a `Mutex<VecDeque>` + two condvars —
//! the classic bounded-buffer, with an explicit closed state so
//! shutdown drains cleanly: producers get their item back, consumers
//! drain the remaining items and then observe the close.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Result of a deadline-bounded pop.
pub enum PopResult<T> {
    Item(T),
    /// Deadline passed with the queue still empty.
    TimedOut,
    /// Queue closed and fully drained.
    Closed,
}

struct Inner<T> {
    q: VecDeque<T>,
    cap: usize,
    closed: bool,
}

/// A bounded multi-producer multi-consumer blocking queue.
pub struct Bounded<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Bounded<T> {
    pub fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                cap: cap.max(1),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking push; returns the item back if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.q.len() < g.cap {
                g.q.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking pop; `None` once the queue is closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a deadline: an item if one arrives in time, `TimedOut`
    /// at the deadline, `Closed` when closed and drained.  Drives the
    /// batcher's flush-on-deadline behavior.
    pub fn pop_deadline(&self, deadline: Instant) -> PopResult<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.not_full.notify_one();
                return PopResult::Item(item);
            }
            if g.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::TimedOut;
            }
            let (ng, timeout) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap();
            g = ng;
            if timeout.timed_out() && g.q.is_empty() && !g.closed {
                return PopResult::TimedOut;
            }
        }
    }

    /// Close the queue: producers fail fast, consumers drain then stop.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_and_backpressure() {
        let q = Arc::new(Bounded::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        // Full: a producer blocks until a consumer pops.
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(3).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert!(t.join().unwrap());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn close_drains_then_stops() {
        let q: Bounded<u32> = Bounded::new(4);
        q.push(7).unwrap();
        q.close();
        assert!(q.push(8).is_err(), "push after close must fail");
        assert_eq!(q.pop(), Some(7), "closed queues still drain");
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn deadline_pop_times_out_then_delivers() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(4));
        let d = Instant::now() + Duration::from_millis(10);
        match q.pop_deadline(d) {
            PopResult::TimedOut => {}
            _ => panic!("expected timeout"),
        }
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            q2.push(9).unwrap();
        });
        match q.pop_deadline(Instant::now() + Duration::from_secs(5)) {
            PopResult::Item(v) => assert_eq!(v, 9),
            _ => panic!("expected item"),
        }
        t.join().unwrap();
        q.close();
        match q.pop_deadline(Instant::now() + Duration::from_millis(1)) {
            PopResult::Closed => {}
            _ => panic!("expected closed"),
        }
    }

    #[test]
    fn many_producers_one_consumer() {
        let q: Arc<Bounded<usize>> = Arc::new(Bounded::new(3));
        let mut handles = Vec::new();
        for p in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10 {
                    q.push(p * 100 + i).unwrap();
                }
            }));
        }
        let mut got = Vec::new();
        for _ in 0..40 {
            got.push(q.pop().unwrap());
        }
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 40, "all items delivered exactly once");
    }
}
