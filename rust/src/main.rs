//! `e2train` — the leader binary: train/eval runs, experiment harness
//! (one subcommand per paper table/figure), and energy-model reports.
//!
//! ```text
//! e2train list
//! e2train train --family resnet8-c10-tiny --method e2train --iters 300
//! e2train train --family refmlp-tiny --iters 300 --ckpt-every 50 --ckpt-dir ckpts
//! e2train resume ckpts
//! e2train resume --replica replica/run1
//! e2train serve --replica replica/run1 --clients 2,8
//! e2train exp tab2 --iters 400 --out results
//! e2train serve --clients 2,8 --requests 32 --out BENCH_serve.json
//! e2train serve --registry ckpts --clients 2,8
//! e2train shard-bench --shards 1,2,4 --out BENCH_shard.json
//! e2train train --family refmlp-tiny --trace-out trace.jsonl
//! e2train trace-report trace.jsonl
//! e2train train --family refmlp-tiny --backend auto --catalog OBS_CATALOG.json
//! e2train catalog --ingest trace.jsonl
//! e2train energy-report --family resnet20-c10
//! ```

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use e2train::checkpoint::{
    CheckpointRegistry, FsRemoteStore, RemoteRegistry, RetentionCfg,
};
use e2train::config::{BackendChoice, DataCfg, RunCfg};
use e2train::coordinator::Trainer;
use e2train::experiments;
use e2train::runtime::{ArtifactIndex, Engine};
use e2train::util::cli::Args;

const USAGE: &str = "\
e2train — E2-Train (NeurIPS 2019) energy-efficient CNN training

USAGE:
  e2train <COMMAND> [OPTIONS]

COMMANDS:
  list                          list available (family, method) artifacts
  gen-ref                       write the reference-backend artifact
                                families (refmlp-tiny, refmlp-bench) into
                                the artifacts dir — train/serve/shard
                                without the python AOT toolchain
  train                         train one configuration
    --family <fam>              artifact family   [resnet8-c10-tiny]
    --method <m>                sgd32|fixed8|signsgd|psg|slu|sd|e2train|headft [e2train]
    --iters <n>                 iterations        [300]
    --seed <n>                  rng seed          [0]
    --smd                       enable stochastic mini-batch dropping
    --alpha <f>                 SLU FLOPs-regularizer weight [1.0]
    --beta <f>                  PSG adaptive threshold       [0.05]
    --backend <b>               execution backend: host|resident|sharded|auto
                                (default: resident, or sharded when
                                --shards is set — all are bitwise
                                interchangeable for a fixed seed; `auto`
                                lets the planner pick the layout from
                                the obs_catalog/v1 cost catalog)
    --shards <n>                data-parallel shard count    [0]
                                (not combinable with --backend auto)
    --accum <n>                 micro-batches per step       [1]
                                (gradient accumulation; sharded backend
                                only, bitwise identical for any value)
    --catalog <path>            cost catalog to plan from / recalibrate
                                [OBS_CATALOG.json under --backend auto]
    --energy-budget-j <f>       planner hint: prefer the fastest plan
                                predicted to fit this whole-run energy
                                budget (requires --backend auto)
    --n-train <n>               synthetic train size [2048]
    --n-test <n>                synthetic test size  [512]
    --eval-every <n>            periodic eval every n iters  [0]
    --ckpt-every <n>            write a ckpt/v1 checkpoint every n iters [0]
    --ckpt-dir <dir>            checkpoint registry directory
    --ckpt-keep-last <n>        retention: keep newest n checkpoints [3]
    --ckpt-keep-every <n>       retention: pin every n-th iteration  [0]
    --replicate <root>          evacuate every published checkpoint to
                                this replica root (resumable chunked
                                transfer, verified before publish)
    --config <path>             load a JSON run config instead
    --supervised                run under the recovery supervisor:
                                transient failures restore from the
                                latest checkpoint and retry (implied
                                when the config arms fault injection)
    --trace-out <path>          write an obs_trace/v1 JSONL run trace
                                (observability plane only — the traced
                                run stays bitwise identical)
    --out <path>                write run-metrics JSON
  resume [dir]                  continue a checkpointed run, bitwise
                                identical to the uninterrupted one
    --replica <root>            restore from a replicated registry root
                                when the local dir is gone or behind
                                (fetches are hash+trailer verified;
                                with no [dir] at all, a dead box's run
                                resumes entirely from the replica)
    --iter <n>                  resume a specific checkpointed iteration
                                (default: the newest)
    --supervised                supervise the resumed run (see train)
    --data-dir <dir>            relocated CIFAR binaries (path is not
                                part of the resume fingerprint)
    --backend <b> --shards <n>  resume under a different execution
                                backend than the one that checkpointed
                                (backends are bitwise interchangeable;
                                --accum <n> may change too)
    --trace-out <path>          write an obs_trace/v1 JSONL run trace
    --out <path>                write run-metrics JSON
  exp <id>                      reproduce a paper table/figure
                                fig3a|fig3b|tab1|fig4|tab2|tab3|fig5|tab4|finetune|all
    --iters <n>                 per-run iteration budget [400]
    --out <dir>                 results directory [results]
  shard-bench                   data-parallel sharded-training scaling bench
    --family <fam>              artifact family (reference fixture if absent)
    --shards <a,b,..>           shard counts to sweep  [1,2,4]
                                (each swept with reducer overlap off+on)
    --steps <n>                 timed steps per count  [60]
    --warmup <n>                warmup steps           [3]
    --accum <n>                 micro-batches per step [2]
    --seed <n>                  rng seed               [0]
    --out <path>                report path [BENCH_shard.json]
  serve                         micro-batching inference service bench
    --family <fam>              artifact family (reference fixture if absent)
    --registry <dir>            serve weights from a checkpoint registry
                                (cross-process publish: no in-process
                                trainer; hot-loads new checkpoints)
    --replica <root>            serve from a replicated registry root in
                                another failure domain (hot-loads are
                                hash+trailer verified; excludes
                                --registry)
    --clients <a,b,..>          client concurrency levels [2,8]
    --requests <n>              requests per client       [32]
    --req-size <n>              samples per request       [2]
    --workers <n>               eval worker threads       [2]
    --delay-ms <n>              batcher flush deadline    [2]
    --micro-batch <n|auto>      serve micro-batch: a size, or `auto` to
                                pick the fastest measured one from the
                                catalog [the artifact's eval batch]
    --catalog <path>            cost catalog for --micro-batch auto;
                                measured serve-infer spans recalibrate
                                it after the sweep
    --seed <n>                  rng seed                  [0]
    --out <path>                report path [BENCH_serve.json]
  trace-report <file.jsonl>     render an obs_trace/v1 run trace as a
                                per-phase table (count, total/mean ms,
                                p50/p99, % of run) plus counters and
                                recovery events
    --json                      emit the same aggregates as
                                machine-readable trace_report/v1 JSON
  catalog [file]                inspect the obs_catalog/v1 cost catalog
                                [OBS_CATALOG.json]
    --merge <a,b,..>            fold other catalog files in, then save
    --ingest <a,b,..>           re-histogram obs_trace/v1 JSONL files
                                into the catalog, then save
    --out <path>                write result here instead of in place
  energy-report                 analytic energy model vs paper anchors
    --family <fam>              [resnet20-c10]

GLOBAL:
  --artifacts <dir>             artifacts directory [artifacts]
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");

    match cmd {
        "list" => {
            let idx = ArtifactIndex::load(&artifacts)?;
            println!("{:<22} {:>7} {:>10}  methods", "family", "batch", "eval_batch");
            for (fam, e) in &idx.families {
                println!(
                    "{:<22} {:>7} {:>10}  {}",
                    fam,
                    e.batch,
                    e.eval_batch,
                    e.methods.join(",")
                );
            }
        }
        "gen-ref" => {
            // Materialize the reference families (manifest + train/eval/
            // grad programs) so CLI runs — including the sharded launcher
            // configs — work end-to-end on machines without python/jax.
            std::fs::create_dir_all(&artifacts)?;
            for spec in [
                e2train::runtime::RefFamilySpec::tiny(),
                e2train::runtime::RefFamilySpec::bench(),
            ] {
                let fam = e2train::runtime::write_reference_family(&artifacts, &spec)?;
                println!("reference family -> {}", fam.display());
            }
        }
        "train" => {
            let mut cfg = match args.get("config") {
                Some(p) => RunCfg::load(std::path::Path::new(p))?,
                None => {
                    let family = args.str_or("family", "resnet8-c10-tiny");
                    let method = args.str_or("method", "e2train");
                    let iters = args.u64_or("iters", 300)?;
                    let seed = args.u64_or("seed", 0)?;
                    let mut c = RunCfg::quick(&family, &method, iters);
                    c.seed = seed;
                    c.smd.enabled = args.bool("smd") || c.smd.enabled;
                    c.alpha = args.f64_or("alpha", c.alpha)?;
                    c.beta = args.f64_or("beta", c.beta)?;
                    c.eval_every = args.u64_or("eval-every", 0)?;
                    c.data = DataCfg::Synthetic {
                        classes: 10, // fixed up by Trainer vs manifest
                        n_train: args.usize_or("n-train", 2048)?,
                        n_test: args.usize_or("n-test", 512)?,
                        seed,
                    };
                    c.checkpoint.every = args.u64_or("ckpt-every", 0)?;
                    c.checkpoint.dir = args.get("ckpt-dir").map(PathBuf::from);
                    c.checkpoint.keep_last = args.usize_or("ckpt-keep-last", 3)?;
                    c.checkpoint.keep_every = args.u64_or("ckpt-keep-every", 0)?;
                    c.checkpoint.replicate = args.get("replicate").map(PathBuf::from);
                    if c.checkpoint.every > 0 && c.checkpoint.dir.is_none() {
                        bail!("--ckpt-every needs --ckpt-dir");
                    }
                    if c.checkpoint.replicate.is_some() && c.checkpoint.every == 0 {
                        bail!("--replicate needs --ckpt-every/--ckpt-dir (nothing is ever published to evacuate)");
                    }
                    c
                }
            };
            // Flags override whichever source built the config (quick
            // flags or --config launcher) — never silently ignored.
            apply_backend_flags(&mut cfg, &args)?;
            if let Some(p) = args.get("trace-out") {
                cfg.trace_out = Some(PathBuf::from(p));
            }
            // Planner knobs (layout hints — outside the determinism
            // fingerprint, like --backend itself).
            if args.get("energy-budget-j").is_some() {
                let v = args.f64_or("energy-budget-j", 0.0)?;
                if !(v.is_finite() && v > 0.0) {
                    bail!("--energy-budget-j must be a positive number");
                }
                cfg.energy_budget_j = Some(v);
            }
            if let Some(p) = args.get("catalog") {
                cfg.catalog = Some(PathBuf::from(p));
            }
            if cfg.energy_budget_j.is_some()
                && cfg.resolved_backend() != BackendChoice::Auto
            {
                bail!("--energy-budget-j is a planner hint — it requires --backend auto");
            }
            cfg.artifacts_dir = artifacts;
            // Align the synthetic class count with the artifact.
            let manifest = e2train::runtime::Manifest::load(&cfg.manifest_path())?;
            if let DataCfg::Synthetic { classes, .. } = &mut cfg.data {
                *classes = manifest.arch.num_classes;
            }
            // Supervision is explicit (--supervised) or implied by a
            // config that arms fault injection — injected faults only
            // make sense under the recovery loop that absorbs them.
            let supervised = args.bool("supervised") || cfg.faults.enabled();
            let engine = Engine::cpu()?;
            let mut trainer = Trainer::new(&engine, cfg)?;
            let outcome = if supervised {
                trainer.run_supervised()?
            } else {
                trainer.run(None)?
            };
            println!(
                "final: acc={:.4} top5={:.4} loss={:.4} J={:.3} steps={} skipped={} recoveries={}",
                outcome.metrics.final_test_acc,
                outcome.metrics.final_test_acc_top5,
                outcome.metrics.final_loss,
                outcome.metrics.total_joules,
                outcome.metrics.steps_run,
                outcome.metrics.steps_skipped,
                outcome.metrics.recoveries,
            );
            if let Some(p) = args.get("out") {
                std::fs::write(p, outcome.metrics.to_json())?;
                println!("metrics -> {p}");
            }
        }
        "resume" => {
            // The starting checkpoint comes from a local registry dir
            // (positional), a --replica root, or both — local wins and
            // the replica is the cross-failure-domain fallback, the
            // same ladder the supervisor walks on every restart.  A
            // dead training box therefore resumes with no local
            // registry at all: `e2train resume --replica <root>`.
            let dir = args.positional.get(1).cloned();
            let replica = args.get("replica").map(PathBuf::from);
            if dir.is_none() && replica.is_none() {
                bail!("resume needs a checkpoint registry directory (or --replica <root>)");
            }
            let pinned = args.get("iter").is_some();
            let mut ckpt = None;
            if let Some(d) = &dir {
                let registry = CheckpointRegistry::new(d, RetentionCfg::default());
                ckpt = match pinned {
                    true => Some(registry.load_iter(args.u64_or("iter", 0)?)?),
                    false => registry.load_latest()?,
                };
            }
            let (ckpt, from) = match (ckpt, &replica) {
                (Some(c), _) => (c, dir.clone().unwrap()),
                (None, Some(root)) => {
                    // Every replica fetch is hash- and trailer-verified
                    // before it is admitted, so a truncated transfer or
                    // bit-flipped replica fails here instead of
                    // resuming from corrupt state.
                    let remote =
                        RemoteRegistry::new(Box::new(FsRemoteStore::new(root)));
                    let c = match pinned {
                        true => remote.load_iter(args.u64_or("iter", 0)?)?,
                        false => remote.load_latest()?.ok_or_else(|| {
                            anyhow!("no checkpoints under replica {}", root.display())
                        })?,
                    };
                    (c, format!("replica {}", root.display()))
                }
                (None, None) => bail!("no checkpoints under {}", dir.unwrap()),
            };
            // The checkpoint embeds its full run config, so no launcher
            // file is needed; --artifacts / --data-dir relocate what
            // may have moved across the interruption (neither path is
            // part of the determinism fingerprint).
            let mut cfg = ckpt.cfg.clone();
            if let Some(a) = args.get("artifacts") {
                cfg.artifacts_dir = PathBuf::from(a);
            }
            if let Some(d) = args.get("data-dir") {
                match &mut cfg.data {
                    DataCfg::CifarBin { dir } => *dir = PathBuf::from(d),
                    _ => bail!("--data-dir only applies to cifar_bin runs"),
                }
            }
            // Backends are bitwise interchangeable, so a checkpoint may
            // legally resume under a different one (--backend/--shards
            // override the embedded layout; not part of the fingerprint).
            apply_backend_flags(&mut cfg, &args)?;
            // Like the layout knobs, tracing is outside the fingerprint:
            // a resumed run may trace even if the original didn't.
            if let Some(p) = args.get("trace-out") {
                cfg.trace_out = Some(PathBuf::from(p));
            }
            println!(
                "resuming {}/{} at iter {}/{} from {from}",
                cfg.family, cfg.method, ckpt.iter, cfg.iters
            );
            let supervised = args.bool("supervised") || cfg.faults.enabled();
            let engine = Engine::cpu()?;
            let outcome = if supervised {
                // The supervisor owns checkpoint selection (it restores
                // from the newest readable one, possibly several times),
                // so a pinned --iter contradicts it.
                if pinned {
                    bail!("--iter cannot combine with --supervised (the supervisor always restores the latest checkpoint)");
                }
                // Restore from the sources the user pointed at, not
                // wherever the embedded config once looked: the local
                // registry first (when given), then the replica root.
                if let Some(d) = &dir {
                    cfg.checkpoint.dir = Some(PathBuf::from(d));
                }
                if replica.is_some() {
                    cfg.checkpoint.replica = replica.clone();
                }
                let mut trainer = Trainer::new(&engine, cfg)?;
                trainer.run_supervised()?
            } else {
                let mut trainer = Trainer::new(&engine, cfg)?;
                trainer.resume(ckpt)?
            };
            println!(
                "final: acc={:.4} top5={:.4} loss={:.4} J={:.3} steps={} skipped={} recoveries={}",
                outcome.metrics.final_test_acc,
                outcome.metrics.final_test_acc_top5,
                outcome.metrics.final_loss,
                outcome.metrics.total_joules,
                outcome.metrics.steps_run,
                outcome.metrics.steps_skipped,
                outcome.metrics.recoveries,
            );
            if let Some(p) = args.get("out") {
                std::fs::write(p, outcome.metrics.to_json())?;
                println!("metrics -> {p}");
            }
        }
        "exp" => {
            let id = args
                .positional
                .get(1)
                .map(String::as_str)
                .unwrap_or("all");
            let iters = args.u64_or("iters", 400)?;
            let out = PathBuf::from(args.str_or("out", "results"));
            experiments::run_experiment(id, iters, &artifacts, &out)?;
        }
        "shard-bench" => {
            let cfg = experiments::ShardBenchCfg {
                shard_counts: args.usize_list_or("shards", &[1, 2, 4])?,
                warmup_steps: args.usize_or("warmup", 3)?,
                steps: args.usize_or("steps", 60)?,
                accum: args.usize_or("accum", 2)?,
                seed: args.u64_or("seed", 0)?,
                source: if cfg!(debug_assertions) {
                    "e2train shard-bench (debug profile)"
                } else {
                    "e2train shard-bench (release profile)"
                }
                .into(),
            };
            let fixture = e2train::runtime::RefFamilySpec::bench();
            // Sharded training needs a grad-emitting program, which only
            // reference families provide today; an explicit --family
            // without one fails with a message saying so.
            let (manifest, _fixture_guard) = experiments::resolve_bench_family(
                &artifacts,
                args.get("family"),
                &fixture,
            )?;
            let engine = Engine::cpu()?;
            let report = experiments::run_shard_bench(&engine, &manifest, &cfg)?;
            let out = args.str_or("out", "BENCH_shard.json");
            std::fs::write(&out, report.to_string())?;
            println!("shard bench -> {out}");
        }
        "serve" => {
            let (micro_batch, auto_micro_batch) = match args.get("micro-batch") {
                None => (None, false),
                Some("auto") => (None, true),
                Some(v) => (
                    Some(v.parse::<usize>().map_err(|_| {
                        anyhow!("--micro-batch expects a positive integer or `auto`")
                    })?),
                    false,
                ),
            };
            let catalog = match args.get("catalog") {
                Some(p) => Some(PathBuf::from(p)),
                // `auto` without an explicit path uses the default
                // catalog file, same as `train --backend auto`.
                None if auto_micro_batch => Some(PathBuf::from(
                    e2train::obs::catalog::DEFAULT_CATALOG_FILE,
                )),
                None => None,
            };
            let cfg = experiments::ServeBenchCfg {
                levels: args.usize_list_or("clients", &[2, 8])?,
                requests_per_client: args.usize_or("requests", 32)?,
                samples_per_request: args.usize_or("req-size", 2)?,
                workers: args.usize_or("workers", 2)?,
                max_delay: std::time::Duration::from_millis(args.u64_or("delay-ms", 2)?),
                seed: args.u64_or("seed", 0)?,
                registry: args.get("registry").map(PathBuf::from),
                replica: args.get("replica").map(PathBuf::from),
                micro_batch,
                auto_micro_batch,
                catalog,
                source: if cfg!(debug_assertions) {
                    "e2train serve (debug profile)"
                } else {
                    "e2train serve (release profile)"
                }
                .into(),
            };
            let fixture = e2train::runtime::RefFamilySpec::bench();
            // Real artifacts when built, the reference fixture otherwise
            // (the guard keeps the generated family alive for the run).
            let (manifest, _fixture_guard) = experiments::resolve_bench_family(
                &artifacts,
                args.get("family"),
                &fixture,
            )?;
            let engine = Engine::cpu()?;
            let report = experiments::run_serve_bench(&engine, &manifest, &cfg)?;
            let out = args.str_or("out", "BENCH_serve.json");
            std::fs::write(&out, report.to_string())?;
            println!("serve bench -> {out}");
        }
        "trace-report" => {
            let file = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("trace-report needs an obs_trace/v1 JSONL file"))?;
            let text = std::fs::read_to_string(file)
                .map_err(|e| anyhow!("reading {file}: {e}"))?;
            let rep = e2train::obs::report::aggregate(&text)?;
            if args.bool("json") {
                println!("{}", rep.to_json().to_string());
            } else {
                print!("{}", rep.render());
            }
        }
        "catalog" => {
            use e2train::obs::catalog::{Catalog, DEFAULT_CATALOG_FILE};
            let file = PathBuf::from(
                args.positional
                    .get(1)
                    .map(String::as_str)
                    .unwrap_or(DEFAULT_CATALOG_FILE),
            );
            let mut cat = Catalog::load_or_empty(&file)?;
            let mut changed = false;
            if let Some(list) = args.get("merge") {
                for p in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                    cat.merge(&Catalog::load(std::path::Path::new(p))?);
                    changed = true;
                }
            }
            if let Some(list) = args.get("ingest") {
                for p in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                    let text = std::fs::read_to_string(p)
                        .map_err(|e| anyhow!("reading {p}: {e}"))?;
                    cat.ingest_trace(&text)
                        .map_err(|e| anyhow!("ingesting {p}: {e:#}"))?;
                    changed = true;
                }
            }
            let out = args.get("out").map(PathBuf::from);
            if changed || out.is_some() {
                let dest = out.unwrap_or_else(|| file.clone());
                cat.save(&dest)?;
                println!("catalog ({} entries) -> {}", cat.len(), dest.display());
            }
            print!("{}", cat.render());
        }
        "energy-report" => {
            let family = args.str_or("family", "resnet20-c10");
            experiments::energy_report(&family, &artifacts)?;
        }
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
        }
        other => {
            eprint!("{USAGE}");
            bail!("unknown command {other:?}");
        }
    }
    Ok(())
}

/// Apply `--backend` / `--shards` / `--accum` overrides to a run config
/// from any source — quick flags, a `--config` launcher, or a
/// checkpoint's embedded config — so the flags are never silently ignored.  A
/// single-executor `--backend` clears an inherited shard count unless
/// `--shards` is pinned explicitly; the combination is then validated
/// like any other config.
fn apply_backend_flags(cfg: &mut RunCfg, args: &Args) -> Result<()> {
    let backend = args.get("backend").map(BackendChoice::parse).transpose()?;
    let shards = match args.get("shards") {
        Some(_) => Some(args.usize_or("shards", 0)?),
        None => None,
    };
    if let Some(b) = backend {
        cfg.backend = Some(b);
        if b != BackendChoice::Sharded && shards.is_none() {
            cfg.shards = 0;
        }
    }
    if let Some(s) = shards {
        cfg.shards = s;
    }
    if args.get("accum").is_some() {
        cfg.accum = args.usize_or("accum", 1)?;
    }
    cfg.validate_backend()
}
