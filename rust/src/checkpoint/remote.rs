//! Remote checkpoint stores and the pull-through replica registry.
//!
//! [`RemoteStore`] is the evacuation target abstraction: a flat
//! namespace of objects with **staged** (resumable, append-only) and
//! **final** (atomically promoted) states.  The filesystem
//! implementation ([`FsRemoteStore`]) models a mounted replica root in
//! another failure domain; the trait is deliberately narrow —
//! staged-append / promote / read / atomic-write is exactly the subset
//! an object store with multipart uploads can provide, so an S3/GCS
//! implementation slots in without touching the replicator.
//!
//! The transfer protocol ([`super::Replicator`] drives it):
//!
//! 1. upload chunks append to a *staged* object, never the final name;
//! 2. a partial upload survives as staged bytes — the next attempt
//!    compares them against the local prefix and resumes from the last
//!    verified offset instead of restarting;
//! 3. the staged object is promoted (atomic rename) only after its
//!    full FNV-1a-64 hash matches the local manifest entry;
//! 4. the remote `MANIFEST.json` (same `ckpt_registry/v1` schema as the
//!    local registry) is rewritten atomically after the payload is
//!    final, so a replica reader never sees a listed-but-unverified
//!    checkpoint.
//!
//! [`RemoteRegistry`] is the consuming side: a serve fleet or a resumed
//! run in another failure domain reads the replica manifest and
//! fetches-and-verifies checkpoints (manifest hash **and** `ckpt/v1`
//! trailer checked before admission), optionally through a local cache
//! directory.  Torn remote manifests and truncated transfers surface as
//! clean errors; [`RemoteRegistry::entries_with_retry`] and
//! [`RemoteRegistry::load_latest_with_retry`] absorb them with the same
//! deterministic capped backoff the supervisor uses.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::fault::{self, FaultPlan};
use crate::util::hash::fnv1a64_hex;
use crate::util::rng::Rng;

use super::format::{self, CheckpointData};
use super::registry::{self, CheckpointEntry};

/// The replica manifest object name (same schema as the local
/// registry's `MANIFEST.json`: `ckpt_registry/v1`).
pub const REMOTE_MANIFEST: &str = "MANIFEST.json";

/// True when the error chain bottoms out in a filesystem NotFound —
/// "object absent" as opposed to "read failed", which the replica
/// protocol treats very differently (empty vs retry).
pub(crate) fn is_not_found(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>()
            .is_some_and(|io| io.kind() == std::io::ErrorKind::NotFound)
    })
}

/// An evacuation target in another failure domain.  Objects live in a
/// flat namespace; each can exist in a *staged* (partial, resumable)
/// and a *final* (promoted, immutable) state.  All methods take `&self`
/// — implementations synchronize internally if they must.
pub trait RemoteStore: Send + Sync {
    /// Human-readable location for logs and error contexts.
    fn describe(&self) -> String;

    /// Bytes currently staged for `name` (0 when nothing is staged).
    fn staged_len(&self, name: &str) -> Result<u64>;

    /// Read the first `len` staged bytes of `name`.
    fn read_staged(&self, name: &str, len: u64) -> Result<Vec<u8>>;

    /// Append `data` to the staged object at `offset`, which must equal
    /// the current staged length (the resume protocol never writes
    /// holes).  A failure may leave a *prefix* of `data` staged —
    /// truncated transfers are the expected failure mode, and the next
    /// attempt resumes from whatever verified bytes survived.
    fn append_staged(&self, name: &str, offset: u64, data: &[u8]) -> Result<()>;

    /// Atomically promote the staged object to its final name.
    fn promote(&self, name: &str) -> Result<()>;

    /// Discard any staged bytes for `name` (absent staged state is not
    /// an error — abort is idempotent).
    fn abort_staged(&self, name: &str) -> Result<()>;

    /// Read a final object in full.
    fn read(&self, name: &str) -> Result<Vec<u8>>;

    /// True when the final object exists.
    fn exists(&self, name: &str) -> Result<bool>;

    /// Atomically replace a small final object (the manifest): readers
    /// see the old bytes or the new bytes, never a mix — except where a
    /// torn write is *injected* (`replicate.manifest`), which is
    /// exactly the failure replica readers must reject.
    fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<()>;
}

/// Filesystem-backed [`RemoteStore`]: the replica root is a directory,
/// typically a mount from another failure domain (NFS, a second disk, a
/// synced folder).  Staged objects are dot-prefixed siblings
/// (`.stage-<name>`), so replica readers that list final names never
/// see partial uploads.
pub struct FsRemoteStore {
    root: PathBuf,
    faults: Option<Arc<FaultPlan>>,
}

impl FsRemoteStore {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into(), faults: None }
    }

    /// Arm fault injection: `replicate.upload` truncates a staged
    /// append, `replicate.manifest` tears an atomic manifest write, and
    /// `remote.read` fails a read transiently.
    pub fn with_faults(mut self, faults: Arc<FaultPlan>) -> Self {
        self.faults = Some(faults);
        self
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn final_path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    fn staged_path(&self, name: &str) -> PathBuf {
        self.root.join(format!(".stage-{name}"))
    }
}

impl RemoteStore for FsRemoteStore {
    fn describe(&self) -> String {
        self.root.display().to_string()
    }

    fn staged_len(&self, name: &str) -> Result<u64> {
        match std::fs::metadata(self.staged_path(name)) {
            Ok(m) => Ok(m.len()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(0),
            Err(e) => Err(e).with_context(|| {
                format!("stat of staged {} under {}", name, self.root.display())
            }),
        }
    }

    fn read_staged(&self, name: &str, len: u64) -> Result<Vec<u8>> {
        let path = self.staged_path(name);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading staged {}", path.display()))?;
        if (bytes.len() as u64) < len {
            bail!(
                "staged {} holds {} bytes, {} requested",
                path.display(),
                bytes.len(),
                len
            );
        }
        Ok(bytes[..len as usize].to_vec())
    }

    fn append_staged(&self, name: &str, offset: u64, data: &[u8]) -> Result<()> {
        use std::io::Write;
        std::fs::create_dir_all(&self.root)
            .with_context(|| format!("creating replica root {}", self.root.display()))?;
        let path = self.staged_path(name);
        let cur = self.staged_len(name)?;
        if cur != offset {
            bail!(
                "staged {} is at {} bytes but the append targets offset {}",
                path.display(),
                cur,
                offset
            );
        }
        // An armed `replicate.upload` fault truncates this append: only
        // a prefix of `data` lands, then the transfer errors — the
        // canonical mid-upload network/power loss.  The surviving
        // prefix is real staged state the resume path must handle.
        let shot = self
            .faults
            .as_ref()
            .and_then(|p| p.hit(fault::SITE_REPLICATE_UPLOAD));
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening staged {}", path.display()))?;
        match shot {
            None => f
                .write_all(data)
                .with_context(|| format!("appending to staged {}", path.display())),
            Some(s) => {
                let keep = (s.after_bytes.unwrap_or(0) as usize).min(data.len());
                f.write_all(&data[..keep])
                    .with_context(|| format!("appending to staged {}", path.display()))?;
                let _ = f.flush();
                Err(anyhow::Error::new(fault::InjectedFault::new(
                    fault::SITE_REPLICATE_UPLOAD,
                ))
                .context(format!(
                    "upload to {} truncated after {keep} of {} bytes",
                    path.display(),
                    data.len()
                )))
            }
        }
    }

    fn promote(&self, name: &str) -> Result<()> {
        registry::rename_into_place(&self.staged_path(name), &self.final_path(name))
    }

    fn abort_staged(&self, name: &str) -> Result<()> {
        match std::fs::remove_file(self.staged_path(name)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| {
                format!("aborting staged {} under {}", name, self.root.display())
            }),
        }
    }

    fn read(&self, name: &str) -> Result<Vec<u8>> {
        if let Some(p) = &self.faults {
            p.check(fault::SITE_REMOTE_READ).map_err(|e| {
                anyhow::Error::new(e).context(format!(
                    "reading {} from replica {} (transient)",
                    name,
                    self.root.display()
                ))
            })?;
        }
        let path = self.final_path(name);
        std::fs::read(&path)
            .with_context(|| format!("reading replica object {}", path.display()))
    }

    fn exists(&self, name: &str) -> Result<bool> {
        Ok(self.final_path(name).exists())
    }

    fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<()> {
        std::fs::create_dir_all(&self.root)
            .with_context(|| format!("creating replica root {}", self.root.display()))?;
        let path = self.final_path(name);
        // An armed `replicate.manifest` fault lands a *torn* document at
        // the final path and errors — the one failure the atomic
        // temp+rename protocol exists to prevent, injected so replica
        // readers prove they reject it.
        if let Some(p) = &self.faults {
            if p.hit(fault::SITE_REPLICATE_MANIFEST).is_some() {
                let torn = &bytes[..bytes.len() / 2];
                std::fs::write(&path, torn)
                    .with_context(|| format!("tearing {}", path.display()))?;
                return Err(anyhow::Error::new(fault::InjectedFault::new(
                    fault::SITE_REPLICATE_MANIFEST,
                ))
                .context(format!(
                    "manifest write to {} torn after {} of {} bytes",
                    path.display(),
                    torn.len(),
                    bytes.len()
                )));
            }
        }
        registry::write_atomic(&path, bytes)
    }
}

/// Pull-through reader over a [`RemoteStore`]: the replica-side
/// counterpart of [`super::CheckpointRegistry`].  Every fetched
/// checkpoint is verified twice before admission — whole-file FNV-1a-64
/// against the manifest entry, then the `ckpt/v1` trailer
/// ([`format::verify_trailer`]) — so a truncated transfer, a bit-flip
/// in transit, or a replica listing it never produced is rejected with
/// a clean error before any decode.  With a cache directory attached,
/// verified bytes are written through atomically and later fetches of
/// the same entry are served locally.
pub struct RemoteRegistry {
    store: Box<dyn RemoteStore>,
    cache_dir: Option<PathBuf>,
    /// Deterministic capped backoff for the `_with_retry` helpers
    /// (mirrors the supervisor: `base << min(k, 6)` ms + seeded jitter).
    max_retries: u64,
    backoff_ms: u64,
    seed: u64,
}

impl RemoteRegistry {
    pub fn new(store: Box<dyn RemoteStore>) -> Self {
        Self { store, cache_dir: None, max_retries: 4, backoff_ms: 10, seed: 0 }
    }

    /// Write verified checkpoints through to `dir` and serve repeat
    /// fetches from it (hash-checked on the way back out, so a corrupted
    /// cache falls through to the remote instead of poisoning a resume).
    pub fn with_cache(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Tune the `_with_retry` helpers (defaults mirror `FaultsCfg`).
    pub fn with_retry_policy(mut self, max_retries: u64, backoff_ms: u64, seed: u64) -> Self {
        self.max_retries = max_retries;
        self.backoff_ms = backoff_ms.max(1);
        self.seed = seed;
        self
    }

    /// Human-readable replica location for logs.
    pub fn describe(&self) -> String {
        self.store.describe()
    }

    /// All replicated checkpoints, ascending by iteration.  An absent
    /// manifest reads as an empty replica; a torn or truncated one is a
    /// clean (transient) error.
    pub fn entries(&self) -> Result<Vec<CheckpointEntry>> {
        let text = match self.store.read(REMOTE_MANIFEST) {
            Ok(bytes) => String::from_utf8(bytes).map_err(|_| {
                anyhow!(
                    "replica manifest at {} is not UTF-8 (torn write?)",
                    self.store.describe()
                )
            })?,
            Err(e) => {
                // A replica that was never written to is empty, not
                // broken; injected transient read errors stay errors.
                if is_not_found(&e) && !fault::is_injected(&e) {
                    return Ok(Vec::new());
                }
                return Err(e);
            }
        };
        registry::parse_manifest(&text).with_context(|| {
            format!("parsing replica manifest at {}", self.store.describe())
        })
    }

    /// The newest replicated checkpoint entry, if any.
    pub fn latest(&self) -> Result<Option<CheckpointEntry>> {
        Ok(self.entries()?.into_iter().last())
    }

    /// Raw (unverified) bytes of one listed checkpoint — cache first,
    /// then the remote.  Callers that skip [`RemoteRegistry::fetch`]
    /// must verify hash + trailer themselves (the serve watcher does,
    /// counting rejects).
    pub fn read_entry_bytes(&self, entry: &CheckpointEntry) -> Result<Vec<u8>> {
        if let Some(dir) = &self.cache_dir {
            let cached = dir.join(&entry.file);
            if let Ok(bytes) = std::fs::read(&cached) {
                if fnv1a64_hex(&bytes) == entry.hash {
                    return Ok(bytes);
                }
                // Corrupt cache: fall through to the remote.
            }
        }
        self.store.read(&entry.file)
    }

    /// Fetch + verify one listed checkpoint's bytes: manifest hash,
    /// then `ckpt/v1` trailer, then (on success) write-through to the
    /// cache.  The admission gate for everything replica-sourced.
    pub fn fetch(&self, entry: &CheckpointEntry) -> Result<Vec<u8>> {
        let bytes = self.read_entry_bytes(entry)?;
        let hash = fnv1a64_hex(&bytes);
        if hash != entry.hash {
            bail!(
                "replica checkpoint {} hash {hash} does not match manifest ({}): \
                 transfer truncated or replica corrupt",
                entry.file,
                entry.hash
            );
        }
        format::verify_trailer(&bytes).with_context(|| {
            format!("verifying replica checkpoint {} before admission", entry.file)
        })?;
        if let Some(dir) = &self.cache_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating replica cache {}", dir.display()))?;
            registry::write_atomic(&dir.join(&entry.file), &bytes)?;
        }
        Ok(bytes)
    }

    /// Fetch, verify and decode one listed checkpoint.
    pub fn load(&self, entry: &CheckpointEntry) -> Result<CheckpointData> {
        let bytes = self.fetch(entry)?;
        format::decode(&bytes).with_context(|| {
            format!("decoding replica checkpoint {}", entry.file)
        })
    }

    /// Load the newest replicated checkpoint, `None` for an empty
    /// replica.
    pub fn load_latest(&self) -> Result<Option<CheckpointData>> {
        match self.latest()? {
            Some(e) => Ok(Some(self.load(&e)?)),
            None => Ok(None),
        }
    }

    /// Load the checkpoint replicated at a specific iteration.
    pub fn load_iter(&self, iter: u64) -> Result<CheckpointData> {
        let entries = self.entries()?;
        let entry = entries.iter().find(|e| e.iter == iter).ok_or_else(|| {
            anyhow!(
                "no replicated checkpoint at iter {iter} under {} (have: {})",
                self.store.describe(),
                entries
                    .iter()
                    .map(|e| e.iter.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
        self.load(entry)
    }

    /// [`entries`](Self::entries) behind the deterministic capped
    /// backoff: transient remote failures (torn manifest, injected read
    /// error) are retried up to the budget.
    pub fn entries_with_retry(&self) -> Result<Vec<CheckpointEntry>> {
        self.retrying("listing replica", |r| r.entries())
    }

    /// [`load_latest`](Self::load_latest) behind the same backoff.
    pub fn load_latest_with_retry(&self) -> Result<Option<CheckpointData>> {
        self.retrying("loading latest replica checkpoint", |r| r.load_latest())
    }

    fn retrying<T>(
        &self,
        what: &str,
        op: impl Fn(&Self) -> Result<T>,
    ) -> Result<T> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0x5e41_b0ff);
        let mut attempt: u64 = 0;
        loop {
            match op(self) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    attempt += 1;
                    if attempt > self.max_retries {
                        return Err(e.context(format!(
                            "{what} from {}: retry budget exhausted ({} retries)",
                            self.store.describe(),
                            self.max_retries
                        )));
                    }
                    let exp = self.backoff_ms << (attempt - 1).min(6);
                    let jitter = rng.below(self.backoff_ms as usize + 1) as u64;
                    let delay = Duration::from_millis(exp + jitter);
                    eprintln!(
                        "[replica] {what} failed ({e:#}); retrying in {}ms",
                        delay.as_millis()
                    );
                    std::thread::sleep(delay);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::tests::toy_checkpoint;
    use crate::checkpoint::registry::{CheckpointRegistry, RetentionCfg};
    use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};
    use crate::util::tmp::TempDir;

    fn site(name: &str, at: u64, times: u64, after_bytes: Option<u64>) -> FaultSiteCfg {
        FaultSiteCfg { site: name.into(), at, times, after_bytes }
    }

    fn plan_for(sites: Vec<FaultSiteCfg>) -> Arc<FaultPlan> {
        FaultPlan::from_cfg(&FaultsCfg { sites, ..Default::default() }, 0).unwrap()
    }

    /// A published local entry + its verified bytes, for upload tests.
    fn published_entry(dir: &Path, iter: u64) -> (CheckpointEntry, Vec<u8>) {
        let reg = CheckpointRegistry::new(dir, RetentionCfg::default());
        let mut data = toy_checkpoint();
        data.iter = iter;
        let entry = reg.publish(&data).unwrap();
        let bytes = reg.load_bytes(&entry).unwrap();
        (entry, bytes)
    }

    #[test]
    fn staged_append_promote_roundtrip() {
        let tmp = TempDir::new().unwrap();
        let store = FsRemoteStore::new(tmp.path().join("replica"));
        assert_eq!(store.staged_len("obj").unwrap(), 0);
        store.append_staged("obj", 0, b"hello ").unwrap();
        store.append_staged("obj", 6, b"world").unwrap();
        assert_eq!(store.staged_len("obj").unwrap(), 11);
        assert_eq!(store.read_staged("obj", 5).unwrap(), b"hello");
        // wrong offset = protocol violation, not silent corruption
        assert!(store.append_staged("obj", 3, b"x").is_err());
        assert!(!store.exists("obj").unwrap());
        store.promote("obj").unwrap();
        assert!(store.exists("obj").unwrap());
        assert_eq!(store.read("obj").unwrap(), b"hello world");
        assert_eq!(store.staged_len("obj").unwrap(), 0, "staging consumed");
        // abort is idempotent on absent staged state
        store.abort_staged("obj").unwrap();
    }

    #[test]
    fn injected_upload_fault_leaves_a_resumable_prefix() {
        let tmp = TempDir::new().unwrap();
        let plan = plan_for(vec![site(
            fault::SITE_REPLICATE_UPLOAD,
            1,
            1,
            Some(4),
        )]);
        let store =
            FsRemoteStore::new(tmp.path().join("replica")).with_faults(plan.clone());
        let err = store.append_staged("obj", 0, b"abcdefgh").unwrap_err();
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");
        // the truncated prefix survives as staged state ...
        assert_eq!(store.staged_len("obj").unwrap(), 4);
        assert_eq!(store.read_staged("obj", 4).unwrap(), b"abcd");
        // ... and the resumed append (site spent) completes the object
        store.append_staged("obj", 4, b"efgh").unwrap();
        store.promote("obj").unwrap();
        assert_eq!(store.read("obj").unwrap(), b"abcdefgh");
        assert_eq!(plan.fired(fault::SITE_REPLICATE_UPLOAD), 1);
    }

    #[test]
    fn injected_manifest_tear_is_visible_then_repaired() {
        let tmp = TempDir::new().unwrap();
        let plan = plan_for(vec![site(fault::SITE_REPLICATE_MANIFEST, 1, 1, None)]);
        let store =
            FsRemoteStore::new(tmp.path().join("replica")).with_faults(plan.clone());
        let doc = br#"{"schema": "ckpt_registry/v1", "checkpoints": []}"#;
        let err = store.write_atomic(REMOTE_MANIFEST, doc).unwrap_err();
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");
        // the torn bytes are visible at the final path — and rejected
        // by the reader as a clean error, not a panic
        let reg = RemoteRegistry::new(Box::new(FsRemoteStore::new(
            tmp.path().join("replica"),
        )));
        assert!(reg.entries().is_err(), "torn manifest accepted");
        // the retried write (site spent) repairs it atomically
        store.write_atomic(REMOTE_MANIFEST, doc).unwrap();
        assert!(reg.entries().unwrap().is_empty());
    }

    #[test]
    fn fetch_verifies_hash_and_trailer_and_caches() {
        let tmp = TempDir::new().unwrap();
        let local = tmp.path().join("local");
        let (entry, bytes) = published_entry(&local, 7);

        let root = tmp.path().join("replica");
        let store = FsRemoteStore::new(&root);
        store.append_staged(&entry.file, 0, &bytes).unwrap();
        store.promote(&entry.file).unwrap();
        store
            .write_atomic(
                REMOTE_MANIFEST,
                registry::manifest_json(std::slice::from_ref(&entry))
                    .to_string()
                    .as_bytes(),
            )
            .unwrap();

        let cache = tmp.path().join("cache");
        let reg = RemoteRegistry::new(Box::new(FsRemoteStore::new(&root)))
            .with_cache(&cache);
        let got = reg.entries().unwrap();
        assert_eq!(got, vec![entry.clone()]);
        assert_eq!(reg.load(&entry).unwrap().iter, 7);
        assert!(cache.join(&entry.file).exists(), "verified bytes cached");
        // a later fetch is served from the cache even if the remote
        // object vanishes
        std::fs::remove_file(root.join(&entry.file)).unwrap();
        assert_eq!(reg.load(&entry).unwrap().iter, 7);

        // truncated replica object: rejected before decode
        store.append_staged(&entry.file, 0, &bytes[..bytes.len() / 2]).unwrap();
        store.promote(&entry.file).unwrap();
        let fresh = RemoteRegistry::new(Box::new(FsRemoteStore::new(&root)));
        let msg = format!("{:#}", fresh.fetch(&entry).unwrap_err());
        assert!(msg.contains("hash"), "unexpected rejection: {msg}");
        // bit-flipped replica object: ditto
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        store.abort_staged(&entry.file).unwrap();
        store.append_staged(&entry.file, 0, &bad).unwrap();
        store.promote(&entry.file).unwrap();
        assert!(fresh.fetch(&entry).is_err(), "bit-flip admitted");
        // a corrupted *cache* falls through to the (restored) remote
        store.abort_staged(&entry.file).unwrap();
        store.append_staged(&entry.file, 0, &bytes).unwrap();
        store.promote(&entry.file).unwrap();
        std::fs::write(cache.join(&entry.file), b"garbage").unwrap();
        assert_eq!(reg.load(&entry).unwrap().iter, 7, "cache corruption fatal");
    }

    #[test]
    fn transient_read_faults_are_absorbed_by_the_retry_helpers() {
        let tmp = TempDir::new().unwrap();
        let root = tmp.path().join("replica");
        let local = tmp.path().join("local");
        let (entry, bytes) = published_entry(&local, 3);
        let store = FsRemoteStore::new(&root);
        store.append_staged(&entry.file, 0, &bytes).unwrap();
        store.promote(&entry.file).unwrap();
        store
            .write_atomic(
                REMOTE_MANIFEST,
                registry::manifest_json(std::slice::from_ref(&entry))
                    .to_string()
                    .as_bytes(),
            )
            .unwrap();

        let plan = plan_for(vec![site(fault::SITE_REMOTE_READ, 1, 2, None)]);
        let faulty = RemoteRegistry::new(Box::new(
            FsRemoteStore::new(&root).with_faults(plan.clone()),
        ))
        .with_retry_policy(4, 1, 0);
        // direct read fails on the injected fault ...
        assert!(faulty.entries().is_err());
        // ... the retry helper rides out the remaining firing
        let ckpt = faulty.load_latest_with_retry().unwrap().unwrap();
        assert_eq!(ckpt.iter, 3);
        assert_eq!(plan.fired(fault::SITE_REMOTE_READ), 2);

        // an exhausted budget surfaces the typed original error
        let plan = plan_for(vec![site(fault::SITE_REMOTE_READ, 1, 1_000, None)]);
        let dead = RemoteRegistry::new(Box::new(
            FsRemoteStore::new(&root).with_faults(plan),
        ))
        .with_retry_policy(2, 1, 0);
        let err = dead.entries_with_retry().unwrap_err();
        assert!(fault::is_injected(&err), "typed marker lost: {err:#}");
        assert!(format!("{err:#}").contains("retry budget exhausted"));
    }

    #[test]
    fn absent_replica_reads_as_empty() {
        let tmp = TempDir::new().unwrap();
        let reg = RemoteRegistry::new(Box::new(FsRemoteStore::new(
            tmp.path().join("never-written"),
        )));
        assert!(reg.entries().unwrap().is_empty());
        assert!(reg.latest().unwrap().is_none());
        assert!(reg.load_latest().unwrap().is_none());
        assert!(reg.load_iter(5).is_err());
    }
}
