//! `checkpoint` — durable, versioned training checkpoints with
//! bitwise-identical resume and cross-process serving hot-loads.
//!
//! E2-Train targets edge devices, and edge training gets preempted and
//! power-cycled; the system-level answer is small persistent state plus
//! interruption tolerance.  This subsystem extends the repo's standing
//! determinism contract — resident == host == sharded, bit for bit — to
//! *time*: a run interrupted at any checkpoint boundary and resumed
//! (`e2train resume <dir>`, [`crate::coordinator::Trainer::resume`])
//! produces exactly the metrics and final state of the run that never
//! stopped (tests/resume_equivalence.rs).
//!
//! * [`format`] — the `ckpt/v1` single-file container: JSON header for
//!   structure, little-endian binary payload for every exact value
//!   (tensors, RNG words, f64 accumulators), FNV-64 content hash.
//!   Truncation/corruption is rejected cleanly, never a panic.  The
//!   production encoder streams through the hasher straight to the temp
//!   file ([`format::write_checkpoint`] — constant memory, pinned
//!   byte-identical to the whole-buffer [`encode`]).
//! * [`registry`] — a directory of checkpoints with an atomically-
//!   swapped `MANIFEST.json` and keep-last-N / keep-every-M retention.
//!   Safe for concurrent cross-process readers.
//! * [`writer`] — the background publish thread the trainer hands
//!   snapshots to (off the host-side master, so sharded runs checkpoint
//!   without draining replicas), with backpressure and loud failure.
//! * [`remote`] — the off-box side: the [`remote::RemoteStore`]
//!   evacuation-target trait (filesystem-backed today, object-store
//!   shaped), and [`remote::RemoteRegistry`], the pull-through verified
//!   reader a serve fleet or resumed run in another failure domain uses.
//! * [`replicate`] — the background [`replicate::Replicator`] thread
//!   that evacuates each published checkpoint to a remote store with
//!   resumable chunked transfer, and the retention watermark that keeps
//!   prune and upload from racing.
//!
//! The serve side consumes registries through
//! [`crate::serve::watch_registry`]: a server process polls a registry
//! directory — local, or a replica root in another failure domain — and
//! hot-loads each new checkpoint into its
//! [`crate::runtime::SnapshotCell`] with a bumped `snapshot_version` —
//! trainer→server publishing across processes, no shared memory.

pub mod format;
pub mod registry;
pub mod remote;
pub mod replicate;
pub mod writer;

pub use format::{
    decode, encode, read_checkpoint, verify_trailer, write_checkpoint, CheckpointData,
    EncodeStats, SCHEMA,
};
pub use registry::{CheckpointEntry, CheckpointRegistry, RetentionCfg, REGISTRY_SCHEMA};
pub use remote::{FsRemoteStore, RemoteRegistry, RemoteStore, REMOTE_MANIFEST};
pub use replicate::{ReplicaReport, ReplicaSync, Replicator};
pub use writer::CheckpointWriter;
