//! Background checkpoint writer.
//!
//! The trainer snapshots its host-side state (for the sharded path
//! that's the host master — replicas never drain) and hands the
//! [`CheckpointData`] off here; serialization, hashing and the atomic
//! registry publish all happen on this thread, so the step loop's only
//! checkpoint cost is the host snapshot itself.
//!
//! The handoff channel has depth 1: at most one checkpoint is queued
//! while another is being written, so a pathologically slow disk
//! applies backpressure to the trainer instead of growing a queue of
//! full model copies.  A failed write parks the error; the next
//! [`CheckpointWriter::submit`] (or the end-of-run
//! [`CheckpointWriter::finish`]) surfaces it — a run whose checkpoints
//! cannot be written fails loudly rather than pretending to be
//! preemptible.

use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::obs::{self, Obs};

use super::format::CheckpointData;
use super::registry::CheckpointRegistry;

pub struct CheckpointWriter {
    tx: Option<SyncSender<CheckpointData>>,
    worker: Option<JoinHandle<()>>,
    error: Arc<Mutex<Option<anyhow::Error>>>,
    /// Checkpoints successfully published so far.
    published: Arc<Mutex<u64>>,
    /// Cloned from the registry before it moves into the writer thread;
    /// counts submit-side backpressure (time blocked on the depth-1
    /// queue) in the same trace the registry's publish spans land in.
    obs: Obs,
}

impl CheckpointWriter {
    /// Spawn the writer thread over a registry handle.
    pub fn spawn(registry: CheckpointRegistry) -> Self {
        let obs = registry.obs();
        let (tx, rx) = sync_channel::<CheckpointData>(1);
        let error = Arc::new(Mutex::new(None));
        let published = Arc::new(Mutex::new(0u64));
        let err_slot = error.clone();
        let pub_slot = published.clone();
        let worker = std::thread::Builder::new()
            .name("e2train-ckpt-writer".into())
            .spawn(move || {
                while let Ok(data) = rx.recv() {
                    match registry.publish(&data) {
                        Ok(_) => *pub_slot.lock().unwrap() += 1,
                        Err(e) => {
                            *err_slot.lock().unwrap() = Some(e);
                            // Stop consuming: the sender sees a closed
                            // channel and reports the parked error.
                            return;
                        }
                    }
                }
            })
            .expect("spawning checkpoint writer thread");
        Self { tx: Some(tx), worker: Some(worker), error, published, obs }
    }

    /// Queue one checkpoint.  Blocks only while a previous checkpoint
    /// is still being serialized/written (bounded memory); fails with
    /// the original cause once the writer has died.
    pub fn submit(&self, data: CheckpointData) -> Result<()> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("checkpoint writer already finished"))?;
        let t_send = std::time::Instant::now();
        let sent = tx.send(data);
        // Floored at 1ns per submit (like span records), so the counter
        // doubles as proof the submit path ran at all.
        self.obs.count(
            obs::CTR_CKPT_BACKPRESSURE_WAIT_NS,
            (t_send.elapsed().as_nanos() as u64).max(1),
        );
        self.obs.count(obs::CTR_CKPT_SUBMITS, 1);
        if sent.is_err() {
            return Err(self.take_error("checkpoint writer stopped"));
        }
        Ok(())
    }

    /// Checkpoints published so far (telemetry/tests).
    pub fn published(&self) -> u64 {
        *self.published.lock().unwrap()
    }

    /// Flush the queue, join the thread, and surface any deferred write
    /// error.  Returns the number of checkpoints published.
    pub fn finish(mut self) -> Result<u64> {
        self.close_and_join();
        if self.error.lock().unwrap().is_some() {
            return Err(self.take_error("checkpoint writer failed"));
        }
        Ok(self.published())
    }

    fn take_error(&self, fallback: &str) -> anyhow::Error {
        self.error
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| anyhow!("{fallback}"))
    }

    fn close_and_join(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        // A run that errored out mid-loop still flushes + reaps the
        // thread; its error (if any) is intentionally swallowed here —
        // the run's own error is the one the caller sees.
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::tests::toy_checkpoint;
    use crate::checkpoint::registry::RetentionCfg;
    use crate::util::tmp::TempDir;

    #[test]
    fn writes_flow_through_and_finish_flushes() {
        let tmp = TempDir::new().unwrap();
        let reg = CheckpointRegistry::new(tmp.path(), RetentionCfg::default());
        let w = CheckpointWriter::spawn(CheckpointRegistry::new(
            tmp.path(),
            RetentionCfg::default(),
        ));
        for iter in [3, 6, 9] {
            let mut d = toy_checkpoint();
            d.iter = iter;
            w.submit(d).unwrap();
        }
        assert_eq!(w.finish().unwrap(), 3);
        let iters: Vec<u64> = reg.entries().unwrap().iter().map(|e| e.iter).collect();
        assert_eq!(iters, vec![3, 6, 9]);
    }

    /// Round-trip with the fault harness's sink-error site: the writer
    /// thread parks the injected I/O failure and surfaces it typed at
    /// the *next* snapshot attempt (or finish) — never a panic, and the
    /// trainer thread itself keeps running to make that next attempt.
    #[test]
    fn injected_sink_fault_parks_and_surfaces_typed() {
        use crate::util::fault::{self, FaultPlan, FaultSiteCfg, FaultsCfg};

        let tmp = TempDir::new().unwrap();
        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_CKPT_SINK.into(),
                    at: 2,
                    times: 1,
                    after_bytes: Some(128),
                }],
                ..Default::default()
            },
            0,
        )
        .unwrap();
        let reg = CheckpointRegistry::new(tmp.path(), RetentionCfg::default())
            .with_faults(plan);
        let w = CheckpointWriter::spawn(reg);

        let at = |iter: u64| {
            let mut d = toy_checkpoint();
            d.iter = iter;
            d
        };
        w.submit(at(3)).unwrap(); // publishes fine (sink hit 1)
        let _ = w.submit(at(6)); // dies on the sink fault (hit 2)
        // the parked error surfaces on a later submit or on finish
        let mut surfaced = Vec::new();
        for iter in [9, 12] {
            if let Err(e) = w.submit(at(iter)) {
                surfaced.push(e);
            }
        }
        if let Err(e) = w.finish() {
            surfaced.push(e);
        }
        let err = surfaced.pop().expect("the sink failure never surfaced");
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");

        // the registry itself is intact: iter 3 published and loads
        let reg = CheckpointRegistry::new(tmp.path(), RetentionCfg::default());
        assert_eq!(reg.load_iter(3).unwrap().iter, 3);
    }

    #[test]
    fn write_failure_surfaces_on_submit_or_finish() {
        let tmp = TempDir::new().unwrap();
        // Registry dir is a *file*: create_dir_all fails on publish.
        let blocked = tmp.path().join("blocked");
        std::fs::write(&blocked, b"x").unwrap();
        let w = CheckpointWriter::spawn(CheckpointRegistry::new(
            &blocked,
            RetentionCfg::default(),
        ));
        // First submit is accepted (depth-1 queue); the failure lands on
        // a later submit or on finish.
        let _ = w.submit(toy_checkpoint());
        let mut failed = w.submit(toy_checkpoint()).is_err();
        failed |= w.submit(toy_checkpoint()).is_err();
        let fin = CheckpointWriter::spawn(CheckpointRegistry::new(
            &blocked,
            RetentionCfg::default(),
        ));
        fin.submit(toy_checkpoint()).unwrap();
        let fin_err = fin.finish().is_err();
        assert!(failed || fin_err, "write failure never surfaced");
        assert!(fin_err, "finish must report the parked error");
    }
}
