//! `ckpt/v1` — the on-disk checkpoint container.
//!
//! One file captures *everything the step loop consumes*, so a resumed
//! run is bitwise identical to one that never stopped
//! (tests/resume_equivalence.rs):
//!
//! * the full [`ModelState`] (params + momenta + gates + running-mean
//!   state) and the SWA running average when averaging has started;
//! * every RNG stream at its exact position — the sampler's
//!   cursor/permutation/generator, the SMD scheduler, the SD scheduler;
//! * the accumulators final metrics are computed from — the energy
//!   ledger, the metrics trace, the lifetime gate/PSG means;
//! * the embedded [`RunCfg`] plus its determinism fingerprint, verified
//!   on resume so a checkpoint can never silently continue a different
//!   run.
//!
//! ## Layout
//!
//! ```text
//! [0..8)      magic  b"E2CKPT1\n"
//! [8..16)     u64 LE header length H
//! [16..16+H)  header JSON (schema "ckpt/v1"): names/shapes/counts only
//! [16+H..N-8) payload: little-endian sections, in header order
//! [N-8..N)    u64 LE FNV-1a-64 over bytes [0..N-8)
//! ```
//!
//! Exact values never transit JSON: f64 text would round-trip, but
//! inf/NaN would not, and u64 RNG words exceed f64's integer range — so
//! every RNG word, permutation entry, metric accumulator and tensor
//! payload lives in the binary sections.  The header holds structure.
//!
//! Decoding is fully bounds-checked and hash-verified: a truncated or
//! bit-flipped file is rejected with a clean error, never a panic.

use std::io;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::RunCfg;
use crate::coordinator::{SdState, SmdState};
use crate::data::SamplerState;
use crate::energy::{EnergyBreakdown, EnergyLedger};
use crate::metrics::{Mean, TracePoint};
use crate::optim::SwaState;
use crate::runtime::{HostTensor, ModelState, TensorData};
use crate::util::hash::{fnv1a64, Fnv64};
use crate::util::json::{parse, Json};

/// Schema tag written into (and required from) every header.
pub const SCHEMA: &str = "ckpt/v1";

const MAGIC: &[u8; 8] = b"E2CKPT1\n";

/// Everything a checkpoint carries — the step loop's complete state at
/// an iteration boundary.
#[derive(Clone)]
pub struct CheckpointData {
    /// Next iteration the resumed loop executes (the checkpoint was
    /// written after `iter - 1` completed).
    pub iter: u64,
    /// The run's full configuration, embedded so `e2train resume <dir>`
    /// needs no launcher file.
    pub cfg: RunCfg,
    /// Host-side master state (params, momenta, gates, run_mean) in
    /// train-manifest order.
    pub model: ModelState,
    /// SWA running average, once averaging has started.
    pub swa_model: Option<ModelState>,
    pub swa: SwaState,
    pub sampler: SamplerState,
    pub smd: SmdState,
    pub sd: SdState,
    pub ledger: EnergyLedger,
    /// Metrics trace recorded so far (`RunMetrics::trace`).
    pub trace: Vec<TracePoint>,
    /// Lifetime per-gate activity means.
    pub gate_means: Vec<Mean>,
    /// Lifetime PSG predictor-usage mean.
    pub psg_mean: Mean,
}

impl CheckpointData {
    /// The state a serving snapshot should load: the SWA running
    /// average when present (matching what the in-process publisher
    /// pushes to a `SnapshotCell`), else the raw model.
    pub fn serving_state(&self) -> &ModelState {
        self.swa_model.as_ref().unwrap_or(&self.model)
    }
}

// ==========================================================================
// Encode
// ==========================================================================
//
// The byte layout is defined once: [`write_body`] emits magic + header +
// payload sections to any `io::Write` sink.  Two containers assemble it:
//
// * [`encode`] — the whole-buffer reference path: serialize to memory,
//   hash the buffer, append the trailer.  Spec-grade and used by the
//   corruption/roundtrip tests;
// * [`write_checkpoint`] — the streaming production path: every byte
//   flows through the FNV-1a-64 hasher *straight to the sink* (the
//   registry's temp file), so encoding holds no serialized copy of the
//   model — constant memory beyond the live state itself.
//
// `streaming_write_is_byte_identical_to_encode` pins the two paths
// byte-for-byte, so a drift in container assembly can't ship.

fn put_u64<W: io::Write>(w: &mut W, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn put_u32<W: io::Write>(w: &mut W, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn put_f64<W: io::Write>(w: &mut W, v: f64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn put_rng<W: io::Write>(w: &mut W, s: &[u64; 4]) -> io::Result<()> {
    for &word in s {
        put_u64(w, word)?;
    }
    Ok(())
}

fn put_mean<W: io::Write>(w: &mut W, m: &Mean) -> io::Result<()> {
    let (sum, n) = m.parts();
    put_f64(w, sum)?;
    put_u64(w, n)
}

fn put_tensor<W: io::Write>(w: &mut W, t: &HostTensor) -> io::Result<()> {
    match &t.data {
        TensorData::F32(v) => {
            for &x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        TensorData::I32(v) => {
            for &x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

fn tensor_specs(state: &ModelState) -> Json {
    Json::arr(state.names.iter().zip(state.values.iter()).map(|(n, t)| {
        let (dtype, len) = match &t.data {
            TensorData::F32(v) => ("f32", v.len()),
            TensorData::I32(v) => ("i32", v.len()),
        };
        Json::obj(vec![
            ("name", Json::str(n)),
            ("dtype", Json::str(dtype)),
            (
                "shape",
                Json::arr(t.shape.iter().map(|&d| Json::num(d as f64))),
            ),
            // Actual payload length.  Decode reads exactly this many
            // elements, so section alignment never depends on deriving
            // the count from the shape.
            ("elems", Json::num(len as f64)),
        ])
    }))
}

/// Build the header JSON (structure only — names/shapes/counts; exact
/// values live in the binary payload).
fn build_header(data: &CheckpointData) -> String {
    Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("iter", Json::num(data.iter as f64)),
        ("fingerprint", Json::str(data.cfg.fingerprint())),
        ("cfg", data.cfg.to_json()),
        (
            "sampler",
            Json::obj(vec![
                ("cursor", Json::num(data.sampler.cursor as f64)),
                ("epoch", Json::num(data.sampler.epoch as f64)),
                ("perm_len", Json::num(data.sampler.perm.len() as f64)),
            ]),
        ),
        (
            "smd",
            Json::obj(vec![
                ("skipped", Json::num(data.smd.skipped as f64)),
                ("seen", Json::num(data.smd.seen as f64)),
            ]),
        ),
        (
            "swa",
            Json::obj(vec![
                ("n_models", Json::num(data.swa.n_models as f64)),
                ("start_iter", Json::num(data.swa.start_iter as f64)),
                ("period", Json::num(data.swa.period as f64)),
            ]),
        ),
        (
            "ledger",
            Json::obj(vec![
                ("steps_charged", Json::num(data.ledger.steps_charged as f64)),
                ("steps_skipped", Json::num(data.ledger.steps_skipped as f64)),
                ("trace_len", Json::num(data.ledger.trace.len() as f64)),
            ]),
        ),
        ("trace_len", Json::num(data.trace.len() as f64)),
        ("gate_means", Json::num(data.gate_means.len() as f64)),
        ("model", tensor_specs(&data.model)),
        (
            "swa_model",
            match &data.swa_model {
                Some(s) => tensor_specs(s),
                None => Json::Null,
            },
        ),
    ])
    .to_string()
}

/// Emit everything except the trailing hash — magic, header length,
/// header, payload sections in header order — to any sink.  This is the
/// single definition of the byte layout; both container paths call it.
fn write_body<W: io::Write>(data: &CheckpointData, w: &mut W) -> io::Result<()> {
    let header = build_header(data);
    w.write_all(MAGIC)?;
    put_u64(w, header.len() as u64)?;
    w.write_all(header.as_bytes())?;

    // 1. RNG streams
    put_rng(w, &data.sampler.rng)?;
    put_rng(w, &data.smd.rng)?;
    put_rng(w, &data.sd.rng)?;
    // 2. sampler permutation
    for &x in &data.sampler.perm {
        put_u32(w, x)?;
    }
    // 3. energy ledger
    let b = &data.ledger.breakdown;
    for v in [b.fwd_mac, b.bwd_mac, b.sram, b.dram, b.update, data.ledger.macs] {
        put_f64(w, v)?;
    }
    for &(it, j) in &data.ledger.trace {
        put_u64(w, it)?;
        put_f64(w, j)?;
    }
    // 4. lifetime means
    for m in &data.gate_means {
        put_mean(w, m)?;
    }
    put_mean(w, &data.psg_mean)?;
    // 5. metrics trace
    for t in &data.trace {
        put_u64(w, t.iter)?;
        put_f64(w, t.loss)?;
        put_f64(w, t.train_acc)?;
        put_f64(w, t.joules)?;
        w.write_all(&[u8::from(t.test_acc.is_some())])?;
        put_f64(w, t.test_acc.unwrap_or(0.0))?;
    }
    // 6./7. tensor payloads
    for t in &data.model.values {
        put_tensor(w, t)?;
    }
    if let Some(s) = &data.swa_model {
        for t in &s.values {
            put_tensor(w, t)?;
        }
    }
    Ok(())
}

/// Serialize to the `ckpt/v1` byte container (whole-buffer reference
/// path: body to memory, hash, trailer).
pub fn encode(data: &CheckpointData) -> Vec<u8> {
    let mut out = Vec::new();
    write_body(data, &mut out).expect("writing to a Vec cannot fail");
    let h = fnv1a64(&out);
    put_u64(&mut out, h).expect("writing to a Vec cannot fail");
    out
}

/// What [`write_checkpoint`] streamed: total container size and the
/// FNV-1a-64 of the *complete file* (trailer included) — the hash the
/// registry manifest records for transfer/corruption checks.
#[derive(Debug, Clone, Copy)]
pub struct EncodeStats {
    pub bytes: u64,
    pub file_hash: u64,
}

/// Counts + hashes every byte on its way to the sink.
struct HashingWriter<'w, W: io::Write> {
    w: &'w mut W,
    hasher: Fnv64,
    bytes: u64,
}

impl<W: io::Write> io::Write for HashingWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.w.write(buf)?;
        self.hasher.update(&buf[..n]);
        self.bytes += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.w.flush()
    }
}

/// Streaming production encoder: pipe the body through the FNV-1a-64
/// hasher straight to `w` (the registry's temp file), then append the
/// content-hash trailer — byte-identical to [`encode`] (pinned by
/// `streaming_write_is_byte_identical_to_encode`) with no full
/// serialized copy in memory.
pub fn write_checkpoint<W: io::Write>(
    data: &CheckpointData,
    w: &mut W,
) -> Result<EncodeStats> {
    let mut hw = HashingWriter { w, hasher: Fnv64::new(), bytes: 0 };
    write_body(data, &mut hw).context("streaming checkpoint body")?;
    // The trailer is the hash of everything before it; it is itself part
    // of the file hash the registry manifest records.
    let content = hw.hasher.finish();
    io::Write::write_all(&mut hw, &content.to_le_bytes())
        .context("writing checkpoint trailer")?;
    Ok(EncodeStats { bytes: hw.bytes, file_hash: hw.hasher.finish() })
}

// ==========================================================================
// Decode
// ==========================================================================

/// Bounds-checked little-endian reader over the payload.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow!("checkpoint payload length overflow"))?;
        if end > self.b.len() {
            bail!(
                "checkpoint payload truncated: need {n} bytes at offset {}, {} remain",
                self.pos,
                self.b.len() - self.pos
            );
        }
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rng(&mut self) -> Result<[u64; 4]> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }

    fn mean(&mut self) -> Result<Mean> {
        let sum = self.f64()?;
        let n = self.u64()?;
        Ok(Mean::from_parts(sum, n))
    }

    fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        let bytes = self.take(checked_bytes(n, 4)?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(checked_bytes(n, 4)?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        let bytes = self.take(checked_bytes(n, 4)?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            bail!(
                "checkpoint payload has {} unread trailing bytes",
                self.b.len() - self.pos
            );
        }
        Ok(())
    }
}

fn checked_bytes(n: usize, width: usize) -> Result<usize> {
    n.checked_mul(width)
        .ok_or_else(|| anyhow!("checkpoint section size overflow ({n} x {width})"))
}

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("checkpoint header missing '{key}'"))
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    Ok(req_u64(v, key)? as usize)
}

/// Parse one tensor-spec list and read its payload section.
fn read_tensors(specs: &[Json], r: &mut Reader) -> Result<ModelState> {
    let mut names = Vec::with_capacity(specs.len());
    let mut values = Vec::with_capacity(specs.len());
    for spec in specs {
        let name = spec.req_str("name")?.to_string();
        let dtype = spec.req_str("dtype")?;
        let mut shape = Vec::new();
        let mut expect: usize = 1;
        for d in spec.req_arr("shape")? {
            let d = d
                .as_usize()
                .ok_or_else(|| anyhow!("tensor {name}: bad shape entry"))?;
            expect = expect
                .checked_mul(d)
                .ok_or_else(|| anyhow!("tensor {name}: shape overflow"))?;
            shape.push(d);
        }
        // Read exactly what encode wrote (the recorded payload length),
        // then validate it against the shape *under `HostTensor`'s own
        // invariant* (`elem_count` = product-or-1: rank-0 scalars and
        // zero-sized dims both carry one element).  Anything else —
        // including a crafted header whose `elems` disagrees — is a
        // clean error before any tensor is constructed, so decode can
        // neither misalign the payload nor trip `HostTensor`'s
        // debug assertions.
        let elems = spec
            .get("elems")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("tensor {name}: missing 'elems'"))?;
        if elems != expect.max(1) {
            bail!(
                "tensor {name}: payload holds {elems} elements but shape \
                 {shape:?} implies {}",
                expect.max(1)
            );
        }
        let t = match dtype {
            "f32" => HostTensor::f32(shape, r.f32_vec(elems)?),
            "i32" => HostTensor::i32(shape, r.i32_vec(elems)?),
            other => bail!("tensor {name}: unknown dtype '{other}'"),
        };
        names.push(name);
        values.push(t);
    }
    Ok(ModelState::new(values, names))
}

/// Verify the container framing without decoding: magic, minimum
/// length, and the FNV-1a-64 trailer over everything before it.  A
/// cheap whole-file integrity gate — truncation and bit-flips are
/// rejected here before any header parse or tensor construction, so
/// hot-load and replica-admission paths can refuse corrupt bytes
/// without paying for a decode.
pub fn verify_trailer(bytes: &[u8]) -> Result<()> {
    if bytes.len() < MAGIC.len() + 8 + 8 {
        bail!("checkpoint file too short ({} bytes)", bytes.len());
    }
    if &bytes[..8] != MAGIC {
        bail!("not a checkpoint file (bad magic)");
    }
    let body_end = bytes.len() - 8;
    let stored = u64::from_le_bytes(bytes[body_end..].try_into().unwrap());
    let computed = fnv1a64(&bytes[..body_end]);
    if stored != computed {
        bail!(
            "checkpoint content hash mismatch (stored {stored:016x}, \
             computed {computed:016x}): file is corrupt or truncated"
        );
    }
    Ok(())
}

/// Deserialize a `ckpt/v1` byte container, verifying magic, hash,
/// schema and internal consistency.  Every failure is a clean error.
pub fn decode(bytes: &[u8]) -> Result<CheckpointData> {
    verify_trailer(bytes)?;
    let body_end = bytes.len() - 8;
    let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let header_end = 16usize
        .checked_add(header_len)
        .ok_or_else(|| anyhow!("checkpoint header length overflow"))?;
    if header_end > body_end {
        bail!("checkpoint header overruns the file");
    }
    let header_text = std::str::from_utf8(&bytes[16..header_end])
        .context("checkpoint header is not UTF-8")?;
    let h = parse(header_text).context("parsing checkpoint header")?;
    let schema = h.req_str("schema")?;
    if schema != SCHEMA {
        bail!("unsupported checkpoint schema '{schema}' (this build reads {SCHEMA})");
    }
    let iter = req_u64(&h, "iter")?;
    let cfg = RunCfg::from_json(
        h.get("cfg")
            .ok_or_else(|| anyhow!("checkpoint header missing 'cfg'"))?,
    )
    .context("parsing embedded run config")?;
    let fingerprint = h.req_str("fingerprint")?;
    if fingerprint != cfg.fingerprint() {
        bail!(
            "checkpoint fingerprint {fingerprint} does not match its own \
             embedded config ({}): file is corrupt",
            cfg.fingerprint()
        );
    }

    let sampler_h = h
        .get("sampler")
        .ok_or_else(|| anyhow!("checkpoint header missing 'sampler'"))?;
    let smd_h = h
        .get("smd")
        .ok_or_else(|| anyhow!("checkpoint header missing 'smd'"))?;
    let swa_h = h
        .get("swa")
        .ok_or_else(|| anyhow!("checkpoint header missing 'swa'"))?;
    let ledger_h = h
        .get("ledger")
        .ok_or_else(|| anyhow!("checkpoint header missing 'ledger'"))?;
    let perm_len = req_usize(sampler_h, "perm_len")?;
    let ledger_trace_len = req_usize(ledger_h, "trace_len")?;
    let trace_len = req_usize(&h, "trace_len")?;
    let gate_means_len = req_usize(&h, "gate_means")?;
    let model_specs = h.req_arr("model")?;
    let swa_specs = match h.get("swa_model") {
        Some(Json::Null) | None => None,
        Some(v) => Some(
            v.as_arr()
                .ok_or_else(|| anyhow!("checkpoint 'swa_model' is not a list"))?,
        ),
    };

    let mut r = Reader::new(&bytes[header_end..body_end]);
    // 1. RNG streams
    let sampler_rng = r.rng()?;
    let smd_rng = r.rng()?;
    let sd_rng = r.rng()?;
    // 2. sampler permutation
    let perm = r.u32_vec(perm_len)?;
    // 3. energy ledger
    let breakdown = EnergyBreakdown {
        fwd_mac: r.f64()?,
        bwd_mac: r.f64()?,
        sram: r.f64()?,
        dram: r.f64()?,
        update: r.f64()?,
    };
    let macs = r.f64()?;
    let mut ledger_trace = Vec::with_capacity(ledger_trace_len.min(1 << 20));
    for _ in 0..ledger_trace_len {
        let it = r.u64()?;
        let j = r.f64()?;
        ledger_trace.push((it, j));
    }
    // 4. lifetime means
    let mut gate_means = Vec::with_capacity(gate_means_len.min(1 << 16));
    for _ in 0..gate_means_len {
        gate_means.push(r.mean()?);
    }
    let psg_mean = r.mean()?;
    // 5. metrics trace
    let mut trace = Vec::with_capacity(trace_len.min(1 << 20));
    for _ in 0..trace_len {
        let it = r.u64()?;
        let loss = r.f64()?;
        let train_acc = r.f64()?;
        let joules = r.f64()?;
        let has_test = r.u8()? != 0;
        let test = r.f64()?;
        trace.push(TracePoint {
            iter: it,
            loss,
            train_acc,
            joules,
            test_acc: if has_test { Some(test) } else { None },
        });
    }
    // 6./7. tensor payloads
    let model = read_tensors(model_specs, &mut r)?;
    let swa_model = match swa_specs {
        Some(specs) => Some(read_tensors(specs, &mut r)?),
        None => None,
    };
    r.done()?;

    Ok(CheckpointData {
        iter,
        model,
        swa_model,
        swa: SwaState {
            n_models: req_u64(swa_h, "n_models")?,
            start_iter: req_u64(swa_h, "start_iter")?,
            period: req_u64(swa_h, "period")?.max(1),
        },
        sampler: SamplerState {
            rng: sampler_rng,
            perm,
            cursor: req_u64(sampler_h, "cursor")?,
            epoch: req_u64(sampler_h, "epoch")?,
        },
        smd: SmdState {
            rng: smd_rng,
            skipped: req_u64(smd_h, "skipped")?,
            seen: req_u64(smd_h, "seen")?,
        },
        sd: SdState { rng: sd_rng },
        ledger: EnergyLedger {
            steps_charged: req_u64(ledger_h, "steps_charged")?,
            steps_skipped: req_u64(ledger_h, "steps_skipped")?,
            breakdown,
            macs,
            trace: ledger_trace,
        },
        trace,
        gate_means,
        psg_mean,
        cfg,
    })
}

/// Read + decode one checkpoint file.
pub fn read_checkpoint(path: &std::path::Path) -> Result<CheckpointData> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    decode(&bytes).with_context(|| format!("decoding checkpoint {}", path.display()))
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::RunCfg;

    fn toy_model(seed: f32) -> ModelState {
        ModelState::new(
            vec![
                HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32 + seed).collect()),
                HostTensor::f32(vec![3], vec![seed, -seed, 0.5]),
                HostTensor::i32(vec![2], vec![7, -9]),
            ],
            vec!["w".into(), "b".into(), "counts".into()],
        )
    }

    pub(crate) fn toy_checkpoint() -> CheckpointData {
        let mut ledger = EnergyLedger::default();
        ledger.steps_charged = 5;
        ledger.steps_skipped = 2;
        ledger.macs = 123.5;
        ledger.breakdown.fwd_mac = 1e9;
        ledger.trace = vec![(0, 0.25), (1, 0.5)];
        let mut psg = Mean::default();
        psg.push(0.75);
        CheckpointData {
            iter: 7,
            cfg: RunCfg::quick("fam", "e2train", 20),
            model: toy_model(1.0),
            swa_model: Some(toy_model(-3.0)),
            swa: SwaState { n_models: 2, start_iter: 10, period: 1 },
            sampler: SamplerState {
                rng: [1, 2, 3, 4],
                perm: vec![3, 0, 2, 1],
                cursor: 2,
                epoch: 1,
            },
            smd: SmdState { rng: [5, 6, 7, 8], skipped: 2, seen: 7 },
            sd: SdState { rng: [9, 10, 11, 12] },
            ledger,
            trace: vec![
                TracePoint {
                    iter: 0,
                    loss: 2.302,
                    train_acc: 0.125,
                    joules: 0.25,
                    test_acc: Some(0.1),
                },
                TracePoint {
                    iter: 4,
                    loss: f64::NAN, // exactness includes non-finite values
                    train_acc: 0.25,
                    joules: 0.5,
                    test_acc: None,
                },
            ],
            gate_means: vec![Mean::from_parts(1.5, 3), Mean::from_parts(0.0, 0)],
            psg_mean: psg,
        }
    }

    /// Bitwise state compare that also covers i32 tensors (the crate's
    /// `assert_bitwise_eq` is f32-only).
    fn assert_state_eq(a: &ModelState, b: &ModelState) {
        assert_eq!(a.names, b.names);
        for ((n, x), y) in a.names.iter().zip(a.values.iter()).zip(b.values.iter()) {
            assert_eq!(x.shape, y.shape, "{n}: shape drift");
            match (&x.data, &y.data) {
                (TensorData::F32(p), TensorData::F32(q)) => {
                    let pb: Vec<u32> = p.iter().map(|v| v.to_bits()).collect();
                    let qb: Vec<u32> = q.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(pb, qb, "{n}: f32 payload drift");
                }
                (TensorData::I32(p), TensorData::I32(q)) => {
                    assert_eq!(p, q, "{n}: i32 payload drift");
                }
                _ => panic!("{n}: dtype drift"),
            }
        }
    }

    fn assert_same(a: &CheckpointData, b: &CheckpointData) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.cfg.to_json(), b.cfg.to_json());
        assert_state_eq(&a.model, &b.model);
        match (&a.swa_model, &b.swa_model) {
            (Some(x), Some(y)) => assert_state_eq(x, y),
            (None, None) => {}
            _ => panic!("swa_model presence drifted"),
        }
        assert_eq!(
            (a.swa.n_models, a.swa.start_iter, a.swa.period),
            (b.swa.n_models, b.swa.start_iter, b.swa.period)
        );
        assert_eq!(a.sampler, b.sampler);
        assert_eq!(a.smd, b.smd);
        assert_eq!(a.sd, b.sd);
        assert_eq!(a.ledger.steps_charged, b.ledger.steps_charged);
        assert_eq!(a.ledger.steps_skipped, b.ledger.steps_skipped);
        assert_eq!(a.ledger.macs.to_bits(), b.ledger.macs.to_bits());
        assert_eq!(
            a.ledger.breakdown.total().to_bits(),
            b.ledger.breakdown.total().to_bits()
        );
        assert_eq!(a.ledger.trace, b.ledger.trace);
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(b.trace.iter()) {
            assert_eq!(x.iter, y.iter);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.train_acc.to_bits(), y.train_acc.to_bits());
            assert_eq!(x.joules.to_bits(), y.joules.to_bits());
            assert_eq!(
                x.test_acc.map(f64::to_bits),
                y.test_acc.map(f64::to_bits)
            );
        }
        let parts = |ms: &[Mean]| -> Vec<(u64, u64)> {
            ms.iter()
                .map(|m| {
                    let (s, n) = m.parts();
                    (s.to_bits(), n)
                })
                .collect()
        };
        assert_eq!(parts(&a.gate_means), parts(&b.gate_means));
        assert_eq!(parts(&[a.psg_mean.clone()]), parts(&[b.psg_mean.clone()]));
    }

    #[test]
    fn roundtrip_is_exact() {
        let data = toy_checkpoint();
        let bytes = encode(&data);
        let back = decode(&bytes).unwrap();
        assert_same(&data, &back);
        // encoding is deterministic
        assert_eq!(bytes, encode(&back));
    }

    /// The streaming production path must produce the exact bytes of
    /// the whole-buffer reference path — trailer included — and report
    /// the whole-file hash the registry manifest records.
    #[test]
    fn streaming_write_is_byte_identical_to_encode() {
        for data in [toy_checkpoint(), {
            let mut d = toy_checkpoint();
            d.swa_model = None;
            d.trace.clear();
            d
        }] {
            let reference = encode(&data);
            let mut streamed = Vec::new();
            let stats = write_checkpoint(&data, &mut streamed).unwrap();
            assert_eq!(streamed, reference, "container bytes drifted");
            assert_eq!(stats.bytes, reference.len() as u64);
            assert_eq!(stats.file_hash, crate::util::hash::fnv1a64(&reference));
            // and the streamed container decodes like any other
            assert_same(&data, &decode(&streamed).unwrap());
        }
    }

    /// A failing sink surfaces as a clean error, never a panic or a
    /// silent short file.
    #[test]
    fn streaming_write_propagates_sink_errors() {
        struct Broken;
        impl std::io::Write for Broken {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::other("disk full"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let err = write_checkpoint(&toy_checkpoint(), &mut Broken).unwrap_err();
        assert!(format!("{err:#}").contains("disk full"));
    }

    #[test]
    fn roundtrip_without_swa_model() {
        let mut data = toy_checkpoint();
        data.swa_model = None;
        let back = decode(&encode(&data)).unwrap();
        assert!(back.swa_model.is_none());
        assert_same(&data, &back);
    }

    #[test]
    fn corruption_is_rejected_cleanly() {
        let bytes = encode(&toy_checkpoint());

        // truncations at every region boundary (and inside them)
        for cut in [0, 4, 12, 20, bytes.len() / 2, bytes.len() - 1] {
            let err = decode(&bytes[..cut]).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("too short")
                    || msg.contains("hash mismatch")
                    || msg.contains("truncated"),
                "cut at {cut}: unexpected error {msg}"
            );
        }
        // a single flipped bit anywhere fails the content hash
        for pos in [9, 17, bytes.len() / 2, bytes.len() - 9] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(decode(&bad).is_err(), "flip at {pos} accepted");
        }
        // wrong magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(format!("{:#}", decode(&bad).unwrap_err()).contains("magic"));
        // empty / garbage files
        assert!(decode(&[]).is_err());
        assert!(decode(b"hello world, definitely not a checkpoint").is_err());
    }

    #[test]
    fn serving_state_prefers_swa() {
        let data = toy_checkpoint();
        assert_eq!(
            data.serving_state().values[0].as_f32().unwrap(),
            data.swa_model.as_ref().unwrap().values[0].as_f32().unwrap()
        );
        let mut no_swa = data.clone();
        no_swa.swa_model = None;
        assert_eq!(
            no_swa.serving_state().values[0].as_f32().unwrap(),
            no_swa.model.values[0].as_f32().unwrap()
        );
    }
}
