//! Directory-level checkpoint registry.
//!
//! A registry is a directory of `ckpt-<iter>.e2c` files plus a
//! `MANIFEST.json` index (`schema ckpt_registry/v1`).  Both the
//! checkpoint files and the manifest are written **atomically**
//! (temp file in the same directory + `rename`), so a concurrent
//! reader — `e2train resume`, or a serve process hot-loading weights
//! ([`crate::serve::watch_registry`]) — never observes a torn file.
//! Write ordering is checkpoint-file-first, manifest-second: anything
//! the manifest lists is fully on disk.
//!
//! Retention is applied at publish time: the newest `keep_last`
//! checkpoints always survive, and when `keep_every > 0` every
//! checkpoint whose iteration is a multiple of it is kept forever
//! (coarse history for rollback/debugging while the tail stays dense).
//! When replication is armed ([`CheckpointRegistry::with_replication_floor`])
//! retention additionally never prunes a checkpoint the replicator has
//! not yet evacuated — the local registry may only forget what another
//! failure domain already holds.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::{self, Obs};
use crate::util::fault::{self, FaultPlan, FaultShot};
use crate::util::hash::fnv1a64_hex;
use crate::util::json::{parse, Json};

use super::format::{self, CheckpointData};

/// Manifest schema tag.
pub const REGISTRY_SCHEMA: &str = "ckpt_registry/v1";

const MANIFEST: &str = "MANIFEST.json";

/// Retention policy applied on every publish.
#[derive(Debug, Clone, Copy)]
pub struct RetentionCfg {
    /// Always keep the newest N checkpoints (min 1).
    pub keep_last: usize,
    /// Additionally keep every checkpoint with `iter % keep_every == 0`
    /// (0 = disabled).
    pub keep_every: u64,
}

impl Default for RetentionCfg {
    fn default() -> Self {
        Self { keep_last: 3, keep_every: 0 }
    }
}

/// One manifest row.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointEntry {
    pub iter: u64,
    /// File name relative to the registry directory.
    pub file: String,
    /// FNV-1a-64 hex of the file contents (verified on load).
    pub hash: String,
    pub bytes: u64,
}

/// Handle to a registry directory.  Stateless — every operation reads
/// the manifest fresh, so multiple handles (and multiple processes)
/// stay coherent through the atomic manifest swaps.
pub struct CheckpointRegistry {
    dir: PathBuf,
    retention: RetentionCfg,
    faults: Option<Arc<FaultPlan>>,
    obs: Obs,
    prune_failures: Arc<AtomicU64>,
    replication_floor: Option<Arc<AtomicU64>>,
}

impl CheckpointRegistry {
    /// A handle on `dir` (no I/O yet; the directory is created on the
    /// first publish, and a missing manifest reads as "no checkpoints").
    pub fn new(dir: impl Into<PathBuf>, retention: RetentionCfg) -> Self {
        Self {
            dir: dir.into(),
            retention,
            faults: None,
            obs: Obs::off(),
            prune_failures: Arc::new(AtomicU64::new(0)),
            replication_floor: None,
        }
    }

    /// Arm a fault plan: the `checkpoint.sink` site fails the streaming
    /// file write after its byte budget and `registry.read` makes a
    /// manifest read come back torn.
    pub fn with_faults(mut self, faults: Arc<FaultPlan>) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Attach an observability handle: [`CheckpointRegistry::publish`]
    /// records `checkpoint-encode` (the streaming serialize + write) and
    /// `registry-publish` (the whole publish, retention included) spans
    /// on the calling thread — the background writer, in a live run.
    pub fn with_obs(mut self, obs: Obs) -> Self {
        self.obs = obs;
        self
    }

    /// The attached observability handle ([`Obs::off`] by default) —
    /// cloned by [`super::CheckpointWriter::spawn`] before the registry
    /// moves into the writer thread, so submit-side backpressure waits
    /// land in the same trace.
    pub fn obs(&self) -> Obs {
        self.obs.clone()
    }

    /// Shared counter of retention-prune failures (see
    /// [`CheckpointRegistry::publish`]): grab a handle before moving the
    /// registry into a writer thread, read it after the run.
    pub fn prune_failure_counter(&self) -> Arc<AtomicU64> {
        self.prune_failures.clone()
    }

    /// Arm the replicator-vs-retention guard: `floor` is the replication
    /// watermark (highest iteration fully verified on the remote,
    /// maintained by [`super::Replicator`]).  While armed, retention
    /// never prunes a checkpoint with `iter > floor` — the prune-vs-
    /// mid-upload race is closed at its source, and the local registry
    /// only forgets checkpoints another failure domain already holds.
    /// Disk growth is bounded by replication lag, not by `keep_last`.
    pub fn with_replication_floor(mut self, floor: Arc<AtomicU64>) -> Self {
        self.replication_floor = Some(floor);
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn manifest_path(&self) -> PathBuf {
        self.dir.join(MANIFEST)
    }

    /// All published checkpoints, ascending by iteration.  An absent
    /// manifest is an empty registry; a corrupt one is an error.
    pub fn entries(&self) -> Result<Vec<CheckpointEntry>> {
        let path = self.manifest_path();
        if let Some(p) = &self.faults {
            p.check(fault::SITE_REGISTRY_READ).map_err(|e| {
                anyhow::Error::new(e)
                    .context(format!("reading manifest {} (torn read)", path.display()))
            })?;
        }
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading manifest {}", path.display()))
            }
        };
        parse_manifest(&text)
            .with_context(|| format!("parsing manifest {}", path.display()))
    }

    /// The newest checkpoint entry, if any.
    pub fn latest(&self) -> Result<Option<CheckpointEntry>> {
        Ok(self.entries()?.into_iter().last())
    }

    /// Read one listed checkpoint's bytes with **no** verification —
    /// for callers that own the integrity check themselves (the serve
    /// watcher verifies hash + trailer so it can count corrupt files as
    /// rejects rather than transient read errors).
    pub fn read_raw(&self, entry: &CheckpointEntry) -> Result<Vec<u8>> {
        let path = self.dir.join(&entry.file);
        std::fs::read(&path)
            .with_context(|| format!("reading checkpoint {}", path.display()))
    }

    /// Read one listed checkpoint's raw bytes, verified against the
    /// manifest hash but **not** decoded — the cheap integrity gate the
    /// restore paths share (pair with [`format::verify_trailer`] to also
    /// check the container framing).
    pub fn load_bytes(&self, entry: &CheckpointEntry) -> Result<Vec<u8>> {
        let path = self.dir.join(&entry.file);
        let bytes = self.read_raw(entry)?;
        let hash = fnv1a64_hex(&bytes);
        if hash != entry.hash {
            bail!(
                "checkpoint {} hash {hash} does not match manifest ({}): \
                 file is corrupt",
                path.display(),
                entry.hash
            );
        }
        Ok(bytes)
    }

    /// Load + verify one listed checkpoint.
    pub fn load(&self, entry: &CheckpointEntry) -> Result<CheckpointData> {
        let path = self.dir.join(&entry.file);
        let bytes = self.load_bytes(entry)?;
        format::decode(&bytes)
            .with_context(|| format!("decoding checkpoint {}", path.display()))
    }

    /// Load the newest checkpoint, `None` for an empty registry.
    pub fn load_latest(&self) -> Result<Option<CheckpointData>> {
        match self.latest()? {
            Some(e) => Ok(Some(self.load(&e)?)),
            None => Ok(None),
        }
    }

    /// Load the checkpoint published at a specific iteration.
    pub fn load_iter(&self, iter: u64) -> Result<CheckpointData> {
        let entries = self.entries()?;
        let entry = entries.iter().find(|e| e.iter == iter).ok_or_else(|| {
            anyhow!(
                "no checkpoint at iter {iter} under {} (have: {})",
                self.dir.display(),
                entries
                    .iter()
                    .map(|e| e.iter.to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
        self.load(entry)
    }

    /// Serialize + publish one checkpoint: streaming atomic file write,
    /// manifest update, retention pruning.  Re-publishing an iteration
    /// replaces its entry.  Single-writer by design (the trainer's
    /// writer thread); readers in other processes stay safe throughout.
    ///
    /// The checkpoint streams through the FNV hasher straight to the
    /// temp file (`format::write_checkpoint`) — constant memory instead
    /// of a full serialized copy, byte-identical to the whole-buffer
    /// encoder by pinned test.
    pub fn publish(&self, data: &CheckpointData) -> Result<CheckpointEntry> {
        let t_pub = std::time::Instant::now();
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating registry dir {}", self.dir.display()))?;
        let file = format!("ckpt-{:010}.e2c", data.iter);
        let path = self.dir.join(&file);
        let sink_fault = self.faults.as_ref().and_then(|p| p.hit(fault::SITE_CKPT_SINK));
        let t_enc = std::time::Instant::now();
        let stats = stream_atomic(&path, data, sink_fault)?;
        self.obs.record(obs::PHASE_CKPT_ENCODE, t_enc.elapsed());
        let entry = CheckpointEntry {
            iter: data.iter,
            file,
            hash: format!("{:016x}", stats.file_hash),
            bytes: stats.bytes,
        };

        let mut entries = self.entries()?;
        entries.retain(|e| e.iter != entry.iter);
        entries.push(entry.clone());
        entries.sort_by_key(|e| e.iter);
        let (keep, pruned) = self.split_retained(entries);
        self.write_manifest(&keep)?;
        // Files are unlinked only after the manifest stopped listing
        // them, so a reader never sees a listed-but-missing checkpoint.
        // A failed unlink (a version directory deleted out from under
        // us, a permission flip) must never abort training — the new
        // checkpoint is already durable.  Log it, count it (surfaces in
        // `RunMetrics::prune_failures`), move on.  An already-gone file
        // is the *goal* of pruning, not a failure.
        for p in &pruned {
            let victim = self.dir.join(&p.file);
            if let Err(e) = std::fs::remove_file(&victim) {
                if e.kind() != std::io::ErrorKind::NotFound {
                    self.prune_failures.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "[ckpt] retention prune of {} failed ({e}); continuing",
                        victim.display()
                    );
                }
            }
        }
        self.obs.record(obs::PHASE_REGISTRY_PUBLISH, t_pub.elapsed());
        Ok(entry)
    }

    fn split_retained(
        &self,
        entries: Vec<CheckpointEntry>,
    ) -> (Vec<CheckpointEntry>, Vec<CheckpointEntry>) {
        let keep_last = self.retention.keep_last.max(1);
        // Replication guard: everything newer than the watermark is
        // still in flight to the remote and must survive retention.
        let floor = self
            .replication_floor
            .as_ref()
            .map(|f| f.load(Ordering::Acquire));
        let n = entries.len();
        let mut keep = Vec::with_capacity(n);
        let mut pruned = Vec::new();
        for (i, e) in entries.into_iter().enumerate() {
            let in_tail = i + keep_last >= n;
            let pinned =
                self.retention.keep_every > 0 && e.iter % self.retention.keep_every == 0;
            let unreplicated = floor.is_some_and(|w| e.iter > w);
            if in_tail || pinned || unreplicated {
                keep.push(e);
            } else {
                pruned.push(e);
            }
        }
        (keep, pruned)
    }

    fn write_manifest(&self, entries: &[CheckpointEntry]) -> Result<()> {
        write_atomic(
            &self.manifest_path(),
            manifest_json(entries).to_string().as_bytes(),
        )
    }
}

/// Parse a `ckpt_registry/v1` manifest body into its entries, ascending
/// by iteration.  Shared by the local registry and the remote replica
/// reader (`checkpoint::remote`) — both sides speak the same schema.
pub(crate) fn parse_manifest(text: &str) -> Result<Vec<CheckpointEntry>> {
    let v = parse(text)?;
    let schema = v.req_str("schema")?;
    if schema != REGISTRY_SCHEMA {
        bail!("unsupported registry schema '{schema}'");
    }
    let mut out = Vec::new();
    for row in v.req_arr("checkpoints")? {
        out.push(CheckpointEntry {
            iter: row
                .get("iter")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("manifest row missing 'iter'"))?,
            file: row.req_str("file")?.to_string(),
            hash: row.req_str("hash")?.to_string(),
            bytes: row.get("bytes").and_then(Json::as_u64).unwrap_or(0),
        });
    }
    out.sort_by_key(|e| e.iter);
    Ok(out)
}

/// Serialize entries as a `ckpt_registry/v1` manifest document — the
/// single source of the schema for local and replica manifests alike.
pub(crate) fn manifest_json(entries: &[CheckpointEntry]) -> Json {
    Json::obj(vec![
        ("schema", Json::str(REGISTRY_SCHEMA)),
        (
            "checkpoints",
            Json::arr(entries.iter().map(|e| {
                Json::obj(vec![
                    ("iter", Json::num(e.iter as f64)),
                    ("file", Json::str(&e.file)),
                    ("hash", Json::str(&e.hash)),
                    ("bytes", Json::num(e.bytes as f64)),
                ])
            })),
        ),
    ])
}

/// Write-then-rename in the target's directory (same filesystem, so the
/// rename is atomic on POSIX).  Shared with the filesystem-backed
/// remote store (`checkpoint::remote`), which publishes its replica
/// manifest under the identical contract.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_sibling(path)?;
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    rename_into_place(&tmp, path)
}

/// Stream-encode one checkpoint into a temp sibling of `path` and
/// rename it into place — the same atomicity contract as
/// [`write_atomic`], without ever holding the serialized checkpoint in
/// memory.  An armed `checkpoint.sink` fault swaps in a byte-budgeted
/// writer ("disk full after N bytes"); the failure path is identical to
/// a real I/O error — the temp file is removed and nothing publishes.
fn stream_atomic(
    path: &Path,
    data: &CheckpointData,
    sink_fault: Option<FaultShot>,
) -> Result<format::EncodeStats> {
    let tmp = tmp_sibling(path)?;
    let write = || -> Result<format::EncodeStats> {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(file);
        let stats = match sink_fault {
            None => format::write_checkpoint(data, &mut w)?,
            Some(shot) => {
                let mut fw = fault::FailingWriter::new(&mut w, shot.after_bytes);
                format::write_checkpoint(data, &mut fw)?
            }
        };
        // Surface buffered-write errors before the rename publishes.
        w.into_inner()
            .map_err(|e| anyhow!("flushing {}: {}", tmp.display(), e.error()))?;
        Ok(stats)
    };
    let stats = match write() {
        Ok(s) => s,
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
    };
    rename_into_place(&tmp, path)?;
    Ok(stats)
}

pub(crate) fn tmp_sibling(path: &Path) -> Result<PathBuf> {
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow!("bad target path {}", path.display()))?
        .to_string_lossy()
        .to_string();
    Ok(path.with_file_name(format!(".{file_name}.tmp-{}", std::process::id())))
}

pub(crate) fn rename_into_place(tmp: &Path, path: &Path) -> Result<()> {
    std::fs::rename(tmp, path).with_context(|| {
        let _ = std::fs::remove_file(tmp);
        format!("publishing {}", path.display())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::tests::toy_checkpoint;
    use crate::util::tmp::TempDir;

    fn publish_at(reg: &CheckpointRegistry, iter: u64) -> CheckpointEntry {
        let mut data = toy_checkpoint();
        data.iter = iter;
        reg.publish(&data).unwrap()
    }

    #[test]
    fn empty_registry_reads_clean() {
        let tmp = TempDir::new().unwrap();
        let reg = CheckpointRegistry::new(
            tmp.path().join("does-not-exist-yet"),
            RetentionCfg::default(),
        );
        assert!(reg.entries().unwrap().is_empty());
        assert!(reg.latest().unwrap().is_none());
        assert!(reg.load_latest().unwrap().is_none());
        assert!(reg.load_iter(5).is_err());
    }

    #[test]
    fn publish_load_roundtrip_and_latest() {
        let tmp = TempDir::new().unwrap();
        let reg = CheckpointRegistry::new(tmp.path(), RetentionCfg::default());
        publish_at(&reg, 10);
        publish_at(&reg, 20);
        let latest = reg.latest().unwrap().unwrap();
        assert_eq!(latest.iter, 20);
        assert_eq!(reg.load_latest().unwrap().unwrap().iter, 20);
        assert_eq!(reg.load_iter(10).unwrap().iter, 10);
        // re-publishing an iteration replaces, not duplicates
        publish_at(&reg, 20);
        assert_eq!(
            reg.entries().unwrap().iter().filter(|e| e.iter == 20).count(),
            1
        );
    }

    #[test]
    fn retention_keeps_tail_and_pinned() {
        let tmp = TempDir::new().unwrap();
        let reg = CheckpointRegistry::new(
            tmp.path(),
            RetentionCfg { keep_last: 2, keep_every: 40 },
        );
        for iter in [10, 20, 30, 40, 50, 60, 70, 80, 90] {
            publish_at(&reg, iter);
        }
        let iters: Vec<u64> = reg.entries().unwrap().iter().map(|e| e.iter).collect();
        // tail of 2 (80, 90) + multiples of 40 (40, 80)
        assert_eq!(iters, vec![40, 80, 90]);
        // pruned files are actually gone; kept files exist
        assert!(!tmp.path().join("ckpt-0000000010.e2c").exists());
        assert!(!tmp.path().join("ckpt-0000000070.e2c").exists());
        assert!(tmp.path().join("ckpt-0000000040.e2c").exists());
        assert!(tmp.path().join("ckpt-0000000090.e2c").exists());
        // everything retained still loads + verifies
        for e in reg.entries().unwrap() {
            assert_eq!(reg.load(&e).unwrap().iter, e.iter);
        }
    }

    #[test]
    fn corrupt_file_or_manifest_is_a_clean_error() {
        let tmp = TempDir::new().unwrap();
        let reg = CheckpointRegistry::new(tmp.path(), RetentionCfg::default());
        let e = publish_at(&reg, 5);

        // flip a byte in the checkpoint file -> hash mismatch on load
        let p = tmp.path().join(&e.file);
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", reg.load_latest().unwrap_err());
        assert!(err.contains("hash"), "unexpected error: {err}");

        // truncate the file -> still a clean error
        std::fs::write(&p, &bytes[..bytes.len() / 3]).unwrap();
        assert!(reg.load_latest().is_err());

        // corrupt manifest -> parse error, not a panic
        std::fs::write(tmp.path().join(MANIFEST), b"{not json").unwrap();
        assert!(reg.entries().is_err());
    }

    /// With the replication guard armed, retention never prunes entries
    /// above the watermark — they are still in flight to the remote.
    /// Once the watermark advances, the ordinary policy applies again.
    #[test]
    fn replication_floor_protects_unreplicated_entries() {
        let tmp = TempDir::new().unwrap();
        let floor = Arc::new(AtomicU64::new(0));
        let reg = CheckpointRegistry::new(
            tmp.path(),
            RetentionCfg { keep_last: 1, keep_every: 0 },
        )
        .with_replication_floor(floor.clone());

        for iter in [10, 20, 30] {
            publish_at(&reg, iter);
        }
        let iters: Vec<u64> = reg.entries().unwrap().iter().map(|e| e.iter).collect();
        assert_eq!(iters, vec![10, 20, 30], "nothing replicated, nothing pruned");

        // the replicator verified through iter 20: 10 and 20 become
        // ordinary candidates, 30 stays protected (and is also the tail)
        floor.store(20, Ordering::Release);
        publish_at(&reg, 40);
        let iters: Vec<u64> = reg.entries().unwrap().iter().map(|e| e.iter).collect();
        assert_eq!(iters, vec![30, 40], "replicated history pruned, in-flight kept");
        assert!(!tmp.path().join("ckpt-0000000010.e2c").exists());
        assert!(tmp.path().join("ckpt-0000000030.e2c").exists());
    }

    /// A retention prune that can't unlink its victim (here: the file
    /// was replaced by a directory out from under us) must not fail the
    /// publish — the new checkpoint is already durable.  It is counted
    /// on the shared prune-failure counter; an already-missing victim
    /// is not a failure at all.
    #[test]
    fn prune_failure_is_tolerated_and_counted() {
        let tmp = TempDir::new().unwrap();
        let reg = CheckpointRegistry::new(
            tmp.path(),
            RetentionCfg { keep_last: 1, keep_every: 0 },
        );
        let ctr = reg.prune_failure_counter();
        let e10 = publish_at(&reg, 10);
        let victim = tmp.path().join(&e10.file);
        std::fs::remove_file(&victim).unwrap();
        std::fs::create_dir(&victim).unwrap();

        publish_at(&reg, 20); // prunes iter 10 -> unlink fails -> tolerated
        assert_eq!(ctr.load(Ordering::Relaxed), 1, "failed prune counted");
        assert_eq!(reg.latest().unwrap().unwrap().iter, 20);
        assert!(
            !reg.entries().unwrap().iter().any(|e| e.iter == 10),
            "the manifest stopped listing the unprunable checkpoint"
        );

        // an already-gone victim is the goal of pruning, not a failure
        std::fs::remove_file(tmp.path().join("ckpt-0000000020.e2c")).unwrap();
        publish_at(&reg, 30);
        assert_eq!(ctr.load(Ordering::Relaxed), 1, "NotFound not counted");
    }

    /// The `checkpoint.sink` fault site fails the streaming write after
    /// its byte budget exactly like a full disk: nothing publishes, no
    /// temp litter, and the next publish (site exhausted) succeeds.
    #[test]
    fn injected_sink_fault_fails_the_publish_atomically() {
        use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};

        let tmp = TempDir::new().unwrap();
        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_CKPT_SINK.into(),
                    at: 1,
                    times: 1,
                    after_bytes: Some(64),
                }],
                ..Default::default()
            },
            0,
        )
        .unwrap();
        let reg = CheckpointRegistry::new(tmp.path(), RetentionCfg::default())
            .with_faults(plan.clone());

        let mut data = toy_checkpoint();
        data.iter = 10;
        let err = reg.publish(&data).unwrap_err();
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");
        assert!(reg.entries().unwrap().is_empty(), "partial publish listed");
        let litter: Vec<_> = std::fs::read_dir(tmp.path())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .filter(|n| n.contains("tmp"))
            .collect();
        assert!(litter.is_empty(), "temp litter left behind: {litter:?}");

        // site exhausted: the retry goes through
        assert_eq!(reg.publish(&data).unwrap().iter, 10);
        assert_eq!(plan.fired(fault::SITE_CKPT_SINK), 1);
    }

    /// The `registry.read` fault site makes one manifest read come back
    /// torn; the next read is clean (readers retry around it).
    #[test]
    fn injected_manifest_fault_tears_one_read() {
        use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};

        let tmp = TempDir::new().unwrap();
        let plain = CheckpointRegistry::new(tmp.path(), RetentionCfg::default());
        publish_at(&plain, 5);

        let plan = FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_REGISTRY_READ.into(),
                    at: 2,
                    times: 1,
                    after_bytes: None,
                }],
                ..Default::default()
            },
            0,
        )
        .unwrap();
        let reg = CheckpointRegistry::new(tmp.path(), RetentionCfg::default())
            .with_faults(plan);
        assert_eq!(reg.entries().unwrap().len(), 1);
        let err = reg.entries().unwrap_err();
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");
        assert_eq!(reg.entries().unwrap().len(), 1, "reads recover");
    }
}
