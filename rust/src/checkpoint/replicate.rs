//! Background checkpoint evacuation to another failure domain.
//!
//! [`Replicator`] watches the local registry's `MANIFEST.json` and
//! pushes every newly published checkpoint to a [`RemoteStore`] with
//! the resumable staged-upload protocol (see [`super::remote`]): chunks
//! append to a staged object, the full staged payload is hash-verified
//! against the local manifest entry, then promoted atomically and
//! listed in the remote `MANIFEST.json` — also written atomically, so
//! replica readers only ever see fully verified checkpoints.
//!
//! Failure semantics mirror [`super::CheckpointWriter`]: the worker
//! thread parks its first error and stops; [`Replicator::finish`]
//! surfaces it at the end of the run, where the supervisor classifies
//! it (injected/transient → restart from the latest checkpoint).  The
//! *next* attempt's replicator then finds the staged bytes the failed
//! transfer left behind, verifies them against the local prefix, and
//! resumes from the last verified offset instead of restarting the
//! upload — counted as `replica.retries`.
//!
//! Two deliberate asymmetries with the local registry:
//!
//! * the replicator reads local state through its own **fault-free**
//!   registry handle — local polling must not consume `registry.read`
//!   fault budgets and perturb the supervisor's deterministic schedule;
//! * the remote manifest is a *superset* archive: entries pruned by
//!   local retention stay listed on the replica (it exists precisely to
//!   outlive the local disk).  A torn remote manifest is rebuilt, not
//!   fatal — payload objects are individually content-verified, so the
//!   listing is derived state.
//!
//! The vanished-source race (retention prunes a file between manifest
//! snapshot and upload read) is tolerated: skip, count
//! (`replica.skipped-vanished`), advance — never an error.  The
//! inverse race is closed on the registry side: with a replication
//! watermark attached ([`CheckpointRegistry::with_replication_floor`]),
//! retention never prunes an entry the replicator has not finished
//! evacuating.
//!
//! [`CheckpointRegistry::with_replication_floor`]: super::CheckpointRegistry::with_replication_floor

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::obs::{self, Obs};
use crate::util::fault;
use crate::util::hash::fnv1a64_hex;

use super::registry::{self, CheckpointEntry, CheckpointRegistry, RetentionCfg};
use super::remote::{RemoteStore, REMOTE_MANIFEST};

/// Upload chunk size.  Small enough that an injected `after_bytes`
/// truncation lands mid-object in tests, large enough that a real
/// checkpoint moves in a handful of appends.
pub const CHUNK_BYTES: usize = 64 * 1024;

/// What one run's replication accomplished; lands in `RunMetrics` and
/// (additively) in `BENCH_runtime.json`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaReport {
    /// Checkpoints fully evacuated (verified + promoted + listed).
    pub uploaded: u64,
    /// Payload bytes appended to the remote store by this run.
    pub bytes: u64,
    /// Uploads resumed from a prior attempt's verified staged bytes.
    pub retries: u64,
    /// Source files pruned away before they could be read (skipped).
    pub skipped_vanished: u64,
    /// Local iterations not yet on the replica when the run ended.
    pub lag_iters: u64,
}

/// The synchronous replication core: one call to
/// [`ReplicaSync::sync_once`] drains everything the local manifest
/// lists above the watermark.  The [`Replicator`] thread drives it on a
/// poll loop; unit tests drive it directly.
pub struct ReplicaSync {
    local: CheckpointRegistry,
    local_dir: PathBuf,
    store: Box<dyn RemoteStore>,
    watermark: Arc<AtomicU64>,
    obs: Obs,
    /// Lazily loaded view of the remote manifest (superset archive).
    remote: Option<Vec<CheckpointEntry>>,
    uploaded: u64,
    bytes: u64,
    retries: u64,
    skipped_vanished: u64,
}

impl ReplicaSync {
    pub fn new(
        local_dir: impl Into<PathBuf>,
        store: Box<dyn RemoteStore>,
        watermark: Arc<AtomicU64>,
        obs: Obs,
    ) -> Self {
        let local_dir = local_dir.into();
        Self {
            // Fault-free local handle by design (see module docs).
            local: CheckpointRegistry::new(&local_dir, RetentionCfg::default()),
            local_dir,
            store,
            watermark,
            obs,
            remote: None,
            uploaded: 0,
            bytes: 0,
            retries: 0,
            skipped_vanished: 0,
        }
    }

    /// Evacuate every local manifest entry above the watermark,
    /// ascending by iteration.  Returns after the backlog drains; errors
    /// on the first upload/publish failure (the caller retries the whole
    /// sync — resumable staging makes that cheap).
    pub fn sync_once(&mut self) -> Result<()> {
        let entries = self.local.entries()?;
        if self.remote.is_none() {
            self.remote = Some(self.remote_view()?);
        }
        let floor = self.watermark.load(Ordering::Acquire);
        for entry in entries.into_iter().filter(|e| e.iter > floor) {
            if !self.replicate_entry(&entry)? {
                break;
            }
        }
        Ok(())
    }

    /// Current snapshot of what this sync accomplished.
    pub fn report(&self) -> ReplicaReport {
        let latest = self
            .local
            .entries()
            .ok()
            .and_then(|v| v.last().map(|e| e.iter))
            .unwrap_or(0);
        ReplicaReport {
            uploaded: self.uploaded,
            bytes: self.bytes,
            retries: self.retries,
            skipped_vanished: self.skipped_vanished,
            lag_iters: latest.saturating_sub(self.watermark.load(Ordering::Acquire)),
        }
    }

    /// The remote manifest as currently published; absent reads as
    /// empty, and a torn document is *rebuilt* rather than fatal (every
    /// payload object is content-verified on its own, the listing is
    /// derived state — and the torn write is exactly what the
    /// `replicate.manifest` fault injects).
    fn remote_view(&self) -> Result<Vec<CheckpointEntry>> {
        let bytes = match self.store.read(REMOTE_MANIFEST) {
            Ok(b) => b,
            Err(e) if super::remote::is_not_found(&e) && !fault::is_injected(&e) => {
                return Ok(Vec::new());
            }
            Err(e) => return Err(e),
        };
        let parsed = std::str::from_utf8(&bytes)
            .ok()
            .and_then(|t| registry::parse_manifest(t).ok());
        Ok(parsed.unwrap_or_else(|| {
            eprintln!(
                "[replicate] remote manifest at {} unreadable (torn write?); rebuilding",
                self.store.describe()
            );
            Vec::new()
        }))
    }

    /// Push one checkpoint.  `Ok(true)` = advance to the next entry,
    /// `Ok(false)` = the manifest moved under us (re-published
    /// iteration); end the round and re-snapshot.
    fn replicate_entry(&mut self, entry: &CheckpointEntry) -> Result<bool> {
        let t = Instant::now();
        let src = self.local_dir.join(&entry.file);
        let bytes = match std::fs::read(&src) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // Retention won the race.  The entry is gone locally and
                // can never be evacuated — skip it, count it, and keep
                // the run alive.
                self.skipped_vanished += 1;
                self.obs.count(obs::CTR_REPLICA_SKIPPED_VANISHED, 1);
                eprintln!(
                    "[replicate] {} vanished before upload (retention prune); skipping",
                    src.display()
                );
                self.advance(entry.iter);
                return Ok(true);
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading {} for replication", src.display()))
            }
        };
        if fnv1a64_hex(&bytes) != entry.hash {
            return Ok(false);
        }
        let already = self
            .remote
            .as_ref()
            .is_some_and(|v| v.iter().any(|r| r.iter == entry.iter && r.hash == entry.hash));
        if !already {
            self.upload(entry, &bytes)
                .with_context(|| format!("replicating {}", entry.file))?;
            self.publish_remote(entry.clone())?;
            self.uploaded += 1;
        }
        self.advance(entry.iter);
        self.obs.record(obs::PHASE_REPLICATE_UPLOAD, t.elapsed());
        Ok(true)
    }

    /// The resumable chunked transfer: reuse any verified staged prefix
    /// a failed attempt left behind, append the rest, verify the full
    /// staged hash against the manifest entry, promote.
    fn upload(&mut self, entry: &CheckpointEntry, bytes: &[u8]) -> Result<()> {
        let total = bytes.len() as u64;
        let staged = self.store.staged_len(&entry.file)?;
        let mut offset = 0u64;
        if staged > 0 {
            if staged <= total
                && self.store.read_staged(&entry.file, staged)?.as_slice()
                    == &bytes[..staged as usize]
            {
                offset = staged;
                self.retries += 1;
                self.obs.count(obs::CTR_REPLICA_RETRIES, 1);
                eprintln!(
                    "[replicate] resuming {} from verified offset {offset}/{total}",
                    entry.file
                );
            } else {
                self.store.abort_staged(&entry.file)?;
            }
        }
        let resumed_from = offset;
        while offset < total {
            let end = (offset + CHUNK_BYTES as u64).min(total);
            self.store.append_staged(
                &entry.file,
                offset,
                &bytes[offset as usize..end as usize],
            )?;
            offset = end;
        }
        let landed = self.store.read_staged(&entry.file, total)?;
        let hash = fnv1a64_hex(&landed);
        if hash != entry.hash {
            self.store.abort_staged(&entry.file)?;
            bail!(
                "staged upload of {} hashes to {hash}, expected {}: staged bytes discarded",
                entry.file,
                entry.hash
            );
        }
        self.store.promote(&entry.file)?;
        let sent = total - resumed_from;
        self.bytes += sent;
        self.obs.count(obs::CTR_REPLICA_BYTES, sent);
        Ok(())
    }

    fn publish_remote(&mut self, entry: CheckpointEntry) -> Result<()> {
        let view = self.remote.get_or_insert_with(Vec::new);
        view.retain(|r| r.iter != entry.iter);
        view.push(entry);
        view.sort_by_key(|r| r.iter);
        self.store
            .write_atomic(
                REMOTE_MANIFEST,
                registry::manifest_json(view).to_string().as_bytes(),
            )
            .context("publishing remote manifest")
    }

    /// Raise the replication watermark (single writer: this thread).
    /// Retention on the local registry prunes nothing above it.
    fn advance(&self, iter: u64) {
        if iter > self.watermark.load(Ordering::Acquire) {
            self.watermark.store(iter, Ordering::Release);
        }
    }
}

/// Background evacuation thread.  Lifecycle mirrors
/// [`super::CheckpointWriter`]: spawn next to the trainer, let it poll,
/// then [`finish`](Replicator::finish) — which drains the backlog one
/// final time (the last checkpoint of a run is never left behind) and
/// surfaces any parked error.
pub struct Replicator {
    handle: Option<JoinHandle<()>>,
    stop: Arc<(Mutex<bool>, Condvar)>,
    error: Arc<Mutex<Option<anyhow::Error>>>,
    report: Arc<Mutex<ReplicaReport>>,
}

impl Replicator {
    /// Start watching `local_dir`'s manifest, evacuating to `store`,
    /// raising `watermark` as entries land.  Attach the same watermark
    /// to the local registry via
    /// [`CheckpointRegistry::with_replication_floor`] so retention and
    /// replication cannot race.
    ///
    /// [`CheckpointRegistry::with_replication_floor`]: super::CheckpointRegistry::with_replication_floor
    pub fn spawn(
        local_dir: impl Into<PathBuf>,
        store: Box<dyn RemoteStore>,
        watermark: Arc<AtomicU64>,
        obs: Obs,
        poll: Duration,
    ) -> Self {
        let local_dir = local_dir.into();
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let error: Arc<Mutex<Option<anyhow::Error>>> = Arc::new(Mutex::new(None));
        let report = Arc::new(Mutex::new(ReplicaReport::default()));
        let (stop2, error2, report2) = (stop.clone(), error.clone(), report.clone());
        let handle = std::thread::Builder::new()
            .name("e2train-replicator".into())
            .spawn(move || {
                let mut sync = ReplicaSync::new(local_dir, store, watermark, obs);
                loop {
                    if let Err(e) = sync.sync_once() {
                        *error2.lock().unwrap() = Some(e);
                        return;
                    }
                    let (lock, cvar) = &*stop2;
                    let mut stopped = lock.lock().unwrap();
                    if !*stopped {
                        let (guard, _timed_out) =
                            cvar.wait_timeout(stopped, poll).unwrap();
                        stopped = guard;
                    }
                    let done = *stopped;
                    drop(stopped);
                    if done {
                        // Final drain: anything published since the last
                        // poll tick still gets evacuated.
                        if let Err(e) = sync.sync_once() {
                            *error2.lock().unwrap() = Some(e);
                            return;
                        }
                        *report2.lock().unwrap() = sync.report();
                        return;
                    }
                }
            })
            .expect("spawning replicator thread");
        Self { handle: Some(handle), stop, error, report }
    }

    /// Stop polling, drain the backlog, surface any parked error.
    pub fn finish(mut self) -> Result<ReplicaReport> {
        self.close_and_join();
        if let Some(e) = self.error.lock().unwrap().take() {
            return Err(e.context("checkpoint replicator failed"));
        }
        Ok(self.report.lock().unwrap().clone())
    }

    fn close_and_join(&mut self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap() = true;
        cvar.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Replicator {
    /// Error-swallowing cleanup for early-exit paths; the normal path is
    /// [`Replicator::finish`], which reports instead of swallowing.
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::tests::toy_checkpoint;
    use crate::checkpoint::remote::{FsRemoteStore, RemoteRegistry};
    use crate::util::fault::{FaultPlan, FaultSiteCfg, FaultsCfg};
    use crate::util::tmp::TempDir;
    use std::path::Path;

    fn publish_local(dir: &Path, iters: &[u64]) -> Vec<CheckpointEntry> {
        let reg = CheckpointRegistry::new(dir, RetentionCfg::default());
        iters
            .iter()
            .map(|&iter| {
                let mut data = toy_checkpoint();
                data.iter = iter;
                reg.publish(&data).unwrap()
            })
            .collect()
    }

    fn upload_plan(after_bytes: u64) -> Arc<FaultPlan> {
        FaultPlan::from_cfg(
            &FaultsCfg {
                sites: vec![FaultSiteCfg {
                    site: fault::SITE_REPLICATE_UPLOAD.into(),
                    at: 1,
                    times: 1,
                    after_bytes: Some(after_bytes),
                }],
                ..Default::default()
            },
            0,
        )
        .unwrap()
    }

    #[test]
    fn sync_evacuates_and_the_replica_reads_back_identical() {
        let tmp = TempDir::new().unwrap();
        let local = tmp.path().join("local");
        let root = tmp.path().join("replica");
        let entries = publish_local(&local, &[10, 20]);

        let watermark = Arc::new(AtomicU64::new(0));
        let mut sync = ReplicaSync::new(
            &local,
            Box::new(FsRemoteStore::new(&root)),
            watermark.clone(),
            Obs::off(),
        );
        sync.sync_once().unwrap();
        let report = sync.report();
        assert_eq!(report.uploaded, 2);
        assert_eq!(report.lag_iters, 0);
        assert_eq!(report.retries, 0);
        assert_eq!(report.bytes, entries.iter().map(|e| e.bytes).sum::<u64>());
        assert_eq!(watermark.load(Ordering::Acquire), 20);

        let remote = RemoteRegistry::new(Box::new(FsRemoteStore::new(&root)));
        assert_eq!(remote.entries().unwrap(), entries);
        // `load` verified the whole-file hash against the manifest entry,
        // which the local registry computed at publish — the replica copy
        // is bitwise identical by construction; spot-check the decode.
        assert_eq!(remote.load(&entries[1]).unwrap().iter, 20);

        // a second sync is a no-op: nothing above the watermark
        sync.sync_once().unwrap();
        assert_eq!(sync.report().uploaded, 2);
    }

    #[test]
    fn truncated_upload_resumes_from_the_verified_offset() {
        let tmp = TempDir::new().unwrap();
        let local = tmp.path().join("local");
        let root = tmp.path().join("replica");
        let entries = publish_local(&local, &[5]);
        let plan = upload_plan(100);

        let watermark = Arc::new(AtomicU64::new(0));
        let mut sync = ReplicaSync::new(
            &local,
            Box::new(FsRemoteStore::new(&root).with_faults(plan.clone())),
            watermark.clone(),
            Obs::off(),
        );
        let err = sync.sync_once().unwrap_err();
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");
        assert_eq!(watermark.load(Ordering::Acquire), 0, "nothing verified yet");
        assert!(sync.report().lag_iters > 0);

        // "restart": a fresh sync (same staged state on the remote)
        let watermark = Arc::new(AtomicU64::new(0));
        let mut sync = ReplicaSync::new(
            &local,
            Box::new(FsRemoteStore::new(&root).with_faults(plan.clone())),
            watermark.clone(),
            Obs::off(),
        );
        sync.sync_once().unwrap();
        let report = sync.report();
        assert_eq!(report.uploaded, 1);
        assert_eq!(report.retries, 1, "resume not detected");
        assert_eq!(
            report.bytes,
            entries[0].bytes - 100,
            "resumed upload re-sent already-verified bytes"
        );
        let remote = RemoteRegistry::new(Box::new(FsRemoteStore::new(&root)));
        assert_eq!(remote.load(&entries[0]).unwrap().iter, 5);
    }

    #[test]
    fn vanished_source_is_skipped_not_fatal() {
        let tmp = TempDir::new().unwrap();
        let local = tmp.path().join("local");
        let root = tmp.path().join("replica");
        let entries = publish_local(&local, &[1, 2]);
        // retention-prune race: the older file disappears after the
        // manifest snapshot listed it
        std::fs::remove_file(local.join(&entries[0].file)).unwrap();

        let watermark = Arc::new(AtomicU64::new(0));
        let mut sync = ReplicaSync::new(
            &local,
            Box::new(FsRemoteStore::new(&root)),
            watermark.clone(),
            Obs::off(),
        );
        sync.sync_once().unwrap();
        let report = sync.report();
        assert_eq!(report.skipped_vanished, 1);
        assert_eq!(report.uploaded, 1);
        assert_eq!(report.lag_iters, 0);
        assert_eq!(watermark.load(Ordering::Acquire), 2);
        let remote = RemoteRegistry::new(Box::new(FsRemoteStore::new(&root)));
        assert_eq!(remote.entries().unwrap(), vec![entries[1].clone()]);
    }

    #[test]
    fn torn_remote_manifest_is_rebuilt() {
        let tmp = TempDir::new().unwrap();
        let local = tmp.path().join("local");
        let root = tmp.path().join("replica");
        let entries = publish_local(&local, &[3]);
        std::fs::create_dir_all(&root).unwrap();
        std::fs::write(root.join(REMOTE_MANIFEST), b"{\"schema\": \"ckpt_reg").unwrap();

        let mut sync = ReplicaSync::new(
            &local,
            Box::new(FsRemoteStore::new(&root)),
            Arc::new(AtomicU64::new(0)),
            Obs::off(),
        );
        sync.sync_once().unwrap();
        let remote = RemoteRegistry::new(Box::new(FsRemoteStore::new(&root)));
        assert_eq!(remote.entries().unwrap(), entries);
    }

    #[test]
    fn replicator_thread_drains_on_finish() {
        let tmp = TempDir::new().unwrap();
        let local = tmp.path().join("local");
        let root = tmp.path().join("replica");
        let entries = publish_local(&local, &[7]);

        let watermark = Arc::new(AtomicU64::new(0));
        let rep = Replicator::spawn(
            &local,
            Box::new(FsRemoteStore::new(&root)),
            watermark.clone(),
            Obs::off(),
            Duration::from_millis(2),
        );
        // publish one more while the replicator is live
        let more = publish_local(&local, &[8]);
        let report = rep.finish().unwrap();
        assert_eq!(report.uploaded, 2);
        assert_eq!(report.lag_iters, 0);
        assert_eq!(watermark.load(Ordering::Acquire), 8);
        let remote = RemoteRegistry::new(Box::new(FsRemoteStore::new(&root)));
        assert_eq!(
            remote.entries().unwrap(),
            vec![entries[0].clone(), more[0].clone()]
        );
    }

    #[test]
    fn replicator_thread_parks_upload_errors_until_finish() {
        let tmp = TempDir::new().unwrap();
        let local = tmp.path().join("local");
        let root = tmp.path().join("replica");
        publish_local(&local, &[4]);
        let plan = upload_plan(10);

        let rep = Replicator::spawn(
            &local,
            Box::new(FsRemoteStore::new(&root).with_faults(plan.clone())),
            Arc::new(AtomicU64::new(0)),
            Obs::off(),
            Duration::from_millis(2),
        );
        let err = rep.finish().unwrap_err();
        assert!(fault::is_injected(&err), "untyped failure: {err:#}");
        assert_eq!(plan.fired(fault::SITE_REPLICATE_UPLOAD), 1);
    }
}
